"""The mapper proper: compile a ``NetworkSpec`` onto K logical chips.

``map_network`` turns the declarative graph into physical resources:

  columns   ``partition_columns`` tiles neurons onto chips (defect-aware
            via a ``Blacklist``, balanced over usable capacity);
  rows      every (source, chip, sign) with nonzero local fan-in gets a
            driver row — even rows excitatory, odd rows inhibitory (the
            silicon's Dale pairing, ``AnnCore.step``) — allocated in
            ascending canonical source order so the per-column FMA
            chains of every chip are subsequences of the monolithic
            chain (the bit-exactness argument, ``docs/exactness.md``);
  addresses each allocated row gets a 6-bit address from the per-chip
            schedule (allocation ordinal mod 64) stored across the whole
            row — one address per driver row, which is exactly the
            ``const_addr`` promise the fused synaptic path exploits;
  routes    recurrent sources announce their spikes over the inter-chip
            bus: one ``WaferPlan`` route per (source, destination row).
            A destination the topology does not link directly is reached
            through a RELAY hop — a transit row on an intermediate chip
            plus a PR 9 ``fwd_*`` forward rule — at the cost of one
            extra window of latency (relayed edges are therefore
            excluded from the cross-K bit-equality contract; the mapper
            reports them in ``n_relayed_edges``).

The result is a validated ``ChipMapping``: per-chip weight/address
planes, the ``WaferPlan``, and the placement tables the runtime
(``repro.mapper.runtime``) uses to place inputs and gather spikes.
``map_network`` finishes by RECONSTRUCTING the signed connectivity from
the physical planes and asserting it equals the spec — mapping bugs are
never silent.

Contract tests: ``tests/test_mapper.py`` (``TestMapping`` invariants,
``TestExactness`` round-trip vs monolithic emulation).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.mapper.partition import (CapacityError, ColumnPartition,
                                    partition_columns)
from repro.mapper.spec import NetworkSpec
from repro.wafer.topology import WaferPlan, WaferTopology


@dataclass(frozen=True)
class ChipMapping:
    """A compiled placement of one ``NetworkSpec`` on K chips.

    Attributes:
      spec: the mapped network.
      part: neuron -> (chip, column) assignment.
      row_source: ``[K, R]`` int32 — canonical source id driving each
        row, -1 for unused rows.
      row_sign: ``[K, R]`` int8 — +1 excitatory driver, -1 inhibitory,
        0 unused or pure transit (relay) row.
      row_addr: ``[K, R]`` int8 — the 6-bit address schedule (valid on
        allocated rows).
      weights: ``[K, R, C]`` int8 — unsigned per-chip synapse planes.
      addresses: ``[K, R, C]`` int8 — per-chip stored address planes
        (each allocated row holds its schedule address in every column).
      plan: the validated ``WaferPlan`` (routes + forward rules).
      n_relayed_edges: spec edges delivered through a relay hop (one
        window of EXTRA latency — excluded from cross-K bit-equality).
      n_transit_rows: rows allocated purely to relay traffic.

    Contract test: ``tests/test_mapper.py::TestMapping``.
    """
    spec: NetworkSpec
    part: ColumnPartition
    row_source: np.ndarray
    row_sign: np.ndarray
    row_addr: np.ndarray
    weights: np.ndarray
    addresses: np.ndarray
    plan: WaferPlan
    n_relayed_edges: int = 0
    n_transit_rows: int = 0

    @property
    def n_chips(self) -> int:
        return self.part.n_chips

    @property
    def chip_rows(self) -> int:
        return self.plan.n_rows

    @property
    def chip_cols(self) -> int:
        return self.part.chip_cols

    def input_rows(self):
        """[(chip, row, input_source)] — where external input events are
        placed by ``repro.mapper.runtime.place_inputs``."""
        out = []
        ks, rs = np.nonzero((self.row_source >= 0)
                            & (self.row_source < self.spec.n_in))
        for k, r in zip(ks.tolist(), rs.tolist()):
            out.append((k, r, int(self.row_source[k, r])))
        return out

    def rows_used(self) -> np.ndarray:
        """[K] allocated driver rows per chip (incl. transit rows)."""
        return (self.row_source >= 0).sum(axis=1)

    def reconstruct(self) -> np.ndarray:
        """Signed ``[n_sources, n_neurons]`` connectivity read back from
        the physical planes — must equal ``spec.w_full()`` exactly."""
        w = np.zeros((self.spec.n_sources, self.spec.n_neurons), np.int64)
        for k in range(self.n_chips):
            neurons = self.part.chip_neurons(k)
            slots = self.part.col_slot[neurons]
            for r in np.nonzero(self.row_sign[k] != 0)[0]:
                s = int(self.row_source[k, r])
                w[s, neurons] += (int(self.row_sign[k, r])
                                  * self.weights[k, r, slots].astype(np.int64))
        return w

    def validate(self):
        """Re-assert every mapping invariant (the hypothesis suite calls
        this on random specs). Raises AssertionError on violation."""
        K, R, C = self.n_chips, self.chip_rows, self.chip_cols
        assert self.row_source.shape == (K, R)
        assert self.weights.shape == (K, R, C)
        used = self.row_source >= 0
        # Dale pairing: excitatory drivers on even rows, inhibitory on odd
        rows = np.arange(R)[None, :]
        assert (self.row_sign[~used] == 0).all()
        assert not ((self.row_sign == 1) & (rows % 2 == 1)).any()
        assert not ((self.row_sign == -1) & (rows % 2 == 0)).any()
        # unused rows are silent in every plane
        assert (self.weights[~used] == 0).all()
        assert (self.addresses[~used] == 0).all()
        # allocated rows store their schedule address in every column
        for k, r in zip(*np.nonzero(used)):
            assert (self.addresses[k, r] == self.row_addr[k, r]).all()
        # ascending source order within each parity class (the FMA-order
        # invariant behind the bit-exactness contract); pure transit rows
        # are exempt — their weights are zero, so their FMA terms are
        # exact zeros wherever they sit
        for k in range(K):
            for par in (0, 1):
                src = self.row_source[k, par::2]
                sgn = self.row_sign[k, par::2]
                src = src[(src >= 0) & (sgn != 0)]
                assert (np.diff(src) > 0).all(), \
                    f"chip {k} parity {par}: rows out of source order"
        # routed deliveries carry the destination row's schedule address
        pl = self.plan
        assert (self.row_addr[pl.dst_chip, pl.dst_row]
                == pl.addr.astype(np.int8)).all()
        if pl.n_forwards:
            assert (self.row_addr[pl.fwd_dst_chip, pl.fwd_dst_row]
                    == pl.fwd_addr.astype(np.int8)).all()
        # the physical planes realise exactly the spec connectivity
        np.testing.assert_array_equal(self.reconstruct(),
                                      self.spec.w_full())


def row_demand(spec: NetworkSpec, part: ColumnPartition) -> np.ndarray:
    """[K, 2] driver rows each chip needs per parity class (excitatory,
    inhibitory; before transit rows): one row per (source, sign) with
    nonzero fan-in to the chip's neurons."""
    w = spec.w_full()
    demand = np.zeros((part.n_chips, 2), np.int64)
    for k in range(part.n_chips):
        wloc = w[:, part.chip_neurons(k)]
        demand[k, 0] = (wloc > 0).any(axis=1).sum()
        demand[k, 1] = (wloc < 0).any(axis=1).sum()
    return demand


def map_network(spec: NetworkSpec, n_chips: int, chip_rows: int = 256,
                chip_cols: int = 512, topology: str = "all2all",
                blacklist=None) -> ChipMapping:
    """Compile ``spec`` onto ``n_chips`` chips of ``chip_rows`` x
    ``chip_cols``.

    Args:
      spec: the network (any size; capacity is checked, never truncated).
      n_chips: K logical chips (K == 1 is the monolithic reference the
        exactness contract compares against — same machinery, one chip).
      chip_rows / chip_cols: per-chip synapse-array geometry (the native
        BSS-2 fabric is 256 x 512). ``chip_rows`` must be even (Dale
        row pairing).
      topology: "all2all" (default — any pair linked, every edge direct)
        or "ring" (only k -> k+1 linked; unlinked destinations go
        through a relay hop when an intermediate chip has both links,
        else ``CapacityError``).
      blacklist: optional ``repro.faults.Blacklist`` — screened-out rows
        and neuron columns are avoided by placement (defect-aware
        mapping) and blacklisted links are treated as absent (edges
        re-homed through relays). The mapped network is the IDEAL
        network on the surviving fabric: bit-identical to the clean
        monolithic emulation (``tests/test_mapper.py::TestExactness``).

    Returns: a validated ``ChipMapping``.

    Raises:
      CapacityError: columns, rows, or links do not suffice — with the
        chip and demand/capacity named. Degradation is never silent.
    """
    assert chip_rows % 2 == 0, "Dale pairing needs an even row count"
    K, R, C = n_chips, chip_rows, chip_cols
    bad_rows = np.zeros((K, R), bool)
    bad_neurons = np.zeros((K, C), bool)
    dead_links = set()
    if blacklist is not None:
        if blacklist.rows is not None:
            bad_rows = np.asarray(blacklist.rows, bool)
            assert bad_rows.shape == (K, R), \
                f"blacklist rows shape {bad_rows.shape} != {(K, R)}"
        if blacklist.neurons is not None:
            bad_neurons = np.asarray(blacklist.neurons, bool)
            assert bad_neurons.shape == (K, C), \
                f"blacklist neurons shape {bad_neurons.shape} != {(K, C)}"
        dead_links = {(int(s), int(d)) for s, d in (blacklist.links or ())}

    part = partition_columns(spec.n_neurons, K, C, bad_neurons)
    topo = WaferTopology(K, topology)
    links = set(topo.links()) - dead_links

    w = spec.w_full()
    row_source = np.full((K, R), -1, np.int32)
    row_sign = np.zeros((K, R), np.int8)
    row_addr = np.zeros((K, R), np.int8)
    weights = np.zeros((K, R, C), np.int8)
    addresses = np.zeros((K, R, C), np.int8)

    free_e = [deque(r for r in range(0, R, 2) if not bad_rows[k, r])
              for k in range(K)]
    free_i = [deque(r for r in range(1, R, 2) if not bad_rows[k, r])
              for k in range(K)]
    n_alloc = [0] * K
    # (chip, source) -> {sign: row}; sign 0 holds a pure transit row
    rows_of = [dict() for _ in range(K)]

    def alloc(k, s, sign, free):
        if not free[k]:
            kind = {1: "excitatory", -1: "inhibitory", 0: "transit"}[sign]
            raise CapacityError(
                f"chip {k}: out of {kind} driver rows at source {s} "
                f"(R={R}, {int(bad_rows[k].sum())} blacklisted, "
                f"{n_alloc[k]} allocated)")
        r = free[k].popleft()
        row_source[k, r] = s
        row_sign[k, r] = sign
        a = n_alloc[k] % 64
        row_addr[k, r] = a
        addresses[k, r, :] = a
        n_alloc[k] += 1
        rows_of[k].setdefault(s, {})[sign] = r
        return r

    # -- driver-row allocation: ascending source order per chip ------------
    for k in range(K):
        neurons = part.chip_neurons(k)
        slots = part.col_slot[neurons]
        wloc = w[:, neurons]                               # [S, n_loc]
        need_e = (wloc > 0).any(axis=1)
        need_i = (wloc < 0).any(axis=1)
        for s in np.nonzero(need_e | need_i)[0].tolist():
            if need_e[s]:
                r = alloc(k, s, 1, free_e)
                weights[k, r, slots] = np.maximum(wloc[s], 0)
            if need_i[s]:
                r = alloc(k, s, -1, free_i)
                weights[k, r, slots] = np.maximum(-wloc[s], 0)

    # -- routes: recurrent sources announce spikes over the bus ------------
    routes = []     # (src_chip, src_col, dst_chip, dst_row, addr)
    fwds = []       # (fwd_src_chip, fwd_src_row, dst_chip, dst_row, addr)
    routed = set()  # (src_chip, src_col, dst_chip, dst_row) de-dup
    n_relayed = 0
    n_transit = 0

    def relay_row(s, sc, scol, m):
        """A row on intermediate chip ``m`` that receives source ``s``'s
        spikes (reusing an existing driver row when ``m`` already has
        local fan-in from ``s``, else allocating a transit row)."""
        nonlocal n_transit
        have = rows_of[m].get(s, {})
        for sign in (1, -1, 0):
            if sign in have:
                return have[sign]
        r = alloc(m, s, 0, free_e if free_e[m] else free_i)
        n_transit += 1
        return r

    for j in range(spec.n_neurons):
        s = spec.n_in + j
        sc = int(part.col_chip[j])
        scol = int(part.col_slot[j])
        for d in range(K):
            targets = [(sgn, r) for sgn, r in rows_of[d].get(s, {}).items()
                       if sgn != 0]
            if not targets:
                continue
            if (sc, d) in links:
                for _, r in targets:
                    key = (sc, scol, d, r)
                    if key not in routed:
                        routed.add(key)
                        routes.append((sc, scol, d, r, int(row_addr[d, r])))
                continue
            # relay hop: an intermediate chip with both links alive
            mids = [m for m in range(K)
                    if m != sc and (sc, m) in links and (m, d) in links]
            if not mids:
                raise CapacityError(
                    f"edge neuron {j} (chip {sc}) -> chip {d} has no "
                    f"{topology} link and no relay path"
                    + ("" if topology == "all2all"
                       else "; use topology='all2all'"))
            m = mids[0]
            rt = relay_row(s, sc, scol, m)
            key = (sc, scol, m, rt)
            if key not in routed:
                routed.add(key)
                routes.append((sc, scol, m, rt, int(row_addr[m, rt])))
            for _, r in targets:
                fwds.append((m, rt, d, r, int(row_addr[d, r])))
                n_relayed += 1

    rt = np.asarray(routes, np.int32).reshape(-1, 5)
    fw = np.asarray(fwds, np.int32).reshape(-1, 5)
    plan = WaferPlan(
        topology=topo, n_rows=R, n_cols=C,
        src_chip=rt[:, 0], src_col=rt[:, 1], dst_chip=rt[:, 2],
        dst_row=rt[:, 3], addr=rt[:, 4],
        fwd_src_chip=fw[:, 0], fwd_src_row=fw[:, 1], fwd_dst_chip=fw[:, 2],
        fwd_dst_row=fw[:, 3], fwd_addr=fw[:, 4])

    mapping = ChipMapping(
        spec=spec, part=part, row_source=row_source, row_sign=row_sign,
        row_addr=row_addr, weights=weights, addresses=addresses, plan=plan,
        n_relayed_edges=n_relayed, n_transit_rows=n_transit)
    mapping.validate()
    return mapping


def min_chip_rows(spec: NetworkSpec, n_chips: int, chip_cols: int = 512,
                  blacklist=None) -> int:
    """Smallest even ``chip_rows`` that fits ``spec`` on ``n_chips``
    (before transit rows and row blacklists) — a sizing aid for the
    monolithic reference and the examples."""
    bad_neurons = None
    if blacklist is not None and blacklist.neurons is not None:
        bad_neurons = blacklist.neurons
    part = partition_columns(spec.n_neurons, n_chips, chip_cols, bad_neurons)
    d = int(row_demand(spec, part).max(initial=0))
    return max(2, 2 * d)
