"""Automatic network partitioner / chip mapper.

Compile an arbitrary-topology ``NetworkSpec`` (sizes beyond the native
256x512 fabric, arbitrary sparse connectivity, arbitrary Dale sign
structure) onto K logical BSS-2 chips, bit-exactly: the partitioned and
routed emulation equals the single-virtual-chip emulation of the same
network with ``assert_array_equal``.  See ``docs/mapper.md`` for the
walkthrough and ``docs/exactness.md`` for the argument.

    spec    = mapper.NetworkSpec(n_in=300, n_neurons=700, w_in=...)
    m       = mapper.map_network(spec, n_chips=4)
    rt      = mapper.build_runtime(m)
    _, out  = rt.run(ev_in)          # out["spikes"]: [W, T, 700]
"""
from repro.mapper.mapping import (ChipMapping, map_network, min_chip_rows,
                                  row_demand)
from repro.mapper.partition import (CapacityError, ColumnPartition,
                                    partition_columns)
from repro.mapper.runtime import (MappedRuntime, build_runtime,
                                  gather_spikes, place_inputs,
                                  sample_network_instance, scatter_instance)
from repro.mapper.spec import WMAX, NetworkSpec, random_spec

__all__ = [
    "CapacityError", "ChipMapping", "ColumnPartition", "MappedRuntime",
    "NetworkSpec", "WMAX", "build_runtime", "gather_spikes", "map_network",
    "min_chip_rows", "partition_columns", "place_inputs", "random_spec",
    "row_demand", "sample_network_instance", "scatter_instance",
]
