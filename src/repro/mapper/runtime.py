"""Run a mapped network: K-invariant instances, input placement, spike
gathering, and the routed window scan.

The cross-K bit-exactness contract (mapped K chips ==
``assert_array_equal`` == the K=1 monolithic mapping) needs every
physical quantity that enters the dynamics to be a *pure function of the
spec*, scattered — not resampled — onto whatever chip layout the mapper
chose:

  * ``sample_network_instance`` draws the analog mismatch realisation at
    SPEC shapes — per-neuron ``[n_neurons]`` columns, per-source
    ``[n_sources]`` rows — so the draw is independent of K;
  * ``scatter_instance`` places those draws at each neuron's
    ``(chip, column)`` and each source's driver rows (replicated rows of
    one source share the row parameters: they see the same event train,
    so their STP efficacy trajectories are bit-identical replicas);
    unmapped rows/columns keep the ideal nominal values — they carry
    zero weight and never spike, so they are exact-zero terms;
  * ``place_inputs`` writes each external input's event train onto its
    driver rows on every chip; recurrent traffic rides the router with
    the one-window bus latency — ON EVERY CHIP COUNT, including K=1
    (the self-link), which is what makes the latency K-invariant.

Contract test: ``tests/test_mapper.py::TestExactness`` (K in {1, 2, 4},
fused + blocked backends, ring + all2all, with and without a blacklist).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2, BSS2Config
from repro.core.anncore import AnnCore
from repro.mapper.mapping import ChipMapping
from repro.mapper.spec import NetworkSpec
from repro.verif.mismatch import ideal_instance, sample_instance
from repro.wafer.router import InterChipRouter, run_windows


def sample_network_instance(spec: NetworkSpec, key,
                            cfg: Optional[BSS2Config] = None) -> dict:
    """Mismatch realisation at spec shapes (K-independent).

    Args:
      spec: the network; draws are per-neuron (``[n_neurons]`` leaves)
        and per-source (``[n_sources]`` leaves).
      key: PRNG key — the identity of the virtual silicon; the same key
        always yields the same instance, on any chip count.
      cfg: mismatch magnitudes (default ``BSS2.reduced()``).

    Returns: the ``sample_instance`` dict with rows = sources and
      columns = neurons.
    """
    cfg = cfg or BSS2.reduced()
    scfg = dataclasses.replace(cfg, n_rows=max(spec.n_sources, 1),
                               n_cols=spec.n_neurons)
    return sample_instance(scfg, key, ())


def scatter_instance(mapping: ChipMapping, net_inst: dict,
                     cfg: BSS2Config) -> dict:
    """Spec-shaped draws -> per-chip ``(K,)``-prefix instance planes.

    Neuron j's column parameters land at ``(col_chip[j], col_slot[j])``;
    source s's row parameters land on every driver row allocated for s
    (all replicas share them). Unmapped slots keep ideal values.
    """
    K = mapping.n_chips
    chip_cfg = dataclasses.replace(cfg, n_rows=mapping.chip_rows,
                                   n_cols=mapping.chip_cols)
    base = jax.tree.map(np.array, ideal_instance(chip_cfg, (K,)))
    part = mapping.part
    ks, rs = np.nonzero(mapping.row_source >= 0)
    srcs = mapping.row_source[ks, rs]

    def cols(dst, src):
        dst[part.col_chip, part.col_slot] = np.asarray(src)
        return dst

    def rows(dst, src):
        dst[ks, rs] = np.asarray(src)[srcs]
        return dst

    out = dict(
        neuron_params={k: cols(base["neuron_params"][k], v)
                       for k, v in net_inst["neuron_params"].items()},
        weight_gain=cols(base["weight_gain"], net_inst["weight_gain"]),
        stp_offset=rows(base["stp_offset"], net_inst["stp_offset"]),
        stp_calib=rows(base["stp_calib"], net_inst["stp_calib"]),
        cadc_offset=cols(base["cadc_offset"], net_inst["cadc_offset"]),
        cadc_gain=cols(base["cadc_gain"], net_inst["cadc_gain"]))
    return jax.tree.map(jnp.asarray, out)


def place_inputs(mapping: ChipMapping, ev_in):
    """[..., T, n_in] external event trains -> ([..., T, K, R] events,
    [..., T, K, R] int8 addresses) ready for ``run_windows``.

    Every driver row's address plane is its schedule address — constant
    per row, so the merged (external | routed) stream satisfies the
    ``const_addr`` promise.
    """
    ev_in = np.asarray(ev_in, np.float32)
    K, R = mapping.n_chips, mapping.chip_rows
    lead = ev_in.shape[:-1]
    ev = np.zeros((*lead, K, R), np.float32)
    rows = mapping.input_rows()
    if rows:
        ks = np.asarray([k for k, _, _ in rows])
        rs = np.asarray([r for _, r, _ in rows])
        ss = np.asarray([s for _, _, s in rows])
        ev[..., ks, rs] = ev_in[..., ss]
    ad = np.broadcast_to(mapping.row_addr.astype(np.int8), ev.shape)
    return jnp.asarray(ev), jnp.asarray(np.ascontiguousarray(ad))


def gather_spikes(mapping: ChipMapping, spikes):
    """[..., K, C] per-chip output planes -> [..., n_neurons] spec-order
    spike trains (drops unused columns)."""
    part = mapping.part
    return spikes[..., part.col_chip, part.col_slot]


@dataclass
class MappedRuntime:
    """A ``ChipMapping`` bound to executable machinery.

    ``core`` is the K-chip ``AnnCore`` fleet (instance prefix ``(K,)``),
    ``router`` the plan's ``InterChipRouter``; ``net_inst`` the
    spec-shaped mismatch draw the per-chip ``inst`` was scattered from
    (reuse it to build the monolithic reference of the SAME silicon).
    """
    mapping: ChipMapping
    chip_cfg: BSS2Config
    core: AnnCore
    router: InterChipRouter
    net_inst: dict
    inst: dict

    def init_state(self):
        """Fleet state with the mapped weight/address planes loaded."""
        st = self.core.init_state((self.mapping.n_chips,))
        return st._replace(syn=st.syn._replace(
            weights=jnp.asarray(self.mapping.weights),
            addresses=jnp.asarray(self.mapping.addresses)))

    def run(self, ev_in, telemetry=None, state=None):
        """Emulate W windows of a [W, T, n_in] external stimulus.

        Returns ``(state, out)`` where ``out["spikes"]`` is the
        [W, T, n_neurons] spec-order spike record (``out["chip_spikes"]``
        keeps the raw [W, T, K, C] planes; routed grid and telemetry as
        in ``run_windows``).
        """
        ev, ad = place_inputs(self.mapping, ev_in)
        if state is None:
            state = self.init_state()
        if telemetry is None and self.core.telemetry:
            # init before the scan: the carry structure must be fixed,
            # so the core's lazy auto-init inside the body cannot apply
            from repro.obs import trace as obs_trace
            telemetry = obs_trace.init_telemetry()
        state, out = jax.jit(
            lambda s, e, a: run_windows(self.core, self.router, s, e, a,
                                        telemetry=telemetry))(state, ev, ad)
        out["chip_spikes"] = out["spikes"]
        out["spikes"] = gather_spikes(self.mapping, out["chip_spikes"])
        return state, out


def build_runtime(mapping: ChipMapping, cfg: Optional[BSS2Config] = None,
                  instance_key=None, net_inst: Optional[dict] = None,
                  backend: str = "fused", kernel_impl: str = "auto",
                  const_addr: bool = True, sparse_mode: Optional[str] = None,
                  ctx=None, link_budget: Optional[int] = None,
                  link_mode: str = "auto", faults=None,
                  telemetry: bool = False) -> MappedRuntime:
    """Bind a ``ChipMapping`` to an ``AnnCore`` fleet + router.

    Args:
      mapping: the compiled placement (``map_network``).
      cfg: base chip config (default ``BSS2.reduced()``); its row/column
        counts are replaced by the mapping's chip geometry.
      instance_key: PRNG key for the spec-shaped mismatch draw (default
        ``PRNGKey(7)``); ignored when ``net_inst`` is given.
      net_inst: a ``sample_network_instance`` result to reuse — pass the
        SAME draw to the K-chip and monolithic runtimes to emulate the
        same virtual silicon on both.
      backend / kernel_impl / sparse_mode / telemetry: forwarded to
        ``AnnCore`` (see its docstring).
      const_addr: the mapper's address schedule stores one address per
        driver row, so the fused path may resolve the address-match mask
        once per window — on by default.
      ctx / link_budget / link_mode / faults: forwarded to
        ``InterChipRouter``.

    Returns: a ``MappedRuntime``.
    """
    cfg = cfg or BSS2.reduced()
    chip_cfg = dataclasses.replace(cfg, n_rows=mapping.chip_rows,
                                   n_cols=mapping.chip_cols)
    if net_inst is None:
        if instance_key is None:
            instance_key = jax.random.PRNGKey(7)
        net_inst = sample_network_instance(mapping.spec, instance_key, cfg)
    inst = scatter_instance(mapping, net_inst, cfg)
    kw = {} if sparse_mode is None else {"sparse_mode": sparse_mode}
    core = AnnCore(chip_cfg, inst, backend=backend, kernel_impl=kernel_impl,
                   const_addr=const_addr, telemetry=telemetry, faults=faults,
                   **kw)
    router = InterChipRouter(mapping.plan, ctx=ctx, link_budget=link_budget,
                             link_mode=link_mode, faults=faults)
    return MappedRuntime(mapping=mapping, chip_cfg=chip_cfg, core=core,
                         router=router, net_inst=net_inst, inst=inst)
