"""Declarative network descriptions for the automatic chip mapper.

A ``NetworkSpec`` is the host-side, hardware-agnostic statement of WHAT
to emulate: ``n_in`` external input channels and ``n_neurons`` neurons,
connected by signed integer weights in the 6-bit range the synapse
circuit can store (|w| <= 63).  It says nothing about chips, rows,
columns, addresses, or links — that is the mapper's job
(``repro.mapper.mapping.map_network``).

Sources
-------
Rows of the synapse array are driven by *sources*.  The spec numbers
them canonically:

  source s in [0, n_in)                 external input channel s
  source s in [n_in, n_in + n_neurons)  neuron s - n_in (recurrence)

This canonical order is load-bearing: the mapper allocates driver rows
in ascending source order on every chip, which keeps the per-column FMA
chains of the partitioned and monolithic emulations term-for-term
aligned — the root of the bit-exactness contract (see
``docs/exactness.md`` and ``tests/test_mapper.py``).

Sign structure
--------------
The silicon stores unsigned 6-bit weights; sign comes from Dale row
pairing (even driver rows are excitatory, odd rows inhibitory — see
``repro.core.anncore.AnnCore.step``).  A spec therefore admits arbitrary
per-edge signs: a source whose fan-out onto one chip mixes signs simply
costs that chip two driver rows instead of one.  ``dale_signs`` reports
which sources are single-signed (true Dale sources) — networks built
from those map 1 row per (source, chip).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

WMAX = 63  # 6-bit synapse weight magnitude


@dataclass(frozen=True)
class NetworkSpec:
    """An arbitrary-topology network at the spec level.

    Args:
      n_in: external input channels (events enter here).
      n_neurons: neurons; their spikes may feed back through ``w_rec``.
      w_in: ``[n_in, n_neurons]`` int, signed weights in [-63, 63];
        input i -> neuron j.
      w_rec: ``[n_neurons, n_neurons]`` int, signed recurrent weights;
        neuron i -> neuron j.  Defaults to no recurrence.  Recurrent
        edges are delivered over the (emulated) inter-chip event bus and
        therefore arrive ONE WINDOW after the spike that caused them —
        on every chip count, including the single-chip monolithic
        execution, which is what makes partitioning exact (see
        ``docs/mapper.md``).

    Contract test: ``tests/test_mapper.py::TestSpec``.
    """
    n_in: int
    n_neurons: int
    w_in: np.ndarray
    w_rec: Optional[np.ndarray] = None
    name: str = "net"

    def __post_init__(self):
        assert self.n_in >= 0 and self.n_neurons >= 1
        w_in = np.asarray(self.w_in)
        assert w_in.shape == (self.n_in, self.n_neurons), \
            f"w_in shape {w_in.shape} != {(self.n_in, self.n_neurons)}"
        w_rec = (np.zeros((self.n_neurons, self.n_neurons), np.int32)
                 if self.w_rec is None else np.asarray(self.w_rec))
        assert w_rec.shape == (self.n_neurons, self.n_neurons), \
            f"w_rec shape {w_rec.shape} != 2x{self.n_neurons}"
        for nm, w in (("w_in", w_in), ("w_rec", w_rec)):
            assert np.issubdtype(w.dtype, np.integer), \
                f"{nm} must be integer (6-bit synapse weights)"
            assert np.abs(w).max(initial=0) <= WMAX, \
                f"{nm} exceeds the 6-bit magnitude {WMAX}"
        object.__setattr__(self, "w_in", w_in.astype(np.int32))
        object.__setattr__(self, "w_rec", w_rec.astype(np.int32))

    # -- canonical source numbering ---------------------------------------
    @property
    def n_sources(self) -> int:
        return self.n_in + self.n_neurons

    def w_full(self) -> np.ndarray:
        """[n_sources, n_neurons] signed weights in canonical source
        order (inputs first, then neurons)."""
        return np.concatenate([self.w_in, self.w_rec], axis=0)

    def source_is_input(self, s: int) -> bool:
        return s < self.n_in

    # -- structure queries --------------------------------------------------
    def dale_signs(self) -> np.ndarray:
        """[n_sources] int8: +1 purely excitatory, -1 purely inhibitory,
        0 mixed-sign (costs two driver rows per chip it reaches)."""
        w = self.w_full()
        has_p = (w > 0).any(axis=1)
        has_n = (w < 0).any(axis=1)
        return np.where(has_p & ~has_n, 1,
                        np.where(has_n & ~has_p, -1, 0)).astype(np.int8)

    def fan_in(self) -> np.ndarray:
        """[n_neurons] number of nonzero incoming edges per neuron."""
        return (self.w_full() != 0).sum(axis=0)

    def fan_out(self) -> np.ndarray:
        """[n_sources] number of nonzero outgoing edges per source."""
        return (self.w_full() != 0).sum(axis=1)

    @property
    def n_edges(self) -> int:
        return int((self.w_full() != 0).sum())


def random_spec(rng: np.random.Generator, n_in: int, n_neurons: int,
                fan_out: int = 4, rec_fan_out: int = 0,
                p_inh: float = 0.3, dale: bool = True,
                rec_mask: Optional[np.ndarray] = None,
                name: str = "random") -> NetworkSpec:
    """Random bounded-fan-out network for tests and benches.

    Args:
      rng: host RNG (the spec is host data; reproducible by seed).
      fan_out: nonzero targets per external input.
      rec_fan_out: nonzero targets per neuron (0 = feed-forward).
      p_inh: fraction of inhibitory sources (``dale=True``) or of
        inhibitory edges (``dale=False`` — mixed-sign sources appear).
      rec_mask: optional ``[n_neurons, n_neurons]`` bool of ALLOWED
        recurrent edges (e.g. a ring-adjacency block structure so the
        spec maps onto a ring topology — see ``docs/mapper.md``).

    Returns: a validated ``NetworkSpec``.
    """
    def draw(n_src, w, k, allowed=None):
        for i in range(n_src):
            cols = (np.nonzero(allowed[i])[0] if allowed is not None
                    else np.arange(n_neurons))
            if cols.size == 0 or k == 0:
                continue
            pick = rng.choice(cols, size=min(k, cols.size), replace=False)
            mag = rng.integers(1, WMAX + 1, size=pick.size)
            if dale:
                sign = -1 if rng.random() < p_inh else 1
                w[i, pick] = sign * mag
            else:
                sign = np.where(rng.random(pick.size) < p_inh, -1, 1)
                w[i, pick] = sign * mag

    w_in = np.zeros((n_in, n_neurons), np.int32)
    draw(n_in, w_in, fan_out)
    w_rec = np.zeros((n_neurons, n_neurons), np.int32)
    if rec_fan_out:
        draw(n_neurons, w_rec, rec_fan_out, allowed=rec_mask)
    return NetworkSpec(n_in=n_in, n_neurons=n_neurons, w_in=w_in,
                       w_rec=w_rec, name=name)
