"""Column partitioning: tile a NetworkSpec's neurons onto K chips.

The neuron (column) side of the mapping problem.  Each logical chip has
``chip_cols`` neuron circuits; a ``Blacklist`` (from
``repro.faults.screen``) may mark some of them unusable.  The
partitioner assigns every spec neuron a ``(chip, column-slot)`` in
ascending neuron order, contiguous blocks per chip, balanced over the
chips' *usable* capacity — so a defect-heavy chip automatically takes a
smaller share (the paper's commissioning story made automatic).

Row capacity is NOT decided here: how many driver rows a chip needs
depends on which sources fan into the neurons placed on it, which is
resolved by ``repro.mapper.mapping.map_network`` after the column split.

Contract tests: ``tests/test_mapper.py::TestPartition``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


class CapacityError(ValueError):
    """The network does not fit the requested chips (columns or rows).

    Raised — never silently truncated — in the house never-silent style.
    The message names the chip and the demand/capacity pair.
    """


@dataclass(frozen=True)
class ColumnPartition:
    """Result of ``partition_columns``.

    Attributes:
      col_chip: ``[n_neurons]`` int32, owning chip per spec neuron.
      col_slot: ``[n_neurons]`` int32, physical column on that chip.
      n_chips: K.
      chip_cols: physical columns per chip (C).
    """
    col_chip: np.ndarray
    col_slot: np.ndarray
    n_chips: int
    chip_cols: int

    def chip_neurons(self, k: int) -> np.ndarray:
        """Spec-neuron ids placed on chip ``k`` (ascending)."""
        return np.nonzero(self.col_chip == k)[0]

    def used_mask(self) -> np.ndarray:
        """[K, C] bool — columns that carry a spec neuron."""
        m = np.zeros((self.n_chips, self.chip_cols), bool)
        m[self.col_chip, self.col_slot] = True
        return m


def partition_columns(n_neurons: int, n_chips: int, chip_cols: int,
                      bad_neurons: Optional[np.ndarray] = None,
                      ) -> ColumnPartition:
    """Balanced contiguous split of ``n_neurons`` over ``n_chips``.

    Args:
      n_neurons: spec neurons to place.
      n_chips: K logical chips.
      chip_cols: physical neuron columns per chip.
      bad_neurons: optional ``[n_chips, chip_cols]`` bool — screened-out
        neuron circuits (``Blacklist.neurons``); those slots are skipped.

    Returns: a ``ColumnPartition`` (neurons in ascending order, chip 0
      first; slots are the lowest usable column indices on each chip).

    Raises:
      CapacityError: total usable columns < ``n_neurons``.

    Balancing: chip ``k`` receives ``ceil(remaining / chips_left)``
    neurons, clamped to its usable capacity, so defect-free chips share
    the load evenly and defective chips shed theirs to later chips.
    """
    if bad_neurons is None:
        bad = np.zeros((n_chips, chip_cols), bool)
    else:
        bad = np.asarray(bad_neurons, bool)
        assert bad.shape == (n_chips, chip_cols), \
            f"bad_neurons shape {bad.shape} != {(n_chips, chip_cols)}"
    usable = [np.nonzero(~bad[k])[0] for k in range(n_chips)]
    total = sum(u.size for u in usable)
    if total < n_neurons:
        raise CapacityError(
            f"{n_neurons} neurons > {total} usable columns on "
            f"{n_chips} chip(s) x {chip_cols} cols "
            f"({int(bad.sum())} blacklisted)")

    col_chip = np.empty(n_neurons, np.int32)
    col_slot = np.empty(n_neurons, np.int32)
    nxt = 0
    for k in range(n_chips):
        remaining = n_neurons - nxt
        chips_left = n_chips - k
        want = -(-remaining // chips_left)  # ceil
        take = min(want, usable[k].size)
        if take:
            col_chip[nxt:nxt + take] = k
            col_slot[nxt:nxt + take] = usable[k][:take]
            nxt += take
    if nxt < n_neurons:
        # Balanced quotas under-filled early chips while later ones were
        # defect-starved; greedily top up in a second pass.
        filled = np.zeros((n_chips, chip_cols), bool)
        filled[col_chip[:nxt], col_slot[:nxt]] = True
        for k in range(n_chips):
            free = np.nonzero(~bad[k] & ~filled[k])[0]
            take = min(n_neurons - nxt, free.size)
            if take:
                col_chip[nxt:nxt + take] = k
                col_slot[nxt:nxt + take] = free[:take]
                nxt += take
            if nxt == n_neurons:
                break
    assert nxt == n_neurons
    # Re-sort so ascending neuron id keeps ascending (chip, slot): the
    # top-up pass can interleave chips out of order.
    order = np.lexsort((col_slot, col_chip))
    return ColumnPartition(col_chip=col_chip[order].copy(),
                           col_slot=col_slot[order].copy(),
                           n_chips=n_chips, chip_cols=chip_cols)
