from repro.kernels.neuron_scan.ops import neuron_window  # noqa: F401
