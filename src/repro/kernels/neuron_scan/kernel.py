"""Pallas kernel: time-blocked VMEM-resident AdEx neuron scan.

The fused emulation backend leaves ONE per-dt ``lax.scan`` in the trial:
the neuron-state update, an O(C) body paying XLA while-loop overhead per
dt. The AdEx array itself integrates a whole time window on-chip without
round-trips (Aamir et al., arXiv:1804.01906); this kernel is the TPU
analogue — one grid step integrates a whole **time block**:

  * neuron state (v, w, adaptation current, refractory counters, synaptic
    current states, rate counters) lives in a VMEM scratch buffer that
    persists across the (sequential, innermost) time-block grid axis — it
    is read from HBM once per trial and written back once;
  * the pre-fused per-dt synaptic currents stream in as [block, cb]
    slabs, spikes (and optional voltage records) stream out per block;
  * a leading **instance grid axis** maps a fleet of independent chip
    instances onto the grid — one kernel launch per trial, no vmap fold
    (``repro.parallel.sharding.Ax.INSTANCE`` shards the same axis over
    the mesh's data dims).

The per-step math is ``repro.core.adex.integrate_currents`` +
``membrane_step`` — the same op trees as the oracle scan, called per
unrolled step inside the kernel, so the executors cannot fork
semantically (cf. how the PPU-VM executors share ``make_branches``).

State/param packing (rows of the [*, cb] tiles):
  state  [N, 6, C]: v, w, i_exc, i_inh, refrac, rate_counters
  params [N, 12, C]: e_leak, v_thres, delta_t, g_leak, a, b, e_reset,
                     tau_refrac, de, di, alpha, aw
A trailing partial block (T not a multiple of the block size) is handled
in-kernel: padded steps are masked out of the state update and emit no
spikes, so any T is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import adex

PARAM_ROWS = ("e_leak", "v_thres", "delta_t", "g_leak", "a", "b",
              "e_reset", "tau_refrac")
DECAY_ROWS = ("de", "di", "alpha", "aw")


def _kernel(ie_ref, ii_ref, st_ref, par_ref, spk_ref, stout_ref, *rest,
            dt: float, use_adex: bool, T: int, blk: int, record_v: bool):
    vrec_ref = rest[0] if record_v else None
    scr = rest[-1]
    b_idx = pl.program_id(2)
    nblk = pl.num_programs(2)

    @pl.when(b_idx == 0)
    def _init():
        scr[...] = st_ref[0]

    par = par_ref[0]                                    # [12, cb]
    params = {k: par[i] for i, k in enumerate(PARAM_ROWS)}
    decays = {k: par[len(PARAM_ROWS) + i] for i, k in enumerate(DECAY_ROWS)}

    v, w, i_exc, i_inh, refrac, rc = (scr[i] for i in range(6))
    padded = T % blk != 0                               # static
    for t in range(blk):                                # static unroll
        i_exc2, i_inh2 = adex.integrate_currents(
            i_exc, i_inh, ie_ref[0, t], ii_ref[0, t], decays)
        v2, w2, refrac2, out = adex.membrane_step(
            v, w, refrac, i_exc2 - i_inh2, params, dt, adex=use_adex,
            decays=decays)
        if padded:                                      # mask tail steps
            valid = (b_idx * blk + t) < T
            v = jnp.where(valid, v2, v)
            w = jnp.where(valid, w2, w)
            refrac = jnp.where(valid, refrac2, refrac)
            i_exc = jnp.where(valid, i_exc2, i_exc)
            i_inh = jnp.where(valid, i_inh2, i_inh)
            out = jnp.where(valid, out, 0.0)
        else:
            v, w, refrac, i_exc, i_inh = v2, w2, refrac2, i_exc2, i_inh2
        rc = rc + out
        spk_ref[0, t] = out
        if record_v:
            vrec_ref[0, t] = v

    scr[...] = jnp.stack([v, w, i_exc, i_inh, refrac, rc])

    @pl.when(b_idx == nblk - 1)
    def _flush():
        stout_ref[0] = scr[...]


@functools.partial(jax.jit, static_argnames=("dt", "use_adex", "T", "blk",
                                             "cb", "record_v", "interpret"))
def neuron_window_pallas(ie_t, ii_t, state6, params12, *, dt: float,
                         use_adex: bool, T: int, blk: int = 32,
                         cb: int = 128, record_v: bool = False,
                         interpret: bool = False):
    """ie_t/ii_t: [N, T_pad, C] f32 (T_pad = ceil(T/blk)*blk, zero-padded);
    state6: [N, 6, C] f32; params12: [N, 12, C] f32.

    Returns (spikes [N, T_pad, C], state_out [N, 6, C][, v_rec]) — the
    caller slices records back to [.., :T].
    """
    N, T_pad, C = ie_t.shape
    assert T_pad % blk == 0 and T_pad - blk < T <= T_pad, (T, T_pad, blk)
    cb = min(cb, C)
    assert C % cb == 0, (C, cb)
    grid = (N, C // cb, T_pad // blk)

    drive_spec = pl.BlockSpec((1, blk, cb), lambda n, c, b: (n, b, c))
    state_spec = pl.BlockSpec((1, 6, cb), lambda n, c, b: (n, 0, c))
    par_spec = pl.BlockSpec((1, 12, cb), lambda n, c, b: (n, 0, c))
    out_specs = [drive_spec, state_spec]
    out_shape = [jax.ShapeDtypeStruct((N, T_pad, C), jnp.float32),
                 jax.ShapeDtypeStruct((N, 6, C), jnp.float32)]
    if record_v:
        out_specs.append(drive_spec)
        out_shape.append(jax.ShapeDtypeStruct((N, T_pad, C), jnp.float32))
    out = pl.pallas_call(
        functools.partial(_kernel, dt=dt, use_adex=use_adex, T=T, blk=blk,
                          record_v=record_v),
        grid=grid,
        in_specs=[drive_spec, drive_spec, state_spec, par_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((6, cb), jnp.float32)],
        interpret=interpret,
    )(ie_t, ii_t, state6, params12)
    return tuple(out)
