"""Blocked jnp reference for the time-blocked neuron scan.

Semantics source: ``repro.core.adex`` — ``integrate_currents`` and
``membrane_step`` are the exact op trees the per-dt oracle computes, so
this restructuring is bit-identical to scanning ``adex.step``; it only
changes WHICH XLA program computes them:

  1. The synaptic-current states (i_exc, i_inh) never read the membrane
     state, so their recurrence runs as a separate window-wide scan with a
     2-row packed carry and a tiny body (``trace_block`` steps unrolled
     per iteration).
  2. The sequential membrane core scans over *time blocks* instead of
     dts: the carry is ONE packed [3, ..., C] array (v, w, refrac) — a
     multi-array scan carry is the dominant per-iteration cost of the
     XLA:CPU while loop — and each iteration advances ``block`` dt steps
     of straight-line code, emitting a [block, ..., C] spike slab.
  3. Rate counters leave the loop entirely: spikes are {0,1} floats, so
     integer-valued f32 sums are exact in any order and
     ``rc + spikes.sum(0)`` is bit-identical to the per-step ``rc + out``
     chain.

A trailing remainder (T not divisible by the block size) runs through the
same per-step functions after the main blocked scan, so any T is exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import adex


def _trace_window(i_exc0, i_inh0, ie_t, ii_t, decays, blk: int):
    """Whole-window net drive ``i_exc - i_inh`` [T, ..., C] plus the final
    current states (exact sequential order, blocked into ``blk``-step
    slabs). The per-step subtraction is the op ``step`` computed inline —
    emitting it directly avoids materialising the [T, 2, ..., C] pair."""
    T = ie_t.shape[0]
    bshape = jnp.broadcast_shapes(i_exc0.shape, i_inh0.shape)
    x0 = jnp.stack([jnp.broadcast_to(i_exc0, bshape).astype(jnp.float32),
                    jnp.broadcast_to(i_inh0, bshape).astype(jnp.float32)])
    dedi = jnp.stack([jnp.broadcast_to(decays["de"], bshape),
                      jnp.broadcast_to(decays["di"], bshape)])
    inj = jnp.stack([ie_t, ii_t], axis=1)              # [T, 2, ..., C]

    def steps(x, u, n):
        outs = []
        for t in range(n):
            x = x * dedi + u[t]
            outs.append(x[0] - x[1])
        return x, jnp.stack(outs)

    n_main, tail = divmod(T, blk)
    tr_main = None
    if n_main:
        def body(x, u):
            return steps(x, u, blk)
        x0, tr_main = jax.lax.scan(body, x0, inj[:n_main * blk]
                                   .reshape(n_main, blk, *inj.shape[1:]))
        tr_main = tr_main.reshape(n_main * blk, *tr_main.shape[2:])
    if tail:
        x0, tr_tail = steps(x0, inj[n_main * blk:], tail)
        tr_main = (tr_tail if tr_main is None
                   else jnp.concatenate([tr_main, tr_tail]))
    return x0[0], x0[1], tr_main


def neuron_window_ref(state: adex.NeuronState, rate_counters, ie_t, ii_t,
                      params, *, dt: float, use_adex: bool, decays,
                      block: int = 8, trace_block: int = 8,
                      record_v: bool = False):
    """Integrate a [T, ..., C] current window. Same contract as scanning
    ``adex.step``: returns ``(new_state, rate_counters, outputs)`` with
    ``outputs = (spikes_t,)`` or ``(spikes_t, v_t)``."""
    T = ie_t.shape[0]
    i_exc_f, i_inh_f, i_drive = _trace_window(
        state.i_exc, state.i_inh, ie_t, ii_t, decays, trace_block)

    bshape = jnp.broadcast_shapes(state.v.shape, state.w.shape,
                                  state.refrac.shape)
    p0 = jnp.stack([jnp.broadcast_to(state.v, bshape),
                    jnp.broadcast_to(state.w, bshape),
                    jnp.broadcast_to(state.refrac, bshape)])

    def steps(p, d, n):
        v, w, refrac = p[0], p[1], p[2]
        spk, vs = [], []
        for t in range(n):
            v, w, refrac, out = adex.membrane_step(
                v, w, refrac, d[t], params, dt, adex=use_adex,
                decays=decays)
            spk.append(out)
            if record_v:
                vs.append(v)
        recs = (jnp.stack(spk),) + ((jnp.stack(vs),) if record_v else ())
        return jnp.stack([v, w, refrac]), recs

    n_main, tail = divmod(T, block)
    recs = None
    if n_main:
        def body(p, d):
            return steps(p, d, block)
        p0, recs = jax.lax.scan(
            body, p0, i_drive[:n_main * block]
            .reshape(n_main, block, *i_drive.shape[1:]))
        recs = tuple(r.reshape(n_main * block, *r.shape[2:]) for r in recs)
    if tail:
        p0, recs_tail = steps(p0, i_drive[n_main * block:], tail)
        recs = (recs_tail if recs is None else
                tuple(jnp.concatenate([a, b])
                      for a, b in zip(recs, recs_tail)))

    spikes_t = recs[0]
    new_state = adex.NeuronState(v=p0[0], w=p0[1], i_exc=i_exc_f,
                                 i_inh=i_inh_f, refrac=p0[2])
    return new_state, rate_counters + spikes_t.sum(0), recs
