"""Jit'd public wrapper for the time-blocked neuron scan.

``neuron_window`` integrates a whole [T, ..., C] synaptic-current window
of AdEx dynamics in one call. ``impl`` follows the kernel-wrapper
convention (auto | pallas | interpret | ref): the ref is the blocked jnp
restructuring (``ref.py``), the Pallas kernel keeps the state VMEM-
resident across time blocks with instances on a real grid axis
(``kernel.py``). Both consume the exact ``repro.core.adex`` step op
trees, so all impls (and the per-dt oracle scan) are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import adex
from repro.kernels import fold_instance, fold_instance_time, \
    unfold_instance_time
from repro.kernels.neuron_scan.kernel import DECAY_ROWS, PARAM_ROWS, \
    neuron_window_pallas
from repro.kernels.neuron_scan.ref import neuron_window_ref

_ref_jit = jax.jit(neuron_window_ref,
                   static_argnames=("dt", "use_adex", "block",
                                    "trace_block", "record_v"))


def neuron_window(state: adex.NeuronState, rate_counters, ie_t, ii_t,
                  params, *, dt: float, use_adex: bool, decays=None,
                  impl: str = "auto", block: int = 8,
                  trace_block: int = 8, kernel_block: int = 32,
                  record_v: bool = False):
    """ie_t/ii_t: [T, ..., C] f32 net currents; state/params broadcast over
    the instance prefix. Returns ``(new_state, rate_counters, recs)`` with
    ``recs = (spikes_t,)`` or ``(spikes_t, v_t)`` — the same contract as
    scanning ``adex.step`` over the window.

    ``block``/``trace_block`` size the ref path's membrane / current-trace
    scan slabs (CPU-tuned: small blocks keep the XLA:CPU loop body in
    cache); ``kernel_block`` sizes the Pallas kernel's VMEM-resident time
    block (bigger is better on TPU — fewer grid steps, state stays
    on-chip either way)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if decays is None:
        decays = adex.decay_factors(params, dt)
    if impl == "ref":
        return _ref_jit(state, rate_counters, ie_t, ii_t, params, dt=dt,
                        use_adex=use_adex, decays=decays, block=block,
                        trace_block=trace_block, record_v=record_v)

    T = ie_t.shape[0]
    C = ie_t.shape[-1]
    prefix = ie_t.shape[1:-1]
    blk = min(kernel_block, T)
    pad = (-T) % blk
    cshape = (*prefix, C)
    rc = jnp.broadcast_to(rate_counters, cshape).astype(jnp.float32)
    state6 = fold_instance(jnp.stack(
        [jnp.broadcast_to(getattr(state, f), cshape).astype(jnp.float32)
         for f in ("v", "w", "i_exc", "i_inh", "refrac")] + [rc],
        axis=len(prefix)), 2)
    rows = [params[k] for k in PARAM_ROWS] + [decays[k] for k in DECAY_ROWS]
    params12 = fold_instance(jnp.stack(
        [jnp.broadcast_to(r, cshape).astype(jnp.float32) for r in rows],
        axis=len(prefix)), 2)
    ie_p = jnp.pad(ie_t.astype(jnp.float32), [(0, pad)] + [(0, 0)] * (
        ie_t.ndim - 1))
    ii_p = jnp.pad(ii_t.astype(jnp.float32), [(0, pad)] + [(0, 0)] * (
        ii_t.ndim - 1))
    out = neuron_window_pallas(
        fold_instance_time(ie_p, 1), fold_instance_time(ii_p, 1), state6,
        params12, dt=dt, use_adex=use_adex, T=T, blk=blk,
        record_v=record_v, interpret=(impl == "interpret"))
    spikes = unfold_instance_time(out[0], prefix)[:T]
    st6 = out[1].reshape(*prefix, 6, C)
    idx = functools.partial(jnp.take, st6, axis=len(prefix))
    new_state = adex.NeuronState(v=idx(0), w=idx(1), i_exc=idx(2),
                                 i_inh=idx(3), refrac=idx(4))
    recs = (spikes,)
    if record_v:
        recs = (spikes, unfold_instance_time(out[2], prefix)[:T])
    return new_state, idx(5), recs
