"""Pallas kernel: fused T-step correlation-sensor window.

Integrates T timesteps of the causal/anti-causal accumulation for one
synapse tile without leaving VMEM:

    tp[t] = lam * tp[t-1] + pre[t]         (presynaptic trace, per row)
    tq[t] = lam * tq[t-1] + post[t]        (postsynaptic trace, per col)
    a_c  += tp[t] (outer) post[t]          (saturating)
    a_a  += pre[t] (outer) tq[t]           (saturating)

Hardware adaptation (DESIGN.md): the analog sensor does this "for free" on
capacitors; the naive digital port re-reads the [R, C] accumulators from
HBM every step. The TPU-native version tiles [R, C] into VMEM blocks and
replays the whole T-window per tile — T x fewer HBM round trips; the spike
vectors ([T, rb] + [T, cb]) are tiny. The in-kernel loop preserves per-step
saturation semantics exactly (a post-hoc matmul over time would not).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pre_ref, post_ref, tp0_ref, tq0_ref, ac0_ref, aa0_ref,
            ac_ref, aa_ref, tp_ref, tq_ref, *, lam: float, sat: float):
    pre = pre_ref[0].astype(jnp.float32)       # [T, rb]
    post = post_ref[0].astype(jnp.float32)     # [T, cb]
    T = pre.shape[0]

    def body(t, carry):
        tp, tq, ac, aa = carry
        p_t = pre[t]
        q_t = post[t]
        tp = tp * lam + p_t
        tq = tq * lam + q_t
        ac = jnp.minimum(ac + tp[:, None] * q_t[None, :], sat)
        aa = jnp.minimum(aa + p_t[:, None] * tq[None, :], sat)
        return tp, tq, ac, aa

    tp0 = tp0_ref[0].astype(jnp.float32)[0]
    tq0 = tq0_ref[0].astype(jnp.float32)[0]
    ac0 = ac0_ref[0].astype(jnp.float32)
    aa0 = aa0_ref[0].astype(jnp.float32)
    tp, tq, ac, aa = jax.lax.fori_loop(0, T, body, (tp0, tq0, ac0, aa0))
    ac_ref[0] = ac
    aa_ref[0] = aa
    tp_ref[0] = tp[None]
    tq_ref[0] = tq[None]


@functools.partial(jax.jit,
                   static_argnames=("lam", "sat", "rb", "cb", "interpret"))
def correlation_window_pallas(pre, post, tp0, tq0, ac0, aa0, *,
                              lam: float, sat: float = 1023.0,
                              rb: int = 64, cb: int = 128,
                              interpret: bool = False):
    """pre: [N, T, R]; post: [N, T, C]; tp0 [N, R]; tq0 [N, C]; ac0/aa0
    [N, R, C] — the leading N is the instance grid axis (see
    ``repro.kernels``); operands without it are promoted to N=1.

    Returns (a_causal, a_acausal, tp_final, tq_final).
    """
    squeeze = pre.ndim == 2
    if squeeze:
        pre, post, tp0, tq0 = pre[None], post[None], tp0[None], tq0[None]
        ac0, aa0 = ac0[None], aa0[None]
    N, T, R = pre.shape
    C = post.shape[-1]
    rb = min(rb, R)
    cb = min(cb, C)
    assert R % rb == 0 and C % cb == 0
    grid = (N, R // rb, C // cb)
    acc_spec = pl.BlockSpec((1, rb, cb), lambda n, i, j: (n, i, j))
    row_spec = pl.BlockSpec((1, 1, rb), lambda n, i, j: (n, 0, i))
    col_spec = pl.BlockSpec((1, 1, cb), lambda n, i, j: (n, 0, j))
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, sat=sat),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, rb), lambda n, i, j: (n, 0, i)),
            pl.BlockSpec((1, T, cb), lambda n, i, j: (n, 0, j)),
            row_spec, col_spec, acc_spec, acc_spec,
        ],
        out_specs=[acc_spec, acc_spec, row_spec, col_spec],
        out_shape=[
            jax.ShapeDtypeStruct((N, R, C), jnp.float32),
            jax.ShapeDtypeStruct((N, R, C), jnp.float32),
            jax.ShapeDtypeStruct((N, 1, R), jnp.float32),
            jax.ShapeDtypeStruct((N, 1, C), jnp.float32),
        ],
        interpret=interpret,
    )(pre, post, tp0[:, None], tq0[:, None], ac0, aa0)
    ac, aa, tp, tq = out
    if squeeze:
        return ac[0], aa[0], tp[0, 0], tq[0, 0]
    return ac, aa, tp[:, 0], tq[:, 0]
