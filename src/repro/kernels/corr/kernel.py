"""Pallas kernel: fused T-step correlation-sensor window.

Integrates T timesteps of the causal/anti-causal accumulation for one
synapse tile without leaving VMEM:

    tp[t] = lam * tp[t-1] + pre[t]         (presynaptic trace, per row)
    tq[t] = lam * tq[t-1] + post[t]        (postsynaptic trace, per col)
    a_c  += tp[t] (outer) post[t]          (saturating)
    a_a  += pre[t] (outer) tq[t]           (saturating)

Hardware adaptation (DESIGN.md): the analog sensor does this "for free" on
capacitors; the naive digital port re-reads the [R, C] accumulators from
HBM every step. The TPU-native version tiles [R, C] into VMEM blocks and
replays the whole T-window per tile — T x fewer HBM round trips; the spike
vectors ([T, rb] + [T, cb]) are tiny. The in-kernel loop preserves per-step
saturation semantics exactly (a post-hoc matmul over time would not).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pre_ref, post_ref, tp0_ref, tq0_ref, ac0_ref, aa0_ref,
            ac_ref, aa_ref, tp_ref, tq_ref, *, lam: float, sat: float):
    pre = pre_ref[...].astype(jnp.float32)     # [T, rb]
    post = post_ref[...].astype(jnp.float32)   # [T, cb]
    T = pre.shape[0]

    def body(t, carry):
        tp, tq, ac, aa = carry
        p_t = pre[t]
        q_t = post[t]
        tp = tp * lam + p_t
        tq = tq * lam + q_t
        ac = jnp.minimum(ac + tp[:, None] * q_t[None, :], sat)
        aa = jnp.minimum(aa + p_t[:, None] * tq[None, :], sat)
        return tp, tq, ac, aa

    tp0 = tp0_ref[...].astype(jnp.float32)[0]
    tq0 = tq0_ref[...].astype(jnp.float32)[0]
    ac0 = ac0_ref[...].astype(jnp.float32)
    aa0 = aa0_ref[...].astype(jnp.float32)
    tp, tq, ac, aa = jax.lax.fori_loop(0, T, body, (tp0, tq0, ac0, aa0))
    ac_ref[...] = ac
    aa_ref[...] = aa
    tp_ref[...] = tp[None]
    tq_ref[...] = tq[None]


@functools.partial(jax.jit,
                   static_argnames=("lam", "sat", "rb", "cb", "interpret"))
def correlation_window_pallas(pre, post, tp0, tq0, ac0, aa0, *,
                              lam: float, sat: float = 1023.0,
                              rb: int = 64, cb: int = 128,
                              interpret: bool = False):
    """pre: [T, R]; post: [T, C]; tp0 [R]; tq0 [C]; ac0/aa0 [R, C].

    Returns (a_causal, a_acausal, tp_final, tq_final).
    """
    T, R = pre.shape
    C = post.shape[1]
    rb = min(rb, R)
    cb = min(cb, C)
    assert R % rb == 0 and C % cb == 0
    grid = (R // rb, C // cb)
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, sat=sat),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, rb), lambda i, j: (0, i)),
            pl.BlockSpec((T, cb), lambda i, j: (0, j)),
            pl.BlockSpec((1, rb), lambda i, j: (0, i)),
            pl.BlockSpec((1, cb), lambda i, j: (0, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((1, rb), lambda i, j: (0, i)),
            pl.BlockSpec((1, cb), lambda i, j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.float32),
            jax.ShapeDtypeStruct((R, C), jnp.float32),
            jax.ShapeDtypeStruct((1, R), jnp.float32),
            jax.ShapeDtypeStruct((1, C), jnp.float32),
        ],
        interpret=interpret,
    )(pre, post, tp0[None], tq0[None], ac0, aa0)
    ac, aa, tp, tq = out
    return ac, aa, tp[0], tq[0]
