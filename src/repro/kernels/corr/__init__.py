from repro.kernels.corr.ops import correlation_window  # noqa: F401
