"""Jit'd public wrapper for the fused correlation-window kernel.

Spike windows are time-major ([T, ..., R] / [T, ..., C]) like everywhere
in the emulation; an arbitrary instance prefix on the sensor state is
folded into the kernel's instance grid axis (one launch for the whole
fleet, see ``repro.kernels``). The ref path broadcasts natively.
"""
from __future__ import annotations

import jax

from repro.kernels import (fold_instance, fold_instance_time,
                           unfold_instance)
from repro.kernels.corr.kernel import correlation_window_pallas
from repro.kernels.corr.ref import correlation_window_ref

# jitted once at import — see synray/ops.py; lam/sat are static so each
# (lam, sat) pair compiles exactly once
_ref_jit = jax.jit(correlation_window_ref, static_argnames=("lam", "sat"))


def correlation_window(pre, post, tp0, tq0, ac0, aa0, *, lam, sat=1023.0,
                       impl: str = "auto", **block_kw):
    """pre: [T, ..., R]; post: [T, ..., C]; tp0 [..., R]; tq0 [..., C];
    ac0/aa0 [..., R, C]. Returns (a_causal, a_acausal, tp, tq)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_jit(pre, post, tp0, tq0, ac0, aa0, lam=lam, sat=sat)
    prefix = ac0.shape[:-2]
    ac, aa, tp, tq = correlation_window_pallas(
        fold_instance_time(pre, 1), fold_instance_time(post, 1),
        fold_instance(tp0, 1), fold_instance(tq0, 1),
        fold_instance(ac0, 2), fold_instance(aa0, 2),
        lam=lam, sat=sat, interpret=(impl == "interpret"), **block_kw)
    return (unfold_instance(ac, prefix), unfold_instance(aa, prefix),
            unfold_instance(tp, prefix), unfold_instance(tq, prefix))
