"""Jit'd public wrapper for the fused correlation-window kernel."""
from __future__ import annotations

import jax

from repro.kernels.corr.kernel import correlation_window_pallas
from repro.kernels.corr.ref import correlation_window_ref


def correlation_window(pre, post, tp0, tq0, ac0, aa0, *, lam, sat=1023.0,
                       impl: str = "auto", **block_kw):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return correlation_window_ref(pre, post, tp0, tq0, ac0, aa0,
                                      lam=lam, sat=sat)
    return correlation_window_pallas(pre, post, tp0, tq0, ac0, aa0, lam=lam,
                                     sat=sat, interpret=(impl == "interpret"),
                                     **block_kw)
