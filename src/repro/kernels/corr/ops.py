"""Jit'd public wrapper for the fused correlation-window kernel."""
from __future__ import annotations

import jax

from repro.kernels.corr.kernel import correlation_window_pallas
from repro.kernels.corr.ref import correlation_window_ref

# jitted once at import — see synray/ops.py; lam/sat are static so each
# (lam, sat) pair compiles exactly once
_ref_jit = jax.jit(correlation_window_ref, static_argnames=("lam", "sat"))


def correlation_window(pre, post, tp0, tq0, ac0, aa0, *, lam, sat=1023.0,
                       impl: str = "auto", **block_kw):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_jit(pre, post, tp0, tq0, ac0, aa0, lam=lam, sat=sat)
    return correlation_window_pallas(pre, post, tp0, tq0, ac0, aa0, lam=lam,
                                     sat=sat, interpret=(impl == "interpret"),
                                     **block_kw)
