"""Pure-jnp oracle for the corr kernel (scan over repro.core.correlation)."""
import jax
import jax.numpy as jnp

from repro.core.correlation import CorrelationState, update


def correlation_window_ref(pre, post, tp0, tq0, ac0, aa0, *, lam: float,
                           sat: float = 1023.0):
    """Same contract as correlation_window_pallas, via lax.scan over the
    core module's per-step update. lam = exp(-dt/tau)."""
    # recover (tau, dt) pair giving this lam: update() takes tau & dt
    dt = 1.0
    tau = -dt / jnp.log(lam)
    st = CorrelationState(trace_pre=tp0, trace_post=tq0,
                          a_causal=ac0, a_acausal=aa0)

    def body(s, x):
        p, q = x
        return update(s, p, q, tau_pre=tau, tau_post=tau, dt=dt, sat=sat), None

    st, _ = jax.lax.scan(body, st, (pre, post))
    return st.a_causal, st.a_acausal, st.trace_pre, st.trace_post
