"""Public wrapper for the Pallas tile-VM executor.

``run_program_tiled`` accepts the same operands as
``repro.ppuvm.interp.run_program_jax`` (arbitrary instance prefix,
broadcastable qc/qa/noise, float rate counters, optional mod/noise) and
routes the 2-D core through ``kernel.run_program_pallas``; a leading
instance prefix is folded by nested vmap like the other kernel wrappers.

Host-side preparation mirrors ``interp.prepare_operands`` bit-for-bit
(rate saturation, Q8.8 digitization conventions), so the kernel consumes
exactly the integers every other executor sees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ppuvm_exec.kernel import run_program_pallas
from repro.ppuvm import isa
from repro.ppuvm.interp import rates_to_fixed


def run_program_tiled(words, weights, qc, qa, rates, mod=None, noise=None,
                      *, rb: int = 64, cb: int = 128,
                      interpret: bool = False):
    """Same signature/returns as ``interp.run_program_jax``:
    (weights_out int32 [..., R, C], regs int32 [N_REGS, ..., R, C])."""
    lane_shape = weights.shape
    words = jnp.asarray(words, jnp.int32)
    weights = weights.astype(jnp.int32)
    qc = jnp.broadcast_to(qc, lane_shape).astype(jnp.int32)
    qa = jnp.broadcast_to(qa, lane_shape).astype(jnp.int32)
    rates_fx = rates_to_fixed(rates)                     # [..., C]
    rates_fx = jnp.broadcast_to(rates_fx, (*lane_shape[:-2],
                                           lane_shape[-1]))
    if mod is None:
        mod = jnp.zeros((1, *lane_shape[:-2], lane_shape[-1]), jnp.int32)
    mod = jnp.broadcast_to(mod, (mod.shape[0], *lane_shape[:-2],
                                 lane_shape[-1])).astype(jnp.int32)
    if noise is None:
        noise = jnp.zeros(lane_shape, jnp.int32)
    noise = jnp.broadcast_to(noise, lane_shape).astype(jnp.int32)

    def fn(w, c, a, r, m, n):
        return run_program_pallas(words, w, c, a, r, m, n, rb=rb, cb=cb,
                                  interpret=interpret)

    # peel one instance dim per vmap: operands carry the prefix at axis 0,
    # mod at axis 1 (slots lead); regs gain the prefix at axis 1 (N_REGS
    # leads), matching interp's [N_REGS, ..., R, C] convention
    for _ in range(weights.ndim - 2):
        fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 1, 0), out_axes=(0, 1))
    return fn(weights, qc, qa, rates_fx, mod, noise)
