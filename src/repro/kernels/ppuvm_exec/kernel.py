"""Pallas tile VM: execute a whole PPU-VM program per VMEM tile.

The silicon PPU runs its plasticity kernel out of on-chip SRAM: the
program loops over synapse rows, and every intermediate lives in the
vector unit's registers — weights stream through, the program does not.
This kernel is the TPU analogue: one grid pass over (row, column) tiles
of the synapse array; per tile, the ENTIRE instruction stream executes
with the register file held on-chip (a [N_REGS, rb, cb] carry that the
compiler keeps in VMEM/vregs), so a P-instruction program costs one HBM
round trip instead of P (the scan interpreter re-reads the operand
planes per lax.switch arm).

The instruction words are a scalar-prefetch operand (SMEM): they are the
*data* driving control flow — `fori_loop` over words, `lax.switch` over
opcodes — exactly like the hardware fetches its kernel from SRAM. The
per-word semantics are `repro.ppuvm.interp.make_branches`/`step_word`,
shared verbatim with the scan interpreter, so the two executors cannot
drift; bit-equality across random programs is enforced by
``tests/test_ppuvm_fuzz.py``.

Operand tiling (grid = (R//rb, C//cb)):
  weights/qc/qa/noise  [R, C] int32   -> (rb, cb) row tiles
  rates_fx             [1, C] int32   -> (1, cb) column tiles (pre-sat
                       Q8.8 — digitized once on the host side of the
                       kernel so every executor consumes identical ints)
  mod                  [n_mod, C] i32 -> (n_mod, cb) column tiles
Outputs: new weights (rb, cb) int32 and the final register file
  (N_REGS, rb, cb) — the program's scratch readout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.ppuvm import isa
from repro.ppuvm.interp import make_branches, step_word


def _kernel(words_ref, w_ref, qc_ref, qa_ref, rates_ref, mod_ref, noise_ref,
            wout_ref, regs_ref, *, n_words: int):
    lane = w_ref.shape                                   # (rb, cb)
    rates_fx = jnp.broadcast_to(rates_ref[...], lane)
    mod = jnp.broadcast_to(mod_ref[...][:, None, :],
                           (mod_ref.shape[0], *lane))
    branches = make_branches(lane, qc_ref[...], qa_ref[...], rates_fx, mod,
                             noise_ref[...])
    regs0 = jnp.zeros((isa.N_REGS, *lane), jnp.int32)

    def body(i, carry):
        regs, wmem = carry
        return step_word(branches, regs, wmem, words_ref[i])

    regs, wmem = jax.lax.fori_loop(0, n_words, body, (regs0, w_ref[...]))
    wout_ref[...] = wmem
    regs_ref[...] = regs


@functools.partial(jax.jit,
                   static_argnames=("rb", "cb", "interpret"))
def run_program_pallas(words, weights, qc, qa, rates_fx, mod, noise, *,
                       rb: int = 64, cb: int = 128,
                       interpret: bool = False):
    """words [P] int32; weights/qc/qa/noise [R, C] int32; rates_fx [C]
    int32 (already saturated Q8.8); mod [n_mod, C] int32. Returns
    (new_weights int32 [R, C], regs int32 [N_REGS, R, C])."""
    R, C = weights.shape
    rb = min(rb, R)
    cb = min(cb, C)
    assert R % rb == 0 and C % cb == 0, (R, C, rb, cb)
    n_mod = mod.shape[0]
    # index maps get the scalar-prefetch ref appended to the grid indices
    row_spec = pl.BlockSpec((rb, cb), lambda i, j, words_ref: (i, j))
    col_spec = pl.BlockSpec((1, cb), lambda i, j, words_ref: (0, j))
    mod_spec = pl.BlockSpec((n_mod, cb), lambda i, j, words_ref: (0, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R // rb, C // cb),
        in_specs=[row_spec, row_spec, row_spec, col_spec, mod_spec,
                  row_spec],
        out_specs=[row_spec,
                   pl.BlockSpec((isa.N_REGS, rb, cb),
                                lambda i, j, words_ref: (0, i, j))],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, n_words=int(words.shape[0])),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int32),
                   jax.ShapeDtypeStruct((isa.N_REGS, R, C), jnp.int32)],
        interpret=interpret,
    )(words.astype(jnp.int32), weights.astype(jnp.int32),
      qc.astype(jnp.int32), qa.astype(jnp.int32),
      rates_fx[None].astype(jnp.int32), mod.astype(jnp.int32),
      noise.astype(jnp.int32))
    return out[0], out[1]
