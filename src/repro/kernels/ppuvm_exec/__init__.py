"""Pallas tile-VM executor for PPU-VM programs: the whole program runs
per VMEM tile (registers on-chip, one grid pass over the synapse array).
See ``kernel`` for the tile VM and ``ops`` for the public wrapper."""
from repro.kernels.ppuvm_exec import kernel, ops  # noqa: F401
