"""Jit'd public wrapper for the synray kernel.

On TPU the Pallas path runs natively; elsewhere (CPU container) it runs in
interpret mode or falls back to the jnp oracle — selected by ``impl``.

Operands may carry an arbitrary instance prefix (events [..., B, R],
weights [..., R, C]): the kernel path folds it into the instance grid
axis (ONE launch for the whole fleet, see ``repro.kernels``), the oracle
broadcasts natively.
"""
from __future__ import annotations

import jax

from repro.kernels import fold_instance, unfold_instance
from repro.kernels.synray.kernel import synaptic_current_pallas
from repro.kernels.synray.ref import synaptic_current_ref

# jitted once at import — constructing jax.jit(ref) per call would defeat
# the jit cache and retrace on every invocation
_ref_jit = jax.jit(synaptic_current_ref)


def synaptic_current(events, event_addr, weights, addresses,
                     impl: str = "auto", **block_kw):
    """impl: auto | pallas | interpret | ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_jit(events, event_addr, weights, addresses)
    prefix = weights.shape[:-2]
    out = synaptic_current_pallas(
        fold_instance(events, 2), fold_instance(event_addr, 2),
        fold_instance(weights, 2), fold_instance(addresses, 2),
        interpret=(impl == "interpret"), **block_kw)
    return unfold_instance(out, prefix)
