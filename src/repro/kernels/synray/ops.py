"""Jit'd public wrapper for the synray kernel.

On TPU the Pallas path runs natively; elsewhere (CPU container) it runs in
interpret mode or falls back to the jnp oracle — selected by ``impl``.
"""
from __future__ import annotations

import jax

from repro.kernels.synray.kernel import synaptic_current_pallas
from repro.kernels.synray.ref import synaptic_current_ref

# jitted once at import — constructing jax.jit(ref) per call would defeat
# the jit cache and retrace on every invocation
_ref_jit = jax.jit(synaptic_current_ref)


def synaptic_current(events, event_addr, weights, addresses,
                     impl: str = "auto", **block_kw):
    """impl: auto | pallas | interpret | ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_jit(events, event_addr, weights, addresses)
    return synaptic_current_pallas(events, event_addr, weights, addresses,
                                   interpret=(impl == "interpret"),
                                   **block_kw)
