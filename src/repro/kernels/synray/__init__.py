from repro.kernels.synray.ops import synaptic_current  # noqa: F401
