"""Pure-jnp oracle for the synray kernel (mirrors repro.core.synapse)."""
import jax.numpy as jnp


def synaptic_current_ref(events, event_addr, weights, addresses):
    """events [B, R] f32; event_addr [B, R] i8; weights/addresses [R, C] i8
    -> [B, C] f32."""
    mask = (addresses[None, :, :] == event_addr[:, :, None])
    w_eff = weights.astype(jnp.float32)[None] * mask.astype(jnp.float32)
    return jnp.einsum("br,brc->bc", events.astype(jnp.float32), w_eff)
