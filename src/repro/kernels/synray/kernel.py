"""Pallas kernel: synapse-array event path.

i[n, b, c] = sum_r ev[n, b, r] * w[n, r, c] * (addr_store[n, r, c] ==
addr_event[n, b, r])

Hardware adaptation (DESIGN.md): on BSS-2 the address comparison happens in
each synapse circuit as the event ripples down the row. On TPU the natural
mapping is a *masked* block matmul: the weight/address tile lives in VMEM,
the per-(batch,row) event address broadcasts against the stored-address
tile, and the masked tile contracts against the event vector. Tiles are
MXU/VPU aligned (row x 128-lane column blocks); the reduction runs over the
row-block grid axis with an accumulator in the output block.

The leading ``n`` is the **instance grid axis**: a fleet of independent
chip instances (each with its own weights/addresses/events) runs as ONE
kernel launch with instances as the outermost grid dimension — no nested
``jax.vmap`` fold (see ``repro.kernels`` docstring). 2-D operands are
accepted and treated as a single instance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ev_ref, ea_ref, w_ref, st_ref, out_ref):
    r_idx = pl.program_id(3)

    @pl.when(r_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ev = ev_ref[0].astype(jnp.float32)              # [bb, rb]
    ea = ea_ref[0]                                  # [bb, rb] int8
    w = w_ref[0].astype(jnp.float32)                # [rb, cb]
    st = st_ref[0]                                  # [rb, cb] int8

    # [bb, rb, cb] masked tile — bounded by the block sizes, VMEM-resident
    mask = (st[None, :, :] == ea[:, :, None]).astype(jnp.float32)
    contrib = jnp.sum(ev[:, :, None] * (w[None, :, :] * mask), axis=1)
    out_ref[0] += contrib


@functools.partial(jax.jit, static_argnames=("bb", "cb", "rb", "interpret"))
def synaptic_current_pallas(events, event_addr, weights, addresses, *,
                            bb: int = 8, cb: int = 128, rb: int = 64,
                            interpret: bool = False):
    """events: [N, B, R] f32; event_addr: [N, B, R] i8; weights/addresses:
    [N, R, C] i8. Returns [N, B, C] f32. 2-D operands (no instance axis)
    are promoted to N=1 and squeezed back."""
    squeeze = events.ndim == 2
    if squeeze:
        events, event_addr = events[None], event_addr[None]
        weights, addresses = weights[None], addresses[None]
    N, B, R = events.shape
    C = weights.shape[-1]
    bb = min(bb, B)
    cb = min(cb, C)
    rb = min(rb, R)
    assert B % bb == 0 and C % cb == 0 and R % rb == 0, (B, R, C, bb, rb, cb)
    grid = (N, B // bb, C // cb, R // rb)
    ev_spec = pl.BlockSpec((1, bb, rb), lambda n, i, j, k: (n, i, k))
    w_spec = pl.BlockSpec((1, rb, cb), lambda n, i, j, k: (n, k, j))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[ev_spec, ev_spec, w_spec, w_spec],
        out_specs=pl.BlockSpec((1, bb, cb), lambda n, i, j, k: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((N, B, C), jnp.float32),
        interpret=interpret,
    )(events, event_addr, weights, addresses)
    return out[0] if squeeze else out
