"""Pallas kernel: synapse-array event path.

i[b, c] = sum_r ev[b, r] * w[r, c] * (addr_store[r, c] == addr_event[b, r])

Hardware adaptation (DESIGN.md): on BSS-2 the address comparison happens in
each synapse circuit as the event ripples down the row. On TPU the natural
mapping is a *masked* block matmul: the weight/address tile lives in VMEM,
the per-(batch,row) event address broadcasts against the stored-address
tile, and the masked tile contracts against the event vector. Tiles are
MXU/VPU aligned (row x 128-lane column blocks); the reduction runs over the
row-block grid axis with an accumulator in the output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ev_ref, ea_ref, w_ref, st_ref, out_ref):
    r_idx = pl.program_id(2)

    @pl.when(r_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ev = ev_ref[...].astype(jnp.float32)            # [bb, rb]
    ea = ea_ref[...]                                # [bb, rb] int8
    w = w_ref[...].astype(jnp.float32)              # [rb, cb]
    st = st_ref[...]                                # [rb, cb] int8

    # [bb, rb, cb] masked tile — bounded by the block sizes, VMEM-resident
    mask = (st[None, :, :] == ea[:, :, None]).astype(jnp.float32)
    contrib = jnp.sum(ev[:, :, None] * (w[None, :, :] * mask), axis=1)
    out_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("bb", "cb", "rb", "interpret"))
def synaptic_current_pallas(events, event_addr, weights, addresses, *,
                            bb: int = 8, cb: int = 128, rb: int = 64,
                            interpret: bool = False):
    """events: [B, R] f32; event_addr: [B, R] i8; weights/addresses: [R, C]
    i8. Returns [B, C] f32."""
    B, R = events.shape
    C = weights.shape[1]
    bb = min(bb, B)
    cb = min(cb, C)
    rb = min(rb, R)
    assert B % bb == 0 and C % cb == 0 and R % rb == 0, (B, R, C, bb, rb, cb)
    grid = (B // bb, C // cb, R // rb)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, rb), lambda i, j, k: (i, k)),
            pl.BlockSpec((bb, rb), lambda i, j, k: (i, k)),
            pl.BlockSpec((rb, cb), lambda i, j, k: (k, j)),
            pl.BlockSpec((rb, cb), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, cb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(events, event_addr, weights, addresses)
