"""Pallas kernel: event-sparse synapse-array path.

i[n, t, c] = sum_k eff[n, t, k] * w[n, rows[n, t, k], c]
                 * (addr_store[n, rows[n, t, k], c] == addr[n, t, k])

Hardware adaptation (DESIGN.md): on BSS-2 only the rows that actually
received an event ripple current into the array — the dense matmul is the
TPU-friendly *approximation* of that, and this kernel is the faithful one:
the [T, K] regrouped event records (``repro.core.events``) gather exactly
the fired weight rows, the 6-bit address comparison runs per gathered
record, and the K record slots contract against the efficacies. Work is
O(T * K * C) instead of O(T * R * C) — at 1% density with K ~ R/16 that is
an order of magnitude fewer MACs.

The grid is (instances, column blocks): the whole [T, K] record grid plus
the [R, cb] weight/address tiles live in VMEM, and the contraction is ONE
batched [T, K] x [T, K, cb] dot — the same einsum as the jnp ref, so the
per-element reduction chain (and therefore every bit, see ref.py) is
preserved; empty record slots carry eff == 0 and are exact no-ops in the
FMA chain. No K-axis grid blocking: splitting K would re-order the
reduction and break the bit contract. The leading ``n`` is the instance
grid axis shared with the other kernels (see ``repro.kernels``); 2-D
record operands are promoted to N=1.

Like the corr kernel, the in-kernel dynamic row gather targets TPU Mosaic
only nominally — the verified path in this CPU container is interpret
mode (tests/test_sparse.py), the deployment target compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rows_ref, addr_ref, eff_ref, w_ref, st_ref, out_ref):
    rows = rows_ref[0]                                  # [T, K] i32
    T, K = rows.shape
    flat = rows.reshape(-1)
    wg = jnp.take(w_ref[0], flat, axis=0)               # [T*K, cb] i8
    sg = jnp.take(st_ref[0], flat, axis=0)
    wg = wg.reshape(T, K, -1).astype(jnp.float32)
    match = (sg.reshape(T, K, -1) == addr_ref[0][:, :, None]
             ).astype(jnp.float32)
    out_ref[0] = jnp.einsum("tk,tkc->tc", eff_ref[0], wg * match)


@functools.partial(jax.jit, static_argnames=("cb", "interpret"))
def sparse_window_pallas(rows_tk, addr_tk, eff_tk, weights, addresses, *,
                         cb: int = 128, interpret: bool = False):
    """rows_tk/addr_tk: [N, T, K] i32; eff_tk: [N, T, K] f32;
    weights/addresses: [N, R, C] i8. Returns [N, T, C] f32. 2-D operands
    (no instance axis) are promoted to N=1 and squeezed back."""
    squeeze = rows_tk.ndim == 2
    if squeeze:
        rows_tk, addr_tk, eff_tk = rows_tk[None], addr_tk[None], eff_tk[None]
        weights, addresses = weights[None], addresses[None]
    N, T, K = rows_tk.shape
    R, C = weights.shape[-2:]
    cb = min(cb, C)
    assert C % cb == 0, (C, cb)
    grid = (N, C // cb)
    rec_spec = pl.BlockSpec((1, T, K), lambda n, j: (n, 0, 0))
    w_spec = pl.BlockSpec((1, R, cb), lambda n, j: (n, 0, j))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[rec_spec, rec_spec, rec_spec, w_spec, w_spec],
        out_specs=pl.BlockSpec((1, T, cb), lambda n, j: (n, 0, j)),
        out_shape=jax.ShapeDtypeStruct((N, T, C), jnp.float32),
        interpret=interpret,
    )(rows_tk, addr_tk, eff_tk, weights, addresses)
    return out[0] if squeeze else out
