from repro.kernels.synray_sparse.ops import (  # noqa: F401
    sparse_window, synaptic_current_sparse)
