"""Pure-jnp oracle for the synray_sparse kernel — and the CPU hot path.

``sparse_window_ref`` consumes the per-step [T, K] regrouped event records
(``repro.core.events.regroup_events``): gather each step's fired weight
rows, apply the 6-bit address match per gathered record, and contract the
K record slots against the efficacies.

Bit-exactness contract (the reason this path may replace the dense one):
XLA:CPU reduces a contraction as one in-order FMA chain per output
element, so (a) terms that are exactly zero are exact no-ops in the chain
(``fma(0 * w, acc) == acc``), and (b) the chain does not depend on the
other rows/columns of the product. Dropping the silent rows while keeping
the fired ones in row order — which the t-major stream regrouping
guarantees — therefore reproduces the dense matmul BIT-identically, as
long as the reduction runs through the same dot machinery. Hence the
einsum below, never a hand-rolled accumulation loop (separate mul+add
rounds differently than the fused multiply-add). Asserted exactly, over a
0%..100% density sweep, in tests/test_sparse.py.
"""
import jax.numpy as jnp


def sparse_window_ref(rows_tk, addr_tk, eff_tk, weights, addresses):
    """rows_tk/addr_tk [T, K] i32; eff_tk [T, K] f32 (0 in empty slots);
    weights/addresses [R, C] i8 -> [T, C] f32."""
    wg = weights[rows_tk].astype(jnp.float32)              # [T, K, C]
    match = (addresses[rows_tk] == addr_tk[..., None]).astype(jnp.float32)
    return jnp.einsum("tk,tkc->tc", eff_tk, wg * match)
