"""Jit'd public wrappers for the synray_sparse kernel.

Two entry points:

``sparse_window``
    The compute on already-regrouped [.., T, K] event records — kernel or
    jnp ref, selected by ``impl`` like every other kernel wrapper.

``synaptic_current_sparse``
    The full event-sparse path on the same [N, T, R] folded operands the
    dense ``synray`` wrapper takes: pack the window into the compact
    event stream (``repro.core.events``), regroup per step, compute.
    Capacities ``max_events``/``k_cap`` are static (they size the jitted
    program); windows that overflow them silently drop records — callers
    that cannot prove the window fits must gate on
    ``repro.core.events.window_stats`` and fall back to the dense path
    (``repro.core.synapse.synaptic_current_window(sparse="auto")`` does).

Operands may carry an arbitrary instance prefix via the callers' fold
(see ``repro.kernels``): the kernel runs the fleet on its instance grid
axis, the ref path vmaps.
"""
from __future__ import annotations

import functools

import jax

from repro.core import events as ev_mod
from repro.kernels.synray_sparse.kernel import sparse_window_pallas
from repro.kernels.synray_sparse.ref import sparse_window_ref

# jitted once at import — same rationale as the synray wrapper
_ref_jit = jax.jit(sparse_window_ref)
_ref_vmap_jit = jax.jit(jax.vmap(sparse_window_ref))


def sparse_window(rows_tk, addr_tk, eff_tk, weights, addresses,
                  impl: str = "auto", **block_kw):
    """impl: auto | pallas | interpret | ref. Record operands [.., T, K],
    weights/addresses [.., R, C] (2-D = single instance)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        if rows_tk.ndim == 2:
            return _ref_jit(rows_tk, addr_tk, eff_tk, weights, addresses)
        return _ref_vmap_jit(rows_tk, addr_tk, eff_tk, weights, addresses)
    return sparse_window_pallas(rows_tk, addr_tk, eff_tk, weights,
                                addresses,
                                interpret=(impl == "interpret"), **block_kw)


@functools.partial(jax.jit, static_argnames=("max_events", "k_cap"))
def _pack_regroup(row_events_t, event_addr_t, *, max_events, k_cap):
    T = row_events_t.shape[1]

    def one(ev, ad):
        stream = ev_mod.pack_events(ev, ad, max_events)
        return ev_mod.regroup_events(stream, T, k_cap)

    return jax.vmap(one)(row_events_t, event_addr_t)


def synaptic_current_sparse(row_events_t, event_addr_t, weights, addresses,
                            *, max_events: int, k_cap: int,
                            impl: str = "auto", **block_kw):
    """row_events_t [N, T, R] f32 (0 = silent, else efficacy);
    event_addr_t [N, T, R] int; weights/addresses [N, R, C] i8
    -> [N, T, C] f32. Drops events beyond the static capacities — see
    module docstring."""
    rows_tk, addr_tk, eff_tk = _pack_regroup(
        row_events_t, event_addr_t, max_events=max_events, k_cap=k_cap)
    return sparse_window(rows_tk, addr_tk, eff_tk, weights, addresses,
                         impl=impl, **block_kw)
