"""Pallas TPU kernels for the machine model's compute hot-spots.

Three kernels, each with a pure-jnp oracle (ref.py) and a jit'd wrapper
(ops.py); validated shape/dtype-swept against the oracle in interpret mode
(this container is CPU-only; TPU is the deployment target):

  synray      event x 6-bit-weight synaptic-current matmul with in-kernel
              address matching (the synapse array's event path)
  corr        T-step fused correlation-sensor update: decay + outer-product
              accumulation entirely in VMEM (T x fewer HBM round trips)
  ppu_update  the PPU vector-unit inner loop: CADC digitization ->
              eligibility -> R-STDP -> saturating 6-bit weight write-back,
              row-parallel

Implementation selection
------------------------
Every ops.py wrapper takes ``impl``:

  auto        pallas when ``jax.default_backend() == "tpu"``, else ref
  pallas      the native Pallas kernel (TPU)
  interpret   the Pallas kernel under the interpreter (CPU validation)
  ref         the module-level-jitted jnp oracle

The emulation hot path consumes these through ``AnnCore`` (see
repro.core.anncore): ``AnnCore(cfg, inst, backend="fused")`` hoists the
correlation-sensor update out of the per-dt scan (one ``corr`` call per
trial), batches the whole trial's synaptic currents through ``synray``
(time as the batch axis), and ``VectorUnit.apply_rstdp`` routes the
standard R-STDP write-back through ``ppu_update`` (the §5 Dale-signed
rule stays on the generic VM path). ``backend="oracle"`` keeps
the literal per-step semantics as ground truth; ``backend="auto"`` selects
the fused path, mirroring the impl auto-selection above.
"""
