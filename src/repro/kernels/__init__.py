"""Pallas TPU kernels for the machine model's compute hot-spots.

Three kernels, each with a pure-jnp oracle (ref.py) and a jit'd wrapper
(ops.py); validated shape/dtype-swept against the oracle in interpret mode
(this container is CPU-only; TPU is the deployment target):

  synray      event x 6-bit-weight synaptic-current matmul with in-kernel
              address matching (the synapse array's event path)
  synray_sparse
              the event-sparse twin of synray: gather-accumulates only
              fired rows from a compact [T, K] record grid
              (repro.core.events) — O(T*K*C) instead of O(T*R*C), and
              BIT-identical to the dense path (see its ref.py);
              auto-selected per window by measured event density in
              ``synapse.synaptic_current_window(sparse="auto")``
  corr        T-step fused correlation-sensor update: decay + outer-product
              accumulation entirely in VMEM (T x fewer HBM round trips)
  ppu_update  the PPU vector-unit inner loop: CADC digitization ->
              eligibility -> R-STDP -> saturating 6-bit weight write-back,
              row-parallel

Implementation selection
------------------------
Every ops.py wrapper takes ``impl``:

  auto        pallas when ``jax.default_backend() == "tpu"``, else ref
  pallas      the native Pallas kernel (TPU)
  interpret   the Pallas kernel under the interpreter (CPU validation)
  ref         the module-level-jitted jnp oracle

The emulation hot path consumes these through ``AnnCore`` (see
repro.core.anncore): ``AnnCore(cfg, inst, backend="fused")`` hoists the
correlation-sensor update out of the per-dt scan (one ``corr`` call per
trial), batches the whole trial's synaptic currents through ``synray``
(time as the batch axis), and ``VectorUnit.apply_rstdp`` routes the
standard R-STDP write-back through ``ppu_update`` (the §5 Dale-signed
rule stays on the generic VM path). ``backend="oracle"`` keeps
the literal per-step semantics as ground truth; ``backend="auto"`` selects
the fused path (the blocked ``neuron_scan`` variant on TPU), mirroring
the impl auto-selection above.

Instance grid axis
------------------
The multi-instance fleet (a batch of independent virtual chips) maps onto
the kernels as a real leading grid axis, not a nested ``jax.vmap`` fold:
the wrappers collapse an arbitrary instance prefix into one N axis with
the helpers below, and each kernel's grid is ``(N, ...tile axes)`` — one
kernel launch for the whole fleet. ``repro.parallel.sharding.Ax.INSTANCE``
names the same axis for the mesh (instances shard over the data dims), so
the grid axis and the sharding axis coincide by construction.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def fold_instance(x, n_core: int):
    """[*prefix, *core] -> [N, *core] with N = prod(prefix) (N=1 when the
    prefix is empty). ``n_core`` is the number of trailing core dims."""
    core = x.shape[x.ndim - n_core:]
    return x.reshape(math.prod(x.shape[:x.ndim - n_core]), *core)


def unfold_instance(y, prefix):
    """Inverse of ``fold_instance``: [N, *core] -> [*prefix, *core]."""
    return y.reshape(*prefix, *y.shape[1:])


def fold_instance_time(x, n_core: int):
    """[T, *prefix, *core] -> [N, T, *core]: time-major window operands
    (event streams, current windows) fold their instance prefix in front
    of the time axis for the kernel instance grid."""
    n_prefix = x.ndim - 1 - n_core
    x = jnp.moveaxis(x, 0, n_prefix)
    return fold_instance(x, n_core + 1)


def unfold_instance_time(y, prefix):
    """Inverse of ``fold_instance_time``: [N, T, *core] -> [T, *prefix,
    *core]."""
    y = y.reshape(*prefix, *y.shape[1:])
    return jnp.moveaxis(y, len(prefix), 0)
