"""Pallas TPU kernels for the machine model's compute hot-spots.

Three kernels, each with a pure-jnp oracle (ref.py) and a jit'd wrapper
(ops.py); validated shape/dtype-swept against the oracle in interpret mode
(this container is CPU-only; TPU is the deployment target):

  synray      event x 6-bit-weight synaptic-current matmul with in-kernel
              address matching (the synapse array's event path)
  corr        T-step fused correlation-sensor update: decay + outer-product
              accumulation entirely in VMEM (T x fewer HBM round trips)
  ppu_update  the PPU vector-unit inner loop: CADC digitization ->
              eligibility -> R-STDP -> saturating 6-bit weight write-back,
              row-parallel
"""
