"""Pure-jnp oracle for the ppu_update kernel (mirrors core.cadc + rules)."""
import jax.numpy as jnp


def rstdp_update_ref(weights, a_causal, a_acausal, cadc_offset, cadc_gain,
                     mod, xi, *, eta: float, cadc_scale: float = 8.0,
                     wmax: int = 63, cadc_max: int = 255):
    # digitization clamps to cadc_max like the kernel (NOT a hardcoded
    # 8-bit range), so both impls agree for any cadc bit width
    def digitize(a):
        code = a * (cadc_gain[None] * cadc_scale) + cadc_offset[None]
        return jnp.clip(jnp.round(code), 0.0, float(cadc_max))

    qc = digitize(a_causal)
    qa = digitize(a_acausal)
    elig = (qc - qa).astype(jnp.float32) / float(cadc_max)
    w_new = weights.astype(jnp.float32) + eta * mod[None] * elig + xi
    w_q = jnp.clip(jnp.round(w_new), 0, wmax).astype(jnp.int8)
    return w_q, elig
