"""Pure-jnp oracle for the ppu_update kernel (mirrors core.cadc + rules)."""
import jax.numpy as jnp

from repro.core import cadc


def rstdp_update_ref(weights, a_causal, a_acausal, cadc_offset, cadc_gain,
                     mod, xi, *, eta: float, cadc_scale: float = 8.0,
                     wmax: int = 63, cadc_max: int = 255):
    qc = cadc.digitize(a_causal, offset=cadc_offset[None],
                       gain=cadc_gain[None], bits=8, in_scale=cadc_scale)
    qa = cadc.digitize(a_acausal, offset=cadc_offset[None],
                       gain=cadc_gain[None], bits=8, in_scale=cadc_scale)
    elig = (qc - qa).astype(jnp.float32) / float(cadc_max)
    w_new = weights.astype(jnp.float32) + eta * mod[None] * elig + xi
    w_q = jnp.clip(jnp.round(w_new), 0, wmax).astype(jnp.int8)
    return w_q, elig
