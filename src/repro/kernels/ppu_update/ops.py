"""Jit'd public wrapper for the PPU R-STDP update kernel."""
from __future__ import annotations

import jax

from repro.kernels.ppu_update.kernel import rstdp_update_pallas
from repro.kernels.ppu_update.ref import rstdp_update_ref


def rstdp_update(weights, a_causal, a_acausal, cadc_offset, cadc_gain, mod,
                 xi, *, eta, impl: str = "auto", **kw):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return jax.jit(
            lambda *a: rstdp_update_ref(*a, eta=eta, **kw)
        )(weights, a_causal, a_acausal, cadc_offset, cadc_gain, mod, xi)
    return rstdp_update_pallas(weights, a_causal, a_acausal, cadc_offset,
                               cadc_gain, mod, xi, eta=eta,
                               interpret=(impl == "interpret"), **kw)
