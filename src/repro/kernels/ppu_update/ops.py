"""Jit'd public wrapper for the PPU R-STDP update kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ppu_update.kernel import rstdp_update_pallas
from repro.kernels.ppu_update.ref import rstdp_update_ref

# jitted once at import (static kwargs hash into the cache key) —
# constructing jax.jit(lambda ...) per call would defeat the jit cache
_ref_jit = jax.jit(rstdp_update_ref,
                   static_argnames=("eta", "cadc_scale", "wmax", "cadc_max"))


def rstdp_update(weights, a_causal, a_acausal, cadc_offset, cadc_gain, mod,
                 xi, *, eta, impl: str = "auto", **kw):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_jit(weights, a_causal, a_acausal, cadc_offset,
                        cadc_gain, mod, xi, eta=eta, **kw)
    return rstdp_update_pallas(weights, a_causal, a_acausal, cadc_offset,
                               cadc_gain, mod, xi, eta=eta,
                               interpret=(impl == "interpret"), **kw)
