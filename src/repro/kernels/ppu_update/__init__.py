from repro.kernels.ppu_update.ops import rstdp_update  # noqa: F401
