"""Pallas kernel: the PPU vector-unit inner loop, row-parallel.

Fuses, per synapse tile:
  1. CADC digitization of the causal/anti-causal capacitor voltages
     (8-bit, per-column offset/gain),
  2. eligibility e = (q_causal - q_acausal)/255,
  3. R-STDP weight update dw = eta * mod[c] * e + xi,
  4. saturating 6-bit write-back.

This mirrors the silicon dataflow exactly: the hardware PPU reads one
synapse row + one CADC row per vector op, computes in fixed point across
the column lanes, and writes the row back through the full-custom SRAM
controller. Lanes == the 128-wide column blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, ac_ref, aa_ref, off_ref, gain_ref, mod_ref, xi_ref,
            wout_ref, elig_ref, *, eta: float, cadc_scale: float,
            wmax: int, cadc_max: int):
    w = w_ref[...].astype(jnp.float32)            # [rb, cb]
    ac = ac_ref[...].astype(jnp.float32)
    aa = aa_ref[...].astype(jnp.float32)
    off = off_ref[...].astype(jnp.float32)        # [1, cb]
    gain = gain_ref[...].astype(jnp.float32)
    mod = mod_ref[...].astype(jnp.float32)        # [1, cb]
    xi = xi_ref[...].astype(jnp.float32)

    def digitize(a):
        code = a * (gain * cadc_scale) + off
        return jnp.clip(jnp.round(code), 0.0, float(cadc_max))

    qc = digitize(ac)
    qa = digitize(aa)
    elig = (qc - qa) / float(cadc_max)
    w_new = w + eta * mod * elig + xi
    wout_ref[...] = jnp.clip(jnp.round(w_new), 0.0, float(wmax)
                             ).astype(jnp.int8)
    elig_ref[...] = elig


@functools.partial(jax.jit, static_argnames=("eta", "cadc_scale", "wmax",
                                             "cadc_max", "rb", "cb",
                                             "interpret"))
def rstdp_update_pallas(weights, a_causal, a_acausal, cadc_offset, cadc_gain,
                        mod, xi, *, eta: float, cadc_scale: float = 8.0,
                        wmax: int = 63, cadc_max: int = 255,
                        rb: int = 64, cb: int = 128,
                        interpret: bool = False):
    """weights [R, C] i8; a_* [R, C] f32; cadc_offset/gain, mod [C] f32;
    xi [R, C] f32. Returns (new_weights i8, eligibility f32)."""
    R, C = weights.shape
    rb = min(rb, R)
    cb = min(cb, C)
    assert R % rb == 0 and C % cb == 0
    grid = (R // rb, C // cb)
    row_spec = pl.BlockSpec((rb, cb), lambda i, j: (i, j))
    col_spec = pl.BlockSpec((1, cb), lambda i, j: (0, j))
    out = pl.pallas_call(
        functools.partial(_kernel, eta=eta, cadc_scale=cadc_scale,
                          wmax=wmax, cadc_max=cadc_max),
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, col_spec, col_spec, col_spec,
                  row_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, C), jnp.float32)],
        interpret=interpret,
    )(weights, a_causal, a_acausal, cadc_offset[None], cadc_gain[None],
      mod[None], xi)
    return out[0], out[1]
