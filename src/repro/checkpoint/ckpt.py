"""Fault-tolerant checkpointing.

Design goals for 1000+ node operation (DESIGN.md §4):

  * **atomic**: write to ``step_NNN.tmp`` then ``os.replace`` — a crash
    mid-write never corrupts the restore point;
  * **async**: ``CheckpointManager(async_save=True)`` hands the host copy
    to a writer thread so the train loop is blocked only for the
    device->host transfer;
  * **elastic**: checkpoints store *logical* arrays + the tree structure;
    ``restore_checkpoint`` re-places them onto whatever mesh/sharding the
    restoring job uses — a job restarted with a different pod count resumes
    from the same state (tested in tests/test_checkpoint.py);
  * **complete**: optimizer state and the data-pipeline cursor are part of
    the checkpoint, so restart is bit-exact, not just weight-exact.

On a real multi-host pod each process saves only its addressable shards
(`process_index` namespacing is already in the path layout); in this
single-process container that degenerates to one file per step.
"""
from __future__ import annotations

import json
import os
import re
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save_checkpoint(ckpt_dir, step: int, state: Dict[str, Any],
                    meta: Optional[dict] = None):
    """state: {'params': tree, 'opt': tree, 'data': tree, ...}."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}
    tmp = ckpt_dir / f"step_{step:08d}.tmp.npz"
    final = ckpt_dir / f"step_{step:08d}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **{k.replace("/", "|"): v for k, v in host.items()})
    os.replace(tmp, final)
    if meta is not None:
        mp = ckpt_dir / f"step_{step:08d}.meta.json"
        mp.write_text(json.dumps(meta))
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := re.fullmatch(r"step_(\d+)\.npz", p.name))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step: Optional[int] = None,
                       shardings=None):
    """Load a checkpoint; optionally re-place onto ``shardings`` (a tree of
    NamedSharding matching the state tree) — the elastic-reshard path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    data = np.load(ckpt_dir / f"step_{step:08d}.npz")
    flat = {k.replace("|", "/"): data[k] for k in data.files}
    state = _unflatten(flat)
    if shardings is not None:
        flat_s = _flatten(shardings)
        flat_v = _flatten(state)
        placed = {}
        for k, v in flat_v.items():
            sh = flat_s.get(k)
            placed[k] = jax.device_put(v, sh) if sh is not None else v
        state = _unflatten(placed)
    return step, state


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async writer thread."""

    def __init__(self, ckpt_dir, keep: int = 3, async_save: bool = False):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state, meta=None):
        # device->host copy happens here (blocking, consistent snapshot)
        host_state = jax.tree.map(np.asarray, state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, meta))
            self._thread.start()
        else:
            self._write(step, host_state, meta)

    def _write(self, step, host_state, meta):
        save_checkpoint(self.dir, step, host_state, meta)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(re.fullmatch(r"step_(\d+)\.npz", p.name).group(1))
                       for p in self.dir.iterdir()
                       if re.fullmatch(r"step_(\d+)\.npz", p.name))
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".meta.json"):
                p = self.dir / f"step_{s:08d}{suffix}"
                if p.exists():
                    p.unlink()

    def restore_latest(self, shardings=None):
        self.wait()
        return restore_checkpoint(self.dir, shardings=shardings)
