"""Synapse array: 6-bit weights + 6-bit address matching (paper §2.1).

Each synapse stores a 6-bit weight and a 6-bit address. An event on a row
carries a source address; the synapse forwards current only when the stored
address matches. Current amplitude = weight * DAC gain (with per-column
mismatch) * STP efficacy of the driver.

The hot operation — events x weights -> per-column synaptic currents — is a
masked int-weight matmul; the Pallas kernel ``repro.kernels.synray``
implements the fused 6-bit dequant + matmul for TPU, and this module's
``synaptic_current`` is its jnp oracle (used on CPU and in tests).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

WMAX = 63  # 6-bit


class SynapseArray(NamedTuple):
    weights: jnp.ndarray    # [..., rows, cols] int8 in [0, 63]
    addresses: jnp.ndarray  # [..., rows, cols] int8 in [0, 63]


def init_array(shape_prefix, rows, cols, key=None) -> SynapseArray:
    w = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    a = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    return SynapseArray(weights=w, addresses=a)


def synaptic_current(weights, addresses, row_events, event_addr, gain):
    """Per-column synaptic current from one event step.

    weights/addresses: [..., R, C] int8; row_events: [..., R] float (0/1 x
    STP efficacy); event_addr: [..., R] int8 (address carried by the event
    on that row); gain: scalar or [..., C] DAC gain.
    Returns [..., C] float32.
    """
    match = (addresses == event_addr[..., None]).astype(jnp.float32)
    w_eff = weights.astype(jnp.float32) * match
    i = jnp.einsum("...rc,...r->...c", w_eff, row_events.astype(jnp.float32))
    return i * gain


def synaptic_current_window(weights, addresses, row_events_t, event_addr_t,
                            gain, impl: str = "auto",
                            const_addr: bool = False):
    """Whole-window synaptic currents: [T, ..., R] events -> [T, ..., C].

    Weights and addresses are constant between PPU writes, so the per-step
    masked matmul collapses into ONE time-batched event x weight matmul:
    time becomes the batch axis of the ``repro.kernels.synray`` Pallas
    kernel (address matching stays in-kernel, so per-step event addresses
    remain fully general). On CPU the broadcasting jnp oracle runs instead.
    A leading instance prefix on ``weights`` maps onto the kernel's
    instance grid axis (one launch for the whole fleet — see
    ``repro.kernels``); the oracle broadcasts natively.

    ``const_addr=True`` asserts the event address on each row is the same
    at every step of the window (true whenever each driver row carries a
    single source, e.g. the §5 experiment). The address-match mask is then
    resolved ONCE into an effective weight matrix and the whole window is
    a plain [T, R] x [R, C] matmul — no [T, R, C] mask materialization.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        if const_addr:
            match = (addresses == event_addr_t[0][..., None]
                     ).astype(jnp.float32)
            w_eff = weights.astype(jnp.float32) * match
            if weights.ndim == 2:     # no instance prefix: plain matmul
                i = row_events_t.astype(jnp.float32) @ w_eff
            else:
                i = jnp.einsum("t...r,...rc->t...c",
                               row_events_t.astype(jnp.float32), w_eff)
            return i * gain
        return synaptic_current(weights, addresses, row_events_t,
                                event_addr_t, gain)
    from repro.kernels import (fold_instance, fold_instance_time,
                               unfold_instance_time)
    from repro.kernels.synray import ops as synray_ops

    # time is the kernel's batch axis; pick the largest batch block that
    # divides the (static) window length
    T = row_events_t.shape[0]
    bb = next(d for d in (8, 4, 2, 1) if T % d == 0)
    prefix = weights.shape[:-2]
    i = synray_ops.synaptic_current(
        fold_instance_time(row_events_t.astype(jnp.float32), 1),
        fold_instance_time(event_addr_t, 1),
        fold_instance(weights, 2), fold_instance(addresses, 2),
        impl=impl, bb=bb)
    return unfold_instance_time(i, prefix) * gain


def quantize_weight(w_float):
    """Saturating 6-bit write (the PPU's vector-store semantics)."""
    return jnp.clip(jnp.round(w_float), 0, WMAX).astype(jnp.int8)
