"""Synapse array: 6-bit weights + 6-bit address matching (paper §2.1).

Each synapse stores a 6-bit weight and a 6-bit address. An event on a row
carries a source address; the synapse forwards current only when the stored
address matches. Current amplitude = weight * DAC gain (with per-column
mismatch) * STP efficacy of the driver.

The hot operation — events x weights -> per-column synaptic currents — is a
masked int-weight matmul; the Pallas kernel ``repro.kernels.synray``
implements the fused 6-bit dequant + matmul for TPU, and this module's
``synaptic_current`` is its jnp oracle (used on CPU and in tests).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events

WMAX = 63  # 6-bit


class SynapseArray(NamedTuple):
    weights: jnp.ndarray    # [..., rows, cols] int8 in [0, 63]
    addresses: jnp.ndarray  # [..., rows, cols] int8 in [0, 63]


def init_array(shape_prefix, rows, cols, key=None) -> SynapseArray:
    w = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    a = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    return SynapseArray(weights=w, addresses=a)


def synaptic_current(weights, addresses, row_events, event_addr, gain):
    """Per-column synaptic current from one event step.

    weights/addresses: [..., R, C] int8; row_events: [..., R] float (0/1 x
    STP efficacy); event_addr: [..., R] int8 (address carried by the event
    on that row); gain: scalar or [..., C] DAC gain.
    Returns [..., C] float32.
    """
    match = (addresses == event_addr[..., None]).astype(jnp.float32)
    w_eff = weights.astype(jnp.float32) * match
    i = jnp.einsum("...rc,...r->...c", w_eff, row_events.astype(jnp.float32))
    return i * gain


# Density below which "auto" routes a window through the event-sparse
# path. The measured dense/sparse crossover on the CPU container sits
# between 50% and 100% density (BENCH_pr6_sparse.json: 1.24x at p=0.5,
# 0.67x at p=1.0), but the default capacities scale with the threshold
# and the static sparse cost is O(T * k_cap * C) — 0.05 keeps that well
# under the dense work while covering the ~4-5x regime at p <= 5%.
SPARSE_THRESHOLD = 0.05
# With ``const_addr`` the dense alternative is the once-resolved PLAIN
# matmul — no [T, R, C] address-mask materialization — so the sparse
# path must clear a lower bar before it wins. "auto" therefore sizes
# its default capacities from this lower threshold when const_addr is
# set: windows in the (0.02, 0.05] density band that used to route
# sparse now overflow the tighter budget and take the (cheaper-here)
# dense fallback. Regression:
# tests/test_sparse.py::TestAutoGate::test_const_addr_lowers_crossover.
SPARSE_THRESHOLD_CONST_ADDR = 0.02
# Static work floor (T * R * C MACs): below it the dense matmul is so
# cheap that packing overhead and the runtime branch can never pay off,
# so sparse="auto" compiles to the pure dense program (keeps e.g. the
# 16 x 16 §5 experiment byte-for-byte the same program as before).
SPARSE_MIN_DENSE_WORK = 2 * 1024 * 1024


def _dense_window(weights, addresses, row_events_t, event_addr_t, gain,
                  impl, const_addr, bb):
    """The dense whole-window path (kernel or broadcasting oracle)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        if const_addr:
            match = (addresses == event_addr_t[0][..., None]
                     ).astype(jnp.float32)
            w_eff = weights.astype(jnp.float32) * match
            if weights.ndim == 2:     # no instance prefix: plain matmul
                i = row_events_t.astype(jnp.float32) @ w_eff
            else:
                i = jnp.einsum("t...r,...rc->t...c",
                               row_events_t.astype(jnp.float32), w_eff)
            return i * gain
        return synaptic_current(weights, addresses, row_events_t,
                                event_addr_t, gain)
    from repro.kernels import (fold_instance, fold_instance_time,
                               unfold_instance_time)
    from repro.kernels.synray import ops as synray_ops

    # time is the kernel's batch axis; pad the window up to the batch
    # block instead of shrinking the block to a divisor of T (the old
    # ``next(d for d in (8, 4, 2, 1) ...)`` silently degraded to bb=1 for
    # any odd T). Batch rows are independent, so zero-event pad steps are
    # exact and sliced off after the call.
    T = row_events_t.shape[0]
    if bb is None:
        bb = min(8, T)
    pad = -T % bb
    if pad:
        row_events_t = jnp.concatenate(
            [row_events_t,
             jnp.zeros((pad, *row_events_t.shape[1:]),
                       row_events_t.dtype)], axis=0)
        event_addr_t = jnp.concatenate(
            [event_addr_t,
             jnp.zeros((pad, *event_addr_t.shape[1:]),
                       event_addr_t.dtype)], axis=0)
    prefix = weights.shape[:-2]
    i = synray_ops.synaptic_current(
        fold_instance_time(row_events_t.astype(jnp.float32), 1),
        fold_instance_time(event_addr_t, 1),
        fold_instance(weights, 2), fold_instance(addresses, 2),
        impl=impl, bb=bb)
    i = unfold_instance_time(i, prefix)
    if pad:
        i = i[:T]
    return i * gain


def _sparse_window(weights, addresses, row_events_t, event_addr_t, gain,
                   impl, max_events, k_cap):
    """The event-sparse whole-window path (repro.kernels.synray_sparse).

    Packs the window into the compact event stream and gather-accumulates
    only fired rows — BIT-identical to the dense path as long as the
    window fits the static capacities (overflow drops records; the
    ``sparse="auto"`` gate in ``synaptic_current_window`` guarantees the
    fit before routing here)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    from repro.kernels import (fold_instance, fold_instance_time,
                               unfold_instance_time)
    from repro.kernels.synray_sparse import ops as sparse_ops

    prefix = weights.shape[:-2]
    i = sparse_ops.synaptic_current_sparse(
        fold_instance_time(row_events_t.astype(jnp.float32), 1),
        fold_instance_time(event_addr_t, 1),
        fold_instance(weights, 2), fold_instance(addresses, 2),
        max_events=max_events, k_cap=k_cap, impl=impl)
    return unfold_instance_time(i, prefix) * gain


def synaptic_current_window(weights, addresses, row_events_t, event_addr_t,
                            gain, impl: str = "auto",
                            const_addr: bool = False,
                            sparse: str = "auto",
                            sparse_threshold: float = None,
                            max_events: int = None, k_cap: int = None,
                            bb: int = None, telemetry=None):
    """Whole-window synaptic currents: [T, ..., R] events -> [T, ..., C].

    Weights and addresses are constant between PPU writes, so the per-step
    masked matmul collapses into ONE time-batched event x weight matmul:
    time becomes the batch axis of the ``repro.kernels.synray`` Pallas
    kernel (address matching stays in-kernel, so per-step event addresses
    remain fully general). On CPU the broadcasting jnp oracle runs instead.
    A leading instance prefix on ``weights`` maps onto the kernel's
    instance grid axis (one launch for the whole fleet — see
    ``repro.kernels``); the oracle broadcasts natively.

    ``const_addr=True`` asserts the event address on each row is the same
    at every step of the window (true whenever each driver row carries a
    single source, e.g. the §5 experiment). The address-match mask is then
    resolved ONCE into an effective weight matrix and the whole window is
    a plain [T, R] x [R, C] matmul — no [T, R, C] mask materialization.

    The machine is event-driven, and at low firing rates the dense matmul
    does orders of magnitude more MACs than the events justify. ``sparse``
    selects the event-sparse path (``repro.kernels.synray_sparse``: pack
    the window into a compact event stream, gather-accumulate only fired
    rows — BIT-identical to the dense path by the in-order-FMA argument in
    its ref.py):

      "auto"    (default) route through sparse when the window provably
                fits the event capacities — a runtime ``lax.cond`` on the
                measured event census, so overflow NEVER drops records (it
                falls back to dense). Windows below the static
                ``SPARSE_MIN_DENSE_WORK`` floor compile to the pure dense
                program with zero switch overhead.
      "never"   always dense (the pre-sparse behavior).
      "always"  force sparse — the caller promises the window fits
                ``max_events``/``k_cap``; overflow silently drops events
                (see tests/test_sparse.py's divergence contract).

    ``sparse_threshold`` sizes the default capacities: ``max_events`` ~
    threshold * T * R total records and ``k_cap`` per-step records, both
    overridable. Its default is ``const_addr``-aware: ``SPARSE_THRESHOLD``
    normally, the lower ``SPARSE_THRESHOLD_CONST_ADDR`` when the dense
    alternative is the once-resolved plain matmul — the auto gate then
    hands the (0.02, 0.05] density band back to dense, where the
    const_addr matmul wins. ``impl`` selects the
    kernel implementation for whichever path runs (auto | pallas |
    interpret | ref). As convenience aliases, ``impl="dense"`` /
    ``impl="sparse"`` force the respective path with auto kernels.

    ``bb`` overrides the dense kernel's time-batch block (default 8; T is
    padded up with zero-event steps when it does not divide).

    ``telemetry`` threads an ``repro.obs.trace.Telemetry`` pytree (or
    ``None`` = off): routing decisions are counted — static dense/sparse
    routes, runtime census-gate outcomes, and capacity-overflow fallbacks
    to dense (previously silent). With telemetry the return value is
    ``(currents, telemetry)``; the currents themselves are untouched (the
    counters only read the census the gate already computes), so on/off
    stays bit-identical.
    """
    from repro.obs import trace as obs_trace
    if impl == "dense":
        impl, sparse = "auto", "never"
    elif impl == "sparse":
        impl, sparse = "auto", "always"
    elif impl.startswith("sparse_"):
        impl, sparse = impl[len("sparse_"):], "always"
    if sparse not in ("auto", "never", "always"):
        raise ValueError(f"unknown sparse mode {sparse!r}")

    T = row_events_t.shape[0]
    R = row_events_t.shape[-1]
    C = weights.shape[-1]
    if sparse == "auto" and T * R * C < SPARSE_MIN_DENSE_WORK:
        sparse = "never"
    if sparse == "never":
        i = _dense_window(weights, addresses, row_events_t,
                          event_addr_t, gain, impl, const_addr, bb)
        if telemetry is None:
            return i
        return i, obs_trace.count_route(telemetry, sparse=False)

    if sparse_threshold is not None:
        thr = sparse_threshold
    else:
        thr = SPARSE_THRESHOLD_CONST_ADDR if const_addr else SPARSE_THRESHOLD
    if max_events is None:
        max_events = events.default_max_events(T, R, thr)
    if k_cap is None:
        k_cap = events.default_k_cap(R, thr)
    if sparse == "always":
        i = _sparse_window(weights, addresses, row_events_t,
                           event_addr_t, gain, impl, max_events, k_cap)
        if telemetry is None:
            return i
        return i, obs_trace.count_route(telemetry, sparse=True)

    n, kmax = events.window_stats(row_events_t)
    fits = events.census_fits(n, kmax, max_events, k_cap)
    i = jax.lax.cond(
        fits,
        lambda: _sparse_window(weights, addresses, row_events_t,
                               event_addr_t, gain, impl, max_events,
                               k_cap),
        lambda: _dense_window(weights, addresses, row_events_t,
                              event_addr_t, gain, impl, const_addr, bb))
    if telemetry is None:
        return i
    return i, obs_trace.count_gate(telemetry, fits, n, kmax)


def quantize_weight(w_float):
    """Saturating 6-bit write (the PPU's vector-store semantics)."""
    return jnp.clip(jnp.round(w_float), 0, WMAX).astype(jnp.int8)
