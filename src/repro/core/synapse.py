"""Synapse array: 6-bit weights + 6-bit address matching (paper §2.1).

Each synapse stores a 6-bit weight and a 6-bit address. An event on a row
carries a source address; the synapse forwards current only when the stored
address matches. Current amplitude = weight * DAC gain (with per-column
mismatch) * STP efficacy of the driver.

The hot operation — events x weights -> per-column synaptic currents — is a
masked int-weight matmul; the Pallas kernel ``repro.kernels.synray``
implements the fused 6-bit dequant + matmul for TPU, and this module's
``synaptic_current`` is its jnp oracle (used on CPU and in tests).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

WMAX = 63  # 6-bit


class SynapseArray(NamedTuple):
    weights: jnp.ndarray    # [..., rows, cols] int8 in [0, 63]
    addresses: jnp.ndarray  # [..., rows, cols] int8 in [0, 63]


def init_array(shape_prefix, rows, cols, key=None) -> SynapseArray:
    w = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    a = jnp.zeros((*shape_prefix, rows, cols), jnp.int8)
    return SynapseArray(weights=w, addresses=a)


def synaptic_current(weights, addresses, row_events, event_addr, gain):
    """Per-column synaptic current from one event step.

    weights/addresses: [..., R, C] int8; row_events: [..., R] float (0/1 x
    STP efficacy); event_addr: [..., R] int8 (address carried by the event
    on that row); gain: scalar or [..., C] DAC gain.
    Returns [..., C] float32.
    """
    match = (addresses == event_addr[..., None]).astype(jnp.float32)
    w_eff = weights.astype(jnp.float32) * match
    i = jnp.einsum("...rc,...r->...c", w_eff, row_events.astype(jnp.float32))
    return i * gain


def quantize_weight(w_float):
    """Saturating 6-bit write (the PPU's vector-store semantics)."""
    return jnp.clip(jnp.round(w_float), 0, WMAX).astype(jnp.int8)
