"""Hybrid plasticity: the fused on-device experiment step (paper §2.2, §5).

The defining property of BrainScaleS-2 is that the learning rule runs *on*
the accelerator: the PPU reads rate counters and correlation sensors, joins
them with the reward, and writes 6-bit weights — no host round-trip. The
paper reports 290 us/training step once host transfers are removed (§5).

Here the entire trial — environment (input pattern generation), anncore
emulation, observable digitization, R-STDP update — is ONE jitted function
(`make_trial_step`). The host-in-the-loop baseline (`host_loop_trial`)
pulls observables to the host between phases, reproducing the comparison
the paper makes.

The experiment is §5's pattern-discrimination task: 16 inputs with Poisson
background, patterns A/B on 5 (possibly overlapping) channels; even neurons
are rewarded for firing on A, odd neurons on B.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2Config, BSS2
from repro.core import rules, synapse
from repro.core.anncore import AnnCore, AnnCoreState
from repro.core.ppu import VectorUnit
from repro.obs import trace as obs_trace
from repro.verif.mismatch import sample_instance


@dataclass(frozen=True)
class RSTDPConfig:
    n_inputs: int = 16
    n_neurons: int = 16
    pattern_size: int = 5
    overlap: float = 0.4          # fraction of shared channels (paper: 40%)
    trial_steps: int = 256        # dt steps per trial
    bg_prob: float = 0.008        # background spike prob / channel / dt
    pattern_repeats: int = 4      # pattern burst repetitions per trial
    eta: float = 16.0
    eta_homeo: float = 0.4        # escape term only — must stay well below
                                  # the eligibility term or it pins the
                                  # network at the firing threshold
    gamma: float = 0.3            # paper Eq. 2
    noise: float = 0.1            # random-walk xi (spike-level exploration
                                  # comes from the Poisson background)
    w_init: float = 20.0
    burst_width: int = 2          # consecutive dt steps per pattern burst
    fire_thresh: float = 1.0      # spikes to count as "fired"


class ExperimentState(NamedTuple):
    core: AnnCoreState
    w_signed: jnp.ndarray         # PPU-resident signed weights [.., I, C]
    mean_reward: jnp.ndarray      # [.., C]
    key: jnp.ndarray
    tele: Any = None              # obs.trace.Telemetry counters (None=off;
    #                               an empty pytree slot, so disabled runs
    #                               compile to the exact pre-telemetry
    #                               program)
    routed: Any = None            # wafer mode: [T, K, R] inter-chip events
    #                               the last trial deposited for this one
    #                               (None = single-chip, an empty slot)


def _patterns(ecfg: RSTDPConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Channel sets for patterns A and B with the requested overlap."""
    k = ecfg.pattern_size
    n_shared = int(round(ecfg.overlap * k))
    a = list(range(k))
    b = a[:n_shared] + list(range(k, 2 * k - n_shared))
    mask_a = np.zeros(ecfg.n_inputs, np.float32)
    mask_b = np.zeros(ecfg.n_inputs, np.float32)
    mask_a[a] = 1
    mask_b[b] = 1
    return mask_a, mask_b


def make_experiment(cfg: BSS2Config = None, ecfg: RSTDPConfig = RSTDPConfig(),
                    instance_key=None, prefix=(), backend: str = "auto",
                    kernel_impl: str = "auto", rule_impl: str = "python",
                    vm_executor: str = "auto", block_size: int = None,
                    trace_block: int = None, kernel_block: int = None,
                    sparse_mode: str = None, sparse_threshold: float = None,
                    telemetry: bool = False, wafer: int = None,
                    wafer_topology: str = "all2all", wafer_relay: bool = True,
                    wafer_plan=None, wafer_ctx=None, link_budget: int = None,
                    link_mode: str = "auto", faults=None, blacklist=None):
    """Build the experiment closure set. Returns (init_fn, trial_fn, meta).

    The machine uses 2 rows per input (exc/inh pair, Dale's law: the PPU
    writes |w| to the row matching the sign — paper §5).

    ``backend``/``kernel_impl`` select the AnnCore emulation path (see
    repro.core.anncore): "auto" runs the fused hot path — correlation
    hoisted out of the dt scan, whole-trial synray matmul ("blocked" adds
    the time-blocked neuron window and is the auto pick on TPU) — with
    "oracle" kept as the per-step ground truth. ``block_size`` /
    ``trace_block`` / ``kernel_block`` override the blocked backend's
    time-block lengths (CPU membrane slab, current-trace slab, TPU
    kernel block; whole-experiment scans compose with any block size —
    T need not divide). ``sparse_mode``/``sparse_threshold`` control the
    event-sparse synaptic path ("auto"/"never"/"always" and its density
    gate — bit-identical output either way, see
    ``synapse.synaptic_current_window``).

    ``rule_impl`` selects how the §5 learning rule executes:
      "python"  the rule is the ``_signed_rule`` Python callable (default);
      "vm"      the vector part runs as a PPU-VM *program*
                (``repro.ppuvm.programs.signed_dw_program``) interpreted by
                the fixed-point SIMD executor inside the same jitted trial —
                the paper's hybrid-plasticity story with the rule as
                uploadable software instead of host code. The scalar glue
                (Eq. 2, xi random walk, Dale row rewrite) is identical, so
                the two paths differ only by Q8.8 fixed-point rounding of
                the dw term.

    ``vm_executor`` selects the VM implementation for ``rule_impl="vm"``
    (see ``repro.ppuvm.interp.EXECUTORS``): the default "auto" resolves
    to the trace-time specializer — the program words are a closed-over
    constant of the jitted trial, so the rule compiles to straight-line
    fixed-point ops with zero interpreter dispatch. All executors are
    bit-identical (tests/test_ppuvm_fuzz.py), so this is purely a
    performance axis.

    ``telemetry``: carry a jit-safe ``repro.obs.trace.Telemetry`` counter
    pytree through the training scan (``ExperimentState.tele``): spike /
    event totals, sparse-gate decisions and overflow fallbacks, VM
    saturation-rail hits, and the weight-update magnitude histogram.
    Off (default) the slot is ``None`` — an empty pytree, the compiled
    program is exactly the pre-telemetry one; on/off is bit-identical in
    spikes/weights (telemetry only reads the existing dataflow).

    ``wafer``: partition the experiment over K virtual chips
    (``repro.wafer``): the neuron columns split into K contiguous blocks
    (one per chip — the instance prefix becomes ``(K,)``), all 2I input
    rows are replicated per chip, and an ``InterChipRouter`` closes the
    trial loop — each trial's spikes are broadcast over the bus and
    arrive as relay-row events in the NEXT trial (``wafer_relay``; see
    ``repro.wafer.topology.s5_column_plan``). Mismatch draws, background
    events, and exploration noise are drawn at the MONOLITHIC shapes with
    the monolithic key stream and then rearranged onto the chips, so the
    learning trajectory is bit-identical for every chip count — the
    closed-loop half of the split-vs-monolithic contract. ``wafer_ctx``
    (a ``ShardingCtx``) turns on the shard_map link collectives;
    ``link_budget``/``link_mode`` are the router's bus-budget knobs.

    ``faults``: a ``repro.faults.FaultPlan`` (or sequence) injected into
    the emulated silicon — dead drivers/neurons, stuck weights, CADC
    corruption, VM-store bit-flips, dead/flaky wafer links. ``None`` is
    the identity: the fault-free experiment is the SAME jaxpr as before
    the subsystem existed. ``blacklist``: a ``repro.faults.Blacklist``
    (typically from ``repro.faults.screen``) applied ON TOP of the
    faults as the graceful-degradation reduction — blacklisted rows /
    neurons are masked exactly (``Blacklist.as_faults``), and
    blacklisted LINKS re-route over an intermediate chip
    (``repro.wafer.topology.reroute_plan``; forwarded traffic is counted
    in the ``link_reroutes`` telemetry counter, never silent).

    Args:
      cfg: ``BSS2Config`` chip geometry; ``None`` derives the reduced
        §5 geometry (``2*n_inputs`` rows x ``n_neurons`` cols) from
        ``ecfg``.
      ecfg: ``RSTDPConfig`` — the §5 experiment parameters (patterns,
        trial length, learning rates).
      instance_key: PRNG key for the virtual-instance mismatch draw
        (``None`` = fixed default key).
      prefix: instance-prefix shape for multi-instance fleets; must be
        ``()`` in wafer mode (the prefix becomes ``(K,)``).
      backend: "auto" | "oracle" | "fused" | "blocked" (see above).
      kernel_impl: "auto" | "pallas" | "interpret" | "ref" kernel choice
        for whichever backend runs.
      rule_impl: "python" | "vm" (see above).
      vm_executor: executor for ``rule_impl="vm"`` (see above).
      block_size / trace_block / kernel_block: blocked-backend time
        blocks (see above).
      sparse_mode / sparse_threshold: event-sparse synaptic path gate
        (see above).
      telemetry: thread the jit-safe counter pytree (see above).
      wafer: chip count K (``None`` = single chip).
      wafer_topology: "all2all" | "ring" link graph for the built-in
        §5 split.
      wafer_relay: allow the §5 split's relay rows on ring topologies.
      wafer_plan: explicit validated ``WaferPlan`` replacing the
        built-in ``s5_column_plan`` — the ``repro.mapper`` integration
        point; geometry must match ``(2*n_inputs, n_neurons/K)``.
      wafer_ctx: ``ShardingCtx`` enabling shard_map link collectives.
      link_budget / link_mode: router bus-budget knobs
        (``repro.wafer.router.InterChipRouter``).
      faults: ``FaultPlan`` defect injection (``None`` = same jaxpr).
      blacklist: ``Blacklist`` graceful-degradation reduction.

    Returns:
      ``(init_fn, trial_fn, meta)`` — jit-ready init/trial closures and
      a dict of host-side objects (core, ppu, router, plan, ...).

    Contracts (each enforced by a tier-1 test — see docs/exactness.md):
      backends bit-identical        tests/test_blocked.py
      sparse path bit-identical     tests/test_sparse.py
      VM executors bit-identical    tests/test_ppuvm_fuzz.py
      telemetry on/off identical    tests/test_obs.py
      split == monolithic           tests/test_wafer.py
      faults=None same jaxpr        tests/test_faults.py
      wafer_plan == built-in split  tests/test_mapper.py (TestHybridIntegration)
    """
    if cfg is None:
        cfg = dataclasses.replace(
            BSS2.reduced(), n_rows=2 * ecfg.n_inputs, n_cols=ecfg.n_neurons)
    assert cfg.n_rows == 2 * ecfg.n_inputs and cfg.n_cols == ecfg.n_neurons
    K = wafer
    if K:
        from repro.wafer import InterChipRouter, s5_column_plan
        assert prefix == (), "wafer mode owns the instance prefix"
        assert ecfg.n_neurons % K == 0 and (ecfg.n_neurons // K) % 2 == 0, \
            "need an even per-chip column count (reward parity)"
        c_loc = ecfg.n_neurons // K
        chip_cfg = dataclasses.replace(cfg, n_cols=c_loc)
        prefix = (K,)
        if wafer_plan is not None:
            # a mapper-built (or hand-built) placement replaces the
            # hard-coded §5 column split — any validated WaferPlan with
            # the experiment's per-chip geometry runs here
            plan = wafer_plan
            assert plan.topology.n_chips == K, \
                f"wafer_plan is for {plan.topology.n_chips} chips, wafer={K}"
            assert (plan.n_rows, plan.n_cols) == (2 * ecfg.n_inputs, c_loc), \
                (f"wafer_plan geometry {(plan.n_rows, plan.n_cols)} != "
                 f"{(2 * ecfg.n_inputs, c_loc)}")
        else:
            plan = s5_column_plan(K, ecfg.n_inputs, ecfg.n_neurons,
                                  relay=wafer_relay, kind=wafer_topology)
    else:
        c_loc = ecfg.n_neurons
        chip_cfg = cfg
        plan = None
    mask_a, mask_b = _patterns(ecfg)
    mask_a, mask_b = jnp.asarray(mask_a), jnp.asarray(mask_b)
    even = (jnp.arange(ecfg.n_neurons) % 2 == 0).astype(jnp.float32)
    if K:
        even = even.reshape(K, c_loc)

    if instance_key is None:
        instance_key = jax.random.PRNGKey(7)
    if K:
        # the fleet is ONE partitioned instance: sample the monolithic
        # mismatch realisation, then slice columns per chip / replicate
        # the (shared) row-side parameters
        inst_g = sample_instance(cfg, instance_key, ())
        _cols = lambda x: jnp.reshape(x, (K, c_loc))
        _rows = lambda x: jnp.broadcast_to(x, (K, x.shape[-1]))
        inst = dict(
            neuron_params=jax.tree.map(_cols, inst_g["neuron_params"]),
            weight_gain=_cols(inst_g["weight_gain"]),
            stp_offset=_rows(inst_g["stp_offset"]),
            stp_calib=_rows(inst_g["stp_calib"]),
            cadc_offset=_cols(inst_g["cadc_offset"]),
            cadc_gain=_cols(inst_g["cadc_gain"]))
    else:
        inst = sample_instance(cfg, instance_key, prefix)
    # const_addr: every driver row carries exactly one source here (input i
    # -> rows 2i/2i+1, address 0 throughout), so the fused path may resolve
    # the address-match mask once per trial
    block_kw = {k: v for k, v in dict(
        block_size=block_size, trace_block=trace_block,
        kernel_block=kernel_block, sparse_mode=sparse_mode,
        sparse_threshold=sparse_threshold).items() if v is not None}
    # fault overlay: injection plans first, the blacklist reduction last
    # (its masks dominate the faults they cover — the exactness contract)
    overlay = faults
    if blacklist is not None and blacklist.total:
        from repro.faults import chain as faults_chain
        overlay = faults_chain(
            faults, blacklist.as_faults(inst, cfg.cadc_bits)
            if (blacklist.n_rows or blacklist.n_neurons) else None)
        if blacklist.links:
            assert K, "link blacklists need wafer mode"
            from repro.faults.model import as_plans, remap_link_faults
            from repro.wafer.topology import reroute_plan
            old_links = plan.topology.links()
            plan, _n_re = reroute_plan(plan, blacklist.links)
            new_links = plan.topology.links()
            if new_links != old_links:
                # ring -> all2all promotion re-indexed the link space:
                # carry injected link faults over by pair identity
                overlay = tuple(remap_link_faults(p, old_links, new_links)
                                for p in as_plans(overlay))
    if K:
        router = InterChipRouter(plan, ctx=wafer_ctx,
                                 link_budget=link_budget,
                                 link_mode=link_mode, faults=overlay)
    else:
        router = None
    core = AnnCore(chip_cfg, inst, backend=backend, kernel_impl=kernel_impl,
                   const_addr=True, faults=overlay, **block_kw)
    ppu = VectorUnit(chip_cfg, inst, faults=overlay)

    def init(key) -> ExperimentState:
        st = core.init_state(prefix)
        w0 = ecfg.w_init * jnp.ones((*prefix, ecfg.n_inputs, c_loc))
        st = st._replace(syn=_write_signed(st.syn, w0))
        return ExperimentState(
            core=st, w_signed=w0,
            mean_reward=jnp.zeros((*prefix, c_loc)), key=key,
            tele=obs_trace.init_telemetry() if telemetry else None,
            routed=router.init_buffer(ecfg.trial_steps) if K else None)

    def _write_signed(syn, w_signed):
        w_exc = jnp.clip(w_signed, 0, None)
        w_inh = jnp.clip(-w_signed, 0, None)
        w_rows = jnp.stack([w_exc, w_inh], axis=-3)   # [.., 2, I, C]
        shape = (*w_signed.shape[:-2], 2 * ecfg.n_inputs, c_loc)
        w_rows = w_rows.transpose(
            *range(w_signed.ndim - 2), -2, -3, -1).reshape(shape)
        return syn._replace(weights=synapse.quantize_weight(w_rows))
    _write_signed.__doc__ = "interleave exc/inh rows: row 2i exc, 2i+1 inh"

    # wafer mode: events and exploration noise are DRAWN monolithically
    # (jax.random is shape-dependent, so per-chip draws would break the
    # bit-for-bit chip-count invariance) and then placed onto the chips
    gen_prefix = () if K else prefix

    # burst schedule is static per experiment — precomputed once here, not
    # rebuilt inside every (possibly scanned) trial
    T = ecfg.trial_steps
    _burst_times = np.linspace(T // 8, T - T // 8, ecfg.pattern_repeats,
                               dtype=np.float32).astype(np.int64)
    _dt_to_burst = np.arange(T)[:, None] - _burst_times[None, :]
    is_burst = jnp.asarray(
        np.any((_dt_to_burst >= 0) & (_dt_to_burst < ecfg.burst_width),
               axis=1).astype(np.float32)
        .reshape(T, *([1] * len(gen_prefix)), 1))

    def _gen_events(key, stim):
        """Event stream [T, .., 2I] for stimulus in {0:none, 1:A, 2:B}."""
        kb, kp = jax.random.split(key)
        bg = (jax.random.uniform(kb, (T, *gen_prefix, ecfg.n_inputs))
              < ecfg.bg_prob).astype(jnp.float32)
        # pattern: synchronized bursts on the pattern channels
        pat_mask = jnp.where(stim == 1, mask_a,
                             jnp.where(stim == 2, mask_b,
                                       jnp.zeros_like(mask_a)))
        pat = is_burst * pat_mask.reshape(*([1] * (1 + len(gen_prefix))), -1)
        ch = jnp.clip(bg + pat, 0, 1)
        # input i drives rows 2i (exc) and 2i+1 (inh) with the same events
        ev = jnp.repeat(ch, 2, axis=-1)
        if K:
            # every chip sees the full (replicated) stimulus
            ev = jnp.broadcast_to(ev[:, None, :], (T, K, ev.shape[-1]))
        addr = jnp.zeros(ev.shape, jnp.int8)
        return ev, addr

    def _draw_xi(sub):
        """Exploration noise, monolithic layout in wafer mode: the global
        [I, n_neurons] draw reshaped so chip k's column block c equals
        global column k * c_loc + c."""
        if K:
            g = jax.random.normal(sub, (ecfg.n_inputs, ecfg.n_neurons))
            return ecfg.noise * jnp.transpose(
                g.reshape(ecfg.n_inputs, K, c_loc), (1, 0, 2))
        return ecfg.noise * jax.random.normal(
            sub, (*prefix, ecfg.n_inputs, c_loc))

    def _reward(rates, stim):
        fired = (rates >= ecfg.fire_thresh).astype(jnp.float32)
        own_shown = jnp.where(stim == 1, even,
                              jnp.where(stim == 2, 1.0 - even,
                                        jnp.zeros_like(even)))
        return jnp.where(own_shown > 0, fired, 1.0 - fired)

    if rule_impl == "vm":
        from repro.ppuvm import isa as _visa, programs as _vprog
        _dw_words = jnp.asarray(_vprog.signed_dw_program(
            eta=ecfg.eta, eta_homeo=ecfg.eta_homeo,
            fire_thresh=ecfg.fire_thresh))
    elif rule_impl != "python":
        raise ValueError(f"unknown rule_impl {rule_impl!r}")

    def _vm_signed_update(cs, state, reward, k_rule, tele):
        """§5 rule with the vector part as a PPU-VM program: the program
        computes the per-row dw readout (register 0); the scalar core
        applies it to the PPU-resident signed float weights, adds the xi
        walk, and rewrites both Dale rows — mirroring ``_signed_rule``."""
        qc, qa = ppu.read_correlation(cs.corr)
        mod = jnp.stack([reward - state.mean_reward, reward], axis=0)
        cs2, regs = ppu.run_program(cs, _dw_words, mod=mod,
                                    executor=vm_executor)
        tele = obs_trace.count_vm(tele, regs)
        dw = regs[0][..., 0::2, :].astype(jnp.float32) / _visa.ONE
        key, sub = jax.random.split(k_rule)
        xi = _draw_xi(sub)
        w_signed = jnp.clip(state.w_signed + dw + xi, -45.0, 45.0)
        mean_r = state.mean_reward + ecfg.gamma * (
            reward - state.mean_reward)                         # Eq. 2
        cs2 = cs2._replace(syn=_write_signed(cs2.syn, w_signed))
        obs = dict(causal=qc, acausal=qa)
        return cs2, dict(mean_reward=mean_r, w_signed=w_signed), obs, tele

    def _trial_with(state, stim, ev, addr, k_rule, key_next):
        """Trial body given pregenerated events + keys (shared between the
        per-trial dispatch path and the whole-experiment scan)."""
        if router is not None:
            # close the wafer loop: last trial's routed spikes merge into
            # this trial's inputs, this trial's spikes go on the bus
            cs, core_out = core.run_routed(state.core, state.routed, ev,
                                           addr, router,
                                           telemetry=state.tele)
        else:
            cs, core_out = core.run(state.core, ev, addr,
                                    telemetry=state.tele)
        tele = core_out.get("telemetry")
        rates = cs.rate_counters
        r = _reward(rates, stim)
        tele = obs_trace.count_trial(tele, rates)

        # PPU: R-STDP on the signed PPU weights, using exc-row eligibility
        if rule_impl == "vm":
            cs2, rule_state, obs, tele = _vm_signed_update(
                cs, state, r, k_rule, tele)
        else:
            cs2, rule_state, obs = ppu.apply_rule(
                _signed_rule, cs,
                dict(mean_reward=state.mean_reward, key=k_rule,
                     w_signed=state.w_signed),
                reward=r)
        tele = obs_trace.count_dw(tele, state.w_signed,
                                  rule_state["w_signed"])
        new = ExperimentState(core=cs2, w_signed=rule_state["w_signed"],
                              mean_reward=rule_state["mean_reward"],
                              key=key_next, tele=tele,
                              routed=core_out.get("routed"))
        elig = (obs["causal"][..., 0::2, :]
                - obs["acausal"][..., 0::2, :]).astype(jnp.float32) / 255.0
        metrics = dict(reward=r, mean_reward=rule_state["mean_reward"],
                       rates=rates, stim=stim, elig=elig,
                       w=rule_state["w_signed"])
        return new, metrics

    def trial(state: ExperimentState, stim) -> Tuple[ExperimentState, Dict]:
        """One fused training trial. stim: int32 in {0,1,2} (the PPU's
        simulated environment picks it upstream or it is scanned over)."""
        key, k_ev, k_rule = jax.random.split(state.key, 3)
        ev, addr = _gen_events(k_ev, stim)
        return _trial_with(state, stim, ev, addr, k_rule, key)

    def scanned_training(state: ExperimentState, stims):
        """The whole experiment as ONE program: a lax.scan of trials.

        The per-trial PRNG key chain is replayed up front (exactly the
        stream ``trial`` would consume), so all trials' Poisson background
        events are generated in ONE batched draw instead of T x n_trials
        tiny ones — then the scan body is pure emulation + PPU update.
        Bit-identical to dispatching ``trial`` per trial from Python."""
        n = stims.shape[0]

        def key_body(k, _):
            k2, k_ev, k_rule = jax.random.split(k, 3)
            return k2, (k2, k_ev, k_rule)

        _, (keys_next, k_evs, k_rules) = jax.lax.scan(
            key_body, state.key, None, length=n)
        ev_all, addr_all = jax.vmap(_gen_events)(k_evs, stims)

        def body(st, xs):
            stim, ev, addr, k_rule, key_next = xs
            return _trial_with(st, stim, ev, addr, k_rule, key_next)

        return jax.lax.scan(body, state,
                            (stims, ev_all, addr_all, k_rules, keys_next))

    def _signed_rule(w_rows, obs, rule_state, *, reward):
        """R-STDP on the signed input-level weights; rewrite both rows."""
        causal = obs["causal"][..., 0::2, :]       # exc rows carry the
        acausal = obs["acausal"][..., 0::2, :]     # pre-spike correlations
        elig = (causal - acausal).astype(jnp.float32) / 255.0
        mod = (reward - rule_state["mean_reward"])[..., None, :]
        key, sub = jax.random.split(rule_state["key"])
        xi = _draw_xi(sub)
        dw = ecfg.eta * mod * elig
        # homeostatic punishment (PPU rate counters): firing when the trial
        # earned no reward uniformly depresses the neuron's whole column.
        # Self-limiting: once the neuron only fires on its own pattern,
        # (1 - R) * fired == 0 and the term vanishes. Without it the
        # excitatory drive rails at w_max (see R-STDP bring-up log).
        # fired & unrewarded -> uniform depression; silent & unrewarded
        # (own pattern missed) -> uniform potentiation. Fixed point: fire
        # exactly on the own pattern (then (1-R) == 0 and the term is gone).
        fired = (obs["rates"] >= ecfg.fire_thresh).astype(jnp.float32)
        dw = dw + ecfg.eta_homeo * (
            (1.0 - reward) * (1.0 - 2.0 * fired))[..., None, :]
        w_signed = rule_state["w_signed"] + dw + xi
        w_signed = jnp.clip(w_signed, -45.0, 45.0)
        mean_r = rule_state["mean_reward"] + ecfg.gamma * (
            reward - rule_state["mean_reward"])                 # Eq. 2
        new_syn = _write_signed(
            synapse.SynapseArray(w_rows.astype(jnp.int8),
                                 jnp.zeros_like(w_rows, dtype=jnp.int8)),
            w_signed)
        return new_syn.weights.astype(jnp.float32), dict(
            mean_reward=mean_r, key=key, w_signed=w_signed)

    meta = dict(cfg=cfg, ecfg=ecfg, inst=inst, core=core, ppu=ppu,
                mask_a=mask_a, mask_b=mask_b, even=even,
                scanned_training=scanned_training, router=router)
    return init, trial, meta


def make_scanned_training(scanned_training):
    """Jit the whole-experiment program (``meta["scanned_training"]``):
    ONE dispatch for the full §5 run, state buffers donated, metrics back
    stacked [n_trials, ...] — the machine-model analogue of the paper's
    claim that hybrid plasticity removes the host from the training loop
    entirely."""
    return jax.jit(scanned_training, donate_argnums=(0,))


def run_training(n_trials: int = 300, ecfg: RSTDPConfig = RSTDPConfig(),
                 seed: int = 0, cfg: BSS2Config = None, fused: bool = True,
                 scan: bool = None, backend: str = "auto",
                 rule_impl: str = "python", vm_executor: str = "auto",
                 block_size: int = None, trace_block: int = None,
                 kernel_block: int = None, sparse_mode: str = None,
                 sparse_threshold: float = None, telemetry: bool = False,
                 wafer: int = None, wafer_topology: str = "all2all",
                 wafer_relay: bool = True, wafer_plan=None, wafer_ctx=None,
                 link_budget: int = None, link_mode: str = "auto",
                 faults=None, blacklist=None):
    """Full §5 experiment. Returns the metrics history (stacked).

    Modes:
      fused=True, scan=True   ONE jitted lax.scan over all trials (default)
      fused=True, scan=False  per-trial jit dispatch from a Python loop
                              (the host-dispatch baseline)
      fused=False             host-in-the-loop: observables cross the host
                              boundary every trial (the slow path §5 kills)

    ``telemetry=True`` threads the jit-safe counter pytree through the
    whole run (bit-identical metrics either way) and returns the host
    summary under ``out["telemetry"]``.

    Args:
      n_trials: number of closed-loop trials to run.
      ecfg / cfg: experiment / chip geometry configs (see
        ``make_experiment``).
      seed: derives both the mismatch instance key (``PRNGKey(seed)``)
        and the run key (``PRNGKey(seed + 1)``).
      fused / scan: execution mode (see Modes above).
      backend, rule_impl, vm_executor, block_size, trace_block,
      kernel_block, sparse_mode, sparse_threshold, telemetry, wafer,
      wafer_topology, wafer_relay, wafer_plan, wafer_ctx, link_budget,
      link_mode, faults, blacklist: forwarded verbatim to
        ``make_experiment`` — every knob documented there (and in the
        knob matrix of docs/architecture.md) applies here.

    Returns:
      ``(out, state, meta)``: ``out`` the stacked metrics history
      (``reward``, ``w_signed_final``, optionally ``telemetry``),
      ``state`` the final ``ExperimentState``, ``meta`` the
      ``make_experiment`` host objects.

    Contract pointers: tests/test_rstdp.py (learning curve),
    tests/test_scan_path.py (fused/scan modes bit-identical),
    tests/test_wafer.py (wafer=K trajectory == monolithic),
    tests/test_mapper.py::TestHybridIntegration (explicit wafer_plan).
    """
    init, trial, meta = make_experiment(cfg=cfg, ecfg=ecfg,
                                        instance_key=jax.random.PRNGKey(seed),
                                        backend=backend, rule_impl=rule_impl,
                                        vm_executor=vm_executor,
                                        block_size=block_size,
                                        trace_block=trace_block,
                                        kernel_block=kernel_block,
                                        sparse_mode=sparse_mode,
                                        sparse_threshold=sparse_threshold,
                                        telemetry=telemetry, wafer=wafer,
                                        wafer_topology=wafer_topology,
                                        wafer_relay=wafer_relay,
                                        wafer_plan=wafer_plan,
                                        wafer_ctx=wafer_ctx,
                                        link_budget=link_budget,
                                        link_mode=link_mode,
                                        faults=faults, blacklist=blacklist)
    state = init(jax.random.PRNGKey(seed + 1))
    stims = jnp.asarray(np.resize([1, 2, 0], n_trials), jnp.int32)
    if scan is None:
        scan = fused

    if fused and scan:
        scanned = make_scanned_training(meta["scanned_training"])
        state, hist = scanned(state, stims)
        out = {k: np.asarray(v) for k, v in hist.items()}
    else:
        jtrial = jax.jit(trial)
        hist = []
        for i in range(n_trials):
            if fused:
                state, m = jtrial(state, stims[i])
            else:
                state, m = host_loop_trial(trial, state, stims[i])
            hist.append(m)
        out = {k: np.stack([np.asarray(h[k]) for h in hist])
               for k in hist[0]}
    out["w_signed_final"] = np.asarray(state.w_signed)
    if telemetry:
        out["telemetry"] = obs_trace.summary(state.tele)
    return out, state, meta


def host_loop_trial(trial, state, stim):
    """Host-in-the-loop baseline: every observable crosses the host boundary
    (device_get / device_put) before the update — the slow path the paper's
    hybrid architecture eliminates."""
    state = jax.tree.map(lambda x: jax.device_put(jax.device_get(x)), state)
    new, m = jax.jit(trial)(state, stim)
    m = {k: jax.device_get(v) for k, v in m.items()}
    return new, m


# ---------------------------------------------------------------------------
# Dry-run cell for --arch bss2: pod-scale batched hybrid-plasticity step
# ---------------------------------------------------------------------------

def lower_bss2_cell(shape, ctx, mesh_cfg):
    """Lower the fused trial step for a *fleet* of full-size BSS-2 machine
    instances: instances over the data axes, synapse columns over model.

    This is the scale-up the paper's Discussion anticipates (several
    anncore+PPU blocks per reticle): shape.global_batch independent chips
    learning in parallel, one jitted program.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import SHAPES
    from repro.analysis.roofline import RooflineReport, collective_seconds, \
        parse_collectives, hbm_bytes_estimate

    n_inst = max(shape.global_batch, 16)
    cfg = BSS2  # full-size: 256 rows x 512 cols
    ecfg = RSTDPConfig(n_inputs=cfg.n_rows // 2, n_neurons=cfg.n_cols,
                       pattern_size=24, trial_steps=128)
    # the lowered cell is the production hot path ("auto" = the blocked
    # time-window backend on TPU, fused elsewhere): whole-trial synray
    # matmul + hoisted correlation window + time-blocked neuron scan, all
    # with the instance fleet on the kernels' instance grid axis
    init, trial, meta = make_experiment(cfg=cfg, ecfg=ecfg, prefix=(n_inst,),
                                        backend="auto")

    def batched_trial(state, stim):
        return trial(state, stim)

    mesh = ctx.mesh
    state_abs = jax.eval_shape(init, jax.random.PRNGKey(0))

    def spec_for(path_leaf):
        # instances (leading dim n_inst) over data axes; trailing synapse
        # col dim over model where divisible — the INSTANCE rule is the
        # mesh-side twin of the kernels' instance grid axis
        shp = path_leaf.shape
        if len(shp) >= 1 and shp[0] == n_inst:
            sh = ctx.instance_sharding(shp, cols=cfg.n_cols)
            if sh is not None:
                return sh
        parts = [None] * len(shp)
        if len(shp) >= 1 and shp[-1] == cfg.n_cols:
            parts[-1] = "model"
        return NamedSharding(mesh, P(*parts))

    st_sh = jax.tree.map(spec_for, state_abs)
    with mesh:
        fn = jax.jit(batched_trial,
                     in_shardings=(st_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        lowered = fn.lower(state_abs, jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()

    txt = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):     # older jax returns [dict] per computation
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    colls = parse_collectives(txt)
    hbm = hbm_bytes_estimate(txt)
    # MODEL_FLOPS for the machine model: synapse matmul + neuron updates
    flops_trial = (2 * cfg.n_rows * cfg.n_cols       # event matmul
                   + 40 * cfg.n_cols                 # neuron/corr updates
                   + 4 * cfg.n_rows * cfg.n_cols     # correlation outer
                   ) * ecfg.trial_steps * n_inst
    from repro.config import get_arch
    rep = RooflineReport(
        arch="bss2", shape=shape.name,
        mesh="2x16x16" if mesh_cfg.multi_pod else "16x16",
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        hbm_bytes_per_dev=float(hbm["rw"]), hbm_by_kind=hbm["by_kind"],
        transcendentals=float(ca.get("transcendentals", 0.0)),
        coll=colls, coll_sec=collective_seconds(colls),
        temp_bytes=int(ma.temp_size_in_bytes),
        arg_bytes=int(ma.argument_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        model_flops_global=float(flops_trial),
        n_devices=mesh_cfg.n_devices, step_kind="train")
    return rep, compiled
