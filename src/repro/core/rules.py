"""Plasticity rules executed by the PPU vector unit.

R-STDP (the paper's §5 experiment, Eqs. 2-3):

    <R_i>  <-  <R_i> + gamma (R_i - <R_i>)                      (2)
    dw_ij  =   eta * (R_i - <R_i>) * e_ij + xi_ij               (3)

with e_ij the causal STDP eligibility from the analog correlation sensors
and xi a small random walk. Also provided: plain additive STDP and a
rate-homeostasis rule (both used in tests and ablations).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rstdp(weights, obs, rule_state, *, reward, eta: float = 0.5,
          gamma: float = 0.3, noise: float = 0.3, key=None):
    """Reward-modulated STDP (paper Eqs. 2-3).

    weights: [..., R, C] f32; obs['causal'/'acausal']: [..., R, C] int codes;
    reward: [..., C] instantaneous binary reward per neuron (column);
    rule_state: dict(mean_reward=[..., C], key=PRNGKey).
    """
    mean_r = rule_state["mean_reward"]
    mean_r_new = mean_r + gamma * (reward - mean_r)                   # Eq. 2

    elig = (obs["causal"] - obs["acausal"]).astype(jnp.float32) / 255.0
    mod = (reward - mean_r)[..., None, :]                             # Eq. 3
    key = rule_state["key"]
    key, sub = jax.random.split(key)
    xi = noise * jax.random.normal(sub, weights.shape)
    w_new = weights + eta * mod * elig + xi
    return w_new, dict(mean_reward=mean_r_new, key=key)


def stdp(weights, obs, rule_state, *, eta_plus: float = 0.1,
         eta_minus: float = 0.12):
    """Plain additive STDP from the correlation codes."""
    dw = (eta_plus * obs["causal"].astype(jnp.float32)
          - eta_minus * obs["acausal"].astype(jnp.float32)) / 255.0
    return weights + dw, rule_state


def homeostasis(weights, obs, rule_state, *, target_rate: float,
                eta: float = 0.2):
    """Rate homeostasis: scale a column's weights toward a target rate
    (used in the criticality-tuning style experiments, paper refs [11])."""
    err = (target_rate - obs["rates"])[..., None, :]
    return weights + eta * err, rule_state
