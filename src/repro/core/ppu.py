"""Plasticity Processing Unit — vector-unit semantics (paper §2.2).

The silicon PPU is a Power-ISA scalar core + a SIMD vector unit whose lanes
are hard-wired to synapse-array columns: plasticity kernels read synapse
rows and CADC casuals row-by-row, compute in fixed point, and write 6-bit
weights back through the full-custom SRAM controller.

Here the vector unit is a *row-parallel rule VM*: a plasticity rule is a
pure function over (weights_row, observables_row, rule state) applied to
all rows (and all columns within a row — the lanes) at once. Weight writes
saturate to 6 bit like the hardware store. The paper's hybrid-plasticity
property — learning runs on-device with no host round-trip — corresponds to
the whole (anncore run + PPU update) being ONE jitted program.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cadc, synapse
from repro.configs.bss2 import BSS2Config


class VectorUnit:
    def __init__(self, cfg: BSS2Config, inst: Dict):
        self.cfg = cfg
        self.inst = inst

    # -- observable reads ------------------------------------------------
    def read_correlation(self, corr_state, reset: bool = True):
        """CADC-digitized causal/anti-causal codes [..., R, C] (int32)."""
        oc = self.inst["cadc_offset"][..., None, :]
        gc = self.inst["cadc_gain"][..., None, :]
        qc = cadc.digitize(corr_state.a_causal, offset=oc, gain=gc,
                           bits=self.cfg.cadc_bits, in_scale=8.0)
        qa = cadc.digitize(corr_state.a_acausal, offset=oc, gain=gc,
                           bits=self.cfg.cadc_bits, in_scale=8.0)
        return qc, qa

    def read_rates(self, state):
        return state.rate_counters

    # -- weight write-back -----------------------------------------------
    def write_weights(self, syn: synapse.SynapseArray, w_new
                      ) -> synapse.SynapseArray:
        return syn._replace(weights=synapse.quantize_weight(w_new))

    # -- rule application --------------------------------------------------
    def apply_rule(self, rule: Callable, state, rule_state: Dict, **kw):
        """rule(weights_f32, observables, rule_state, **kw) ->
        (new_weights_f32, new_rule_state). Row-parallel by construction —
        all tensors are [..., R, C]."""
        qc, qa = self.read_correlation(state.corr)
        obs = dict(causal=qc, acausal=qa, rates=self.read_rates(state))
        w = state.syn.weights.astype(jnp.float32)
        w_new, rule_state = rule(w, obs, rule_state, **kw)
        syn = self.write_weights(state.syn, w_new)
        new_state = state._replace(
            syn=syn,
            rate_counters=jnp.zeros_like(state.rate_counters),
            corr=state.corr._replace(
                a_causal=jnp.zeros_like(state.corr.a_causal),
                a_acausal=jnp.zeros_like(state.corr.a_acausal)),
        )
        return new_state, rule_state, obs
