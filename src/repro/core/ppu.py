"""Plasticity Processing Unit — vector-unit semantics (paper §2.2).

The silicon PPU is a Power-ISA scalar core + a SIMD vector unit whose lanes
are hard-wired to synapse-array columns: plasticity kernels read synapse
rows and CADC casuals row-by-row, compute in fixed point, and write 6-bit
weights back through the full-custom SRAM controller.

Here the vector unit is a *row-parallel rule VM*: a plasticity rule is a
pure function over (weights_row, observables_row, rule state) applied to
all rows (and all columns within a row — the lanes) at once. Weight writes
saturate to 6 bit like the hardware store. The paper's hybrid-plasticity
property — learning runs on-device with no host round-trip — corresponds to
the whole (anncore run + PPU update) being ONE jitted program.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cadc, synapse
from repro.configs.bss2 import BSS2Config
from repro.faults import inject as finject


def _to_fixed_j(x):
    """Float -> Q8.8 int32 (traced twin of ``repro.ppuvm.isa.to_fixed``;
    jnp.round and np.round share round-half-even, so host- and
    device-digitized modulators agree bit-exactly)."""
    from repro.ppuvm import isa

    return jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) * isa.ONE),
                    isa.I16MIN, isa.I16MAX).astype(jnp.int32)


class VectorUnit:
    def __init__(self, cfg: BSS2Config, inst: Dict, faults=None):
        self.cfg = cfg
        self.inst = inst
        # Fault overlay (repro.faults) — None is the identity on every
        # hook, so the fault-free VectorUnit traces the same jaxpr.
        self.faults = faults

    # -- observable reads ------------------------------------------------
    def read_correlation(self, corr_state, reset: bool = True):
        """CADC-digitized causal/anti-causal codes [..., R, C] (int32)."""
        oc = self.inst["cadc_offset"][..., None, :]
        gc = self.inst["cadc_gain"][..., None, :]
        qc = cadc.digitize(corr_state.a_causal, offset=oc, gain=gc,
                           bits=self.cfg.cadc_bits, in_scale=8.0)
        qa = cadc.digitize(corr_state.a_acausal, offset=oc, gain=gc,
                           bits=self.cfg.cadc_bits, in_scale=8.0)
        return finject.cadc(self.faults, qc, qa,
                            2 ** self.cfg.cadc_bits - 1)

    def read_rates(self, state):
        return state.rate_counters

    # -- weight write-back -----------------------------------------------
    def write_weights(self, syn: synapse.SynapseArray, w_new
                      ) -> synapse.SynapseArray:
        return syn._replace(weights=synapse.quantize_weight(w_new))

    # -- rule application --------------------------------------------------
    def apply_rule(self, rule: Callable, state, rule_state: Dict, **kw):
        """rule(weights_f32, observables, rule_state, **kw) ->
        (new_weights_f32, new_rule_state). Row-parallel by construction —
        all tensors are [..., R, C]."""
        qc, qa = self.read_correlation(state.corr)
        obs = dict(causal=qc, acausal=qa, rates=self.read_rates(state))
        w = state.syn.weights.astype(jnp.float32)
        w_new, rule_state = rule(w, obs, rule_state, **kw)
        syn = self.write_weights(state.syn, w_new)
        return (self._reset_observables(state._replace(syn=syn)),
                rule_state, obs)

    def _reset_observables(self, state):
        """Post-read reset: rate counters and correlation capacitors."""
        return state._replace(
            rate_counters=jnp.zeros_like(state.rate_counters),
            corr=state.corr._replace(
                a_causal=jnp.zeros_like(state.corr.a_causal),
                a_acausal=jnp.zeros_like(state.corr.a_acausal)),
        )

    # -- programmable rule execution (PPU-VM) -------------------------------
    def run_program(self, state, words, *, mod=None, noise=None,
                    executor: str = "auto"):
        """Execute a PPU-VM program (``repro.ppuvm``) against the machine
        state: the program sees the digitized CADC causal/anti-causal
        codes, the rate counters, optional per-column modulator slots
        (``mod`` [n_mod, ..., C] float) and a per-synapse noise plane
        (``noise`` [..., R, C] float), and may store new 6-bit weights.
        Pure and jit-able — runs inside the fused training scan.

        ``executor`` selects the VM implementation (see
        ``repro.ppuvm.interp.EXECUTORS``): "auto" compiles via the
        trace-time specializer when ``words`` is concrete at jit time
        (host array or closed-over constant) and falls back to the scan
        interpreter when it is traced; "pallas"/"pallas_interpret" run
        the whole program per VMEM tile.

        Returns (new_state, regs): observables are reset like
        ``apply_rule``; ``regs`` is the final [N_REGS, ..., R, C] register
        file (fixed point), the program's scratch readout.
        """
        mod_fp = None if mod is None else _to_fixed_j(mod)
        noise_fp = None if noise is None else _to_fixed_j(noise)
        return self.run_program_fixed(state, words, mod_fp=mod_fp,
                                      noise_fp=noise_fp, executor=executor)

    def run_program_fixed(self, state, words, *, mod_fp=None, noise_fp=None,
                          executor: str = "auto"):
        """Like ``run_program`` but with pre-digitized Q8.8 int32 modulator
        slots / noise plane — the form the playback ``PPU_RUN`` instruction
        carries, so both co-sim backends consume identical integers."""
        from repro.ppuvm import interp

        qc, qa = self.read_correlation(state.corr)
        w_new, regs = interp.run_program(
            jnp.asarray(words), state.syn.weights.astype(jnp.int32), qc, qa,
            state.rate_counters, mod_fp, noise_fp, executor=executor)
        w_new = finject.store(self.faults, w_new)
        syn = state.syn._replace(weights=w_new.astype(jnp.int8))
        return self._reset_observables(state._replace(syn=syn)), regs

    def apply_rstdp_program(self, state, rule_state: Dict, *, reward,
                            program, gamma: float = 0.3,
                            noise: float = 0.3, executor: str = "auto"):
        """R-STDP with the Eq.-3 vector part executed as a PPU-VM
        *program* (``repro.ppuvm.programs.rstdp_program``): the scalar
        prologue (Eq. 2 running mean, PRNG advance) matches
        ``apply_rstdp`` exactly, so the two paths are interchangeable in
        the training scan — the co-development property of §3.1 applied
        to the learning rule itself."""
        mean_r = rule_state["mean_reward"]
        mean_r_new = mean_r + gamma * (reward - mean_r)          # Eq. 2
        mod = (reward - mean_r)[None]                            # slot 0
        key, sub = jax.random.split(rule_state["key"])
        xi = noise * jax.random.normal(sub, state.syn.weights.shape)
        new_state, regs = self.run_program(state, program, mod=mod, noise=xi,
                                           executor=executor)
        return new_state, dict(mean_reward=mean_r_new, key=key), regs

    # -- fused rule application --------------------------------------------
    def apply_rstdp(self, state, rule_state: Dict, *, reward,
                    eta: float = 0.5, gamma: float = 0.3, noise: float = 0.3,
                    impl: str = "auto"):
        """Standard R-STDP (``rules.rstdp`` semantics) with the whole
        read -> eligibility -> update -> write-back inner loop routed
        through the fused ``repro.kernels.ppu_update`` Pallas kernel: CADC
        digitization, eligibility, dw and the saturating 6-bit store happen
        per VMEM tile, exactly like the silicon PPU's row-parallel vector
        loop. ``impl="auto"`` picks the kernel on TPU and the jnp path
        elsewhere (same selection rule as ``kernels/*/ops.py``).

        Scope: this is the kernel route for the STANDARD rule only. The §5
        experiment's Dale-signed rule (repro.core.hybrid) rewrites both
        signed rows from a PPU-resident float state, which the
        fixed-function kernel cannot express — it stays on the generic
        ``apply_rule`` VM path.

        Returns (new_state, new_rule_state, elig) — observables are reset
        like ``apply_rule``.
        """
        mean_r = rule_state["mean_reward"]
        mean_r_new = mean_r + gamma * (reward - mean_r)          # Eq. 2
        mod = reward - mean_r
        key, sub = jax.random.split(rule_state["key"])
        xi = noise * jax.random.normal(sub, state.syn.weights.shape)
        cadc_max = 2 ** self.cfg.cadc_bits - 1
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        if impl == "ref":
            qc, qa = self.read_correlation(state.corr)
            elig = (qc - qa).astype(jnp.float32) / float(cadc_max)
            w_new = (state.syn.weights.astype(jnp.float32)
                     + eta * mod[..., None, :] * elig + xi)      # Eq. 3
            w_q = synapse.quantize_weight(w_new)
        else:
            from repro.kernels.ppu_update import ops as ppu_ops

            def fn(w, ac, aa, off, g, m, x):
                return ppu_ops.rstdp_update(w, ac, aa, off, g, m, x,
                                            eta=eta, cadc_max=cadc_max,
                                            impl=impl)

            for _ in range(state.syn.weights.ndim - 2):
                fn = jax.vmap(fn)
            w_q, elig = fn(state.syn.weights, state.corr.a_causal,
                           state.corr.a_acausal, self.inst["cadc_offset"],
                           self.inst["cadc_gain"], mod, xi)
        new_state = self._reset_observables(
            state._replace(syn=state.syn._replace(weights=w_q)))
        return new_state, dict(mean_reward=mean_r_new, key=key), elig
