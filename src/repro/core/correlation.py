"""Analog correlation sensors in each synapse (paper §2.1).

Each synapse accumulates causal (pre-before-post) and anti-causal traces on
storage capacitors, later digitized by the CADC for hybrid plasticity.

Implementation: exponentially decaying pre/post spike traces; a post spike
adds the row-wise pre-trace to the causal accumulator (outer product), a pre
spike adds the column-wise post-trace to the anti-causal accumulator. This
row x col outer-product accumulate is the second kernel hot-spot
(``repro.kernels.corr``); this module is its jnp oracle.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CorrelationState(NamedTuple):
    trace_pre: jnp.ndarray    # [..., R] presynaptic trace
    trace_post: jnp.ndarray   # [..., C] postsynaptic trace
    a_causal: jnp.ndarray     # [..., R, C] on-capacitor accumulation
    a_acausal: jnp.ndarray    # [..., R, C]


def init_state(shape_prefix, rows, cols) -> CorrelationState:
    z = jnp.zeros
    return CorrelationState(
        trace_pre=z((*shape_prefix, rows), jnp.float32),
        trace_post=z((*shape_prefix, cols), jnp.float32),
        a_causal=z((*shape_prefix, rows, cols), jnp.float32),
        a_acausal=z((*shape_prefix, rows, cols), jnp.float32),
    )


def update(state: CorrelationState, pre_spikes, post_spikes, *,
           tau_pre: float, tau_post: float, dt: float, eta: float = 1.0,
           sat: float = 1023.0) -> CorrelationState:
    """One dt step. pre_spikes: [..., R]; post_spikes: [..., C]."""
    tp = state.trace_pre * jnp.exp(-dt / tau_pre) + pre_spikes
    tq = state.trace_post * jnp.exp(-dt / tau_post) + post_spikes
    # causal: post spike samples the pre trace (outer product)
    a_c = state.a_causal + eta * tp[..., :, None] * post_spikes[..., None, :]
    # anti-causal: pre spike samples the post trace
    a_a = state.a_acausal + eta * pre_spikes[..., :, None] * tq[..., None, :]
    # storage capacitors saturate
    return CorrelationState(
        trace_pre=tp, trace_post=tq,
        a_causal=jnp.minimum(a_c, sat),
        a_acausal=jnp.minimum(a_a, sat),
    )


def window(state: CorrelationState, pre_t, post_t, *, tau_pre: float,
           tau_post: float, dt: float, eta: float = 1.0, sat: float = 1023.0,
           impl: str = "auto") -> CorrelationState:
    """Apply a whole [T, ...] spike window to the sensors in one shot.

    The sensors never feed back into the neuron dynamics within a trial
    (only the PPU reads them), so the per-dt update can be hoisted out of
    the emulation scan and replayed here once. On TPU this routes through
    the fused ``repro.kernels.corr`` Pallas kernel, which keeps each [rb,
    cb] accumulator tile VMEM-resident for the entire window — T x fewer
    HBM round trips than scanning ``update``.

    The ref path computes the trace trajectories with a cheap vector scan
    and the accumulators as ONE matmul over the window with the
    saturation applied afterwards. With non-negative spikes and eta >= 0
    (always true physically — spikes are {0,1}) every per-step increment
    is non-negative, so the running accumulator is monotone and
    post-window clamping equals per-step clamping exactly; any residual
    difference vs the per-step oracle is float reduction order (~1 ulp).

    pre_t: [T, ..., R]; post_t: [T, ..., C]. A leading instance prefix on
    the state maps onto the kernel's instance grid axis (one launch for
    the whole fleet — see ``repro.kernels``).
    """
    kernel_ok = (tau_pre == tau_post) and eta == 1.0
    if impl in ("pallas", "interpret") and not kernel_ok:
        raise NotImplementedError(
            "the corr kernel supports tau_pre == tau_post and eta == 1.0 "
            "only; use impl='auto'/'ref' for other parameters")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl != "ref" and kernel_ok:
        from repro.kernels.corr import ops as corr_ops
        lam = math.exp(-dt / tau_pre)
        ac, aa, tp, tq = corr_ops.correlation_window(
            pre_t, post_t, state.trace_pre, state.trace_post,
            state.a_causal, state.a_acausal, lam=lam, sat=sat, impl=impl)
        return CorrelationState(trace_pre=tp, trace_post=tq,
                                a_causal=ac, a_acausal=aa)

    if eta < 0.0:       # monotonicity argument breaks: exact per-step scan
        def body(s, x):
            p, q = x
            return update(s, p, q, tau_pre=tau_pre, tau_post=tau_post,
                          dt=dt, eta=eta, sat=sat), None
        st, _ = jax.lax.scan(body, state, (pre_t, post_t))
        return st

    def trace(t0, s_t, tau):
        lam_t = jnp.exp(-dt / tau)

        def body(tp, p):
            tp2 = tp * lam_t + p
            return tp2, tp2
        return jax.lax.scan(body, t0, s_t, unroll=8)

    tp_f, tp_t = trace(state.trace_pre, pre_t, tau_pre)
    tq_f, tq_t = trace(state.trace_post, post_t, tau_post)
    # causal: post samples the updated pre trace; anti-causal: pre samples
    # the updated post trace — summed over the window in one contraction
    # instead of T outer-product round trips
    a_c = state.a_causal + eta * jnp.einsum("t...r,t...c->...rc",
                                            tp_t, post_t)
    a_a = state.a_acausal + eta * jnp.einsum("t...r,t...c->...rc",
                                             pre_t, tq_t)
    return CorrelationState(trace_pre=tp_f, trace_post=tq_f,
                            a_causal=jnp.minimum(a_c, sat),
                            a_acausal=jnp.minimum(a_a, sat))
