"""Analog correlation sensors in each synapse (paper §2.1).

Each synapse accumulates causal (pre-before-post) and anti-causal traces on
storage capacitors, later digitized by the CADC for hybrid plasticity.

Implementation: exponentially decaying pre/post spike traces; a post spike
adds the row-wise pre-trace to the causal accumulator (outer product), a pre
spike adds the column-wise post-trace to the anti-causal accumulator. This
row x col outer-product accumulate is the second kernel hot-spot
(``repro.kernels.corr``); this module is its jnp oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class CorrelationState(NamedTuple):
    trace_pre: jnp.ndarray    # [..., R] presynaptic trace
    trace_post: jnp.ndarray   # [..., C] postsynaptic trace
    a_causal: jnp.ndarray     # [..., R, C] on-capacitor accumulation
    a_acausal: jnp.ndarray    # [..., R, C]


def init_state(shape_prefix, rows, cols) -> CorrelationState:
    z = jnp.zeros
    return CorrelationState(
        trace_pre=z((*shape_prefix, rows), jnp.float32),
        trace_post=z((*shape_prefix, cols), jnp.float32),
        a_causal=z((*shape_prefix, rows, cols), jnp.float32),
        a_acausal=z((*shape_prefix, rows, cols), jnp.float32),
    )


def update(state: CorrelationState, pre_spikes, post_spikes, *,
           tau_pre: float, tau_post: float, dt: float, eta: float = 1.0,
           sat: float = 1023.0) -> CorrelationState:
    """One dt step. pre_spikes: [..., R]; post_spikes: [..., C]."""
    tp = state.trace_pre * jnp.exp(-dt / tau_pre) + pre_spikes
    tq = state.trace_post * jnp.exp(-dt / tau_post) + post_spikes
    # causal: post spike samples the pre trace (outer product)
    a_c = state.a_causal + eta * tp[..., :, None] * post_spikes[..., None, :]
    # anti-causal: pre spike samples the post trace
    a_a = state.a_acausal + eta * pre_spikes[..., :, None] * tq[..., None, :]
    # storage capacitors saturate
    return CorrelationState(
        trace_pre=tp, trace_post=tq,
        a_causal=jnp.minimum(a_c, sat),
        a_acausal=jnp.minimum(a_a, sat),
    )
