"""Capacitive memory (analog parameter storage) model.

Each neuron has its own copy of every analog parameter ("massively
integrated analog parameter storage", paper §2.1). Values are stored as
nominal + per-instance deviation; the deviation comes from the fixed-seed
mismatch model in ``repro.verif.mismatch`` (virtual instances, §3.2.2).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.bss2 import BSS2Config

# parameters stored per neuron column (paper: 8 voltages + 16 currents;
# we model the subset that drives the behavioural equations)
NEURON_PARAMS = (
    "g_leak", "e_leak", "v_thres", "e_reset", "v_exp", "delta_t",
    "tau_w", "a", "b", "tau_refrac", "tau_syn_exc", "tau_syn_inh", "c_mem",
)


def nominal(cfg: BSS2Config) -> Dict[str, jnp.ndarray]:
    """Nominal (datasheet) parameter set, broadcast per neuron."""
    n = cfg.n_cols  # neurons == synapse columns
    p = cfg.neuron
    out = {}
    for name in NEURON_PARAMS:
        out[name] = jnp.full((n,), getattr(p, name), jnp.float32)
    return out


def apply_capmem_mismatch(params: Dict[str, jnp.ndarray], key,
                          cfg: BSS2Config) -> Dict[str, jnp.ndarray]:
    """Per-cell storage spread: every capmem cell deviates multiplicatively
    (sigma_capmem) on top of the circuit-specific mismatch terms."""
    sig = cfg.mismatch.sigma_capmem
    keys = jax.random.split(key, len(params))
    out = {}
    for (name, v), k in zip(sorted(params.items()), keys):
        mult = 1.0 + sig * jax.random.normal(k, v.shape)
        # voltages deviate additively (mV), conductances multiplicatively
        if name in ("e_leak", "v_thres", "e_reset", "v_exp"):
            out[name] = v + cfg.mismatch.sigma_v_thres * jax.random.normal(k, v.shape)
        else:
            out[name] = v * mult
    return out
