"""Column-parallel ADC (CADC) model (paper §2.2).

Digitizes analog observables (correlation capacitors, membrane voltages)
column-parallel at 8 bit, with per-column offset and gain mismatch — the
quantities the PPU actually sees. Mismatch terms come from the virtual
instance (repro.verif.mismatch)."""
from __future__ import annotations

import jax.numpy as jnp


def digitize(x, *, offset, gain, bits: int = 8, in_scale: float = 1.0):
    """x: [..., C] or [..., R, C] analog value; offset/gain: [..., C].

    Returns int32 codes in [0, 2^bits - 1].
    """
    lsb = (2 ** bits - 1)
    code = x * (gain * in_scale) + offset
    return jnp.clip(jnp.round(code), 0, lsb).astype(jnp.int32)


def dedigitize(code, *, offset, gain, in_scale: float = 1.0):
    """Inverse transform with the *nominal* calibration (what the PPU's
    calibration table would apply)."""
    return (code.astype(jnp.float32) - offset) / (gain * in_scale)
