"""Short-term plasticity in the synapse drivers (paper §2.1, [45]).

Tsodyks-Markram presynaptic model: virtual neurotransmitter level is a
voltage on a storage capacitor per driver; on each presynaptic event the
available resource R is partially used (utilization u) and the synaptic
current pulse length is modulated accordingly; R recovers with tau_rec.

A mismatch-induced *efficacy offset* per driver models the Fig.-4
distribution; a 4-bit calibration code trims it (repro.verif.calibration).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class STPState(NamedTuple):
    r: jnp.ndarray   # available resources in [0, 1], per driver row [..., R]


def init_state(shape) -> STPState:
    return STPState(r=jnp.ones(shape, jnp.float32))


CALIB_BITS = 4
# Efficacy units per calibration LSB. Sized so the 4-bit trim range
# (±2^3 LSB = ±0.8) covers ~3.2 sigma of the offset distribution
# (sigma_stp_offset = 0.25): that is the very point of the paper's §3.2.2
# pre-tapeout MC verification — pick circuit parameters such that
# calibration can collapse the observed mismatch. (At the previous 0.04 the
# range was ±0.32 ≈ 1.3 sigma and ~20% of drivers were untrimmable; the
# binary search was fine, the DAC range was the bug.)
CALIB_STEP = 0.1


def efficacy_scale(offset, calib_code):
    """The loop-invariant per-row factor of ``efficacy`` (the calibrated
    mismatch term). Precompute once per window and pass as ``scale`` —
    the op tree stays the one ``efficacy`` always computed, so hoisting
    it out of dt scans is bit-exact."""
    trim = (calib_code.astype(jnp.float32) - 2 ** (CALIB_BITS - 1)) * CALIB_STEP
    return 1.0 + offset - trim


def efficacy(state: STPState, spikes, *, u: float, offset=None,
             calib_code=None, scale=None):
    """Efficacy of this step's events (0 where no spike).

    offset: mismatch-induced efficacy offset per row (the Fig.-4 quantity);
    calib_code: int 4-bit trim, efficacy_corr = offset - (code - 8) * step.
    ``scale`` may be passed instead (``efficacy_scale``, hoisted).
    """
    if scale is None:
        scale = efficacy_scale(offset, calib_code)
    eff = u * state.r * scale
    return jnp.clip(eff, 0.0, 1.5) * spikes


def recovery_factor(tau_rec: float, dt: float):
    """The loop-invariant recovery increment of ``update`` (hoistable like
    ``efficacy_scale``)."""
    return 1.0 - jnp.exp(-dt / tau_rec)


def update(state: STPState, spikes, *, u: float, tau_rec: float = None,
           dt: float = None, recovery=None) -> STPState:
    """Resource dynamics: use on spike, recover with tau_rec."""
    if recovery is None:
        recovery = recovery_factor(tau_rec, dt)
    r = state.r + (1.0 - state.r) * recovery
    r = r - u * r * spikes
    return STPState(r=jnp.clip(r, 0.0, 1.0))
