"""The paper's C1 contribution: a behavioural machine model of the
BrainScaleS-2 ASIC — accelerated analog neuromorphic core (AdEx neurons,
6-bit synapse array, short-term plasticity, correlation sensors, CADC)
tightly coupled to a row-parallel plasticity processor (PPU)."""
from repro.core.anncore import AnnCore, AnnCoreState  # noqa: F401
from repro.core.ppu import VectorUnit  # noqa: F401
