"""The assembled analog network core (anncore).

One object holds the full machine state (neurons, synapses, STP, correlation
sensors) and ``run`` integrates it over a time window with ``lax.scan`` —
the accelerated-time emulation. Everything broadcasts over a leading
instance dim, so a *batch of independent chips* (virtual instances for MC
calibration, or parallel experiment seeds) runs as one vectorized program —
that is how the machine model maps onto the TPU mesh (instances over
``data``, synapse columns over ``model``).

Backends
--------
``run`` has two implementations, selected by the ``backend`` constructor
argument (auto-selected like ``repro.kernels/*/ops.py`` selects its impl):

``oracle``
    The literal per-dt scan of ``step``: every timestep recomputes the
    address-match mask, materializes two [.., R, C] correlation
    accumulators, and strided-slices the Dale rows. Ground truth for
    equivalence tests and the host-style baseline.

``fused`` (the ``auto`` default on CPU)
    The hot path. Exploits two structural facts of the machine:
    (1) STP efficacy depends only on the *input* events, so the whole
    efficacy trajectory is precomputed by a cheap [.., R]-wide scan;
    (2) weights/addresses are constant between PPU writes, so the per-step
    masked matmul becomes ONE time-batched event x weight matmul (Dale
    exc/inh rows pre-split once at window entry) routed through the
    ``synray`` Pallas kernel on TPU. The remaining dt scan touches only
    [.., C] neuron state, and the correlation-sensor update — which never
    feeds back into neuron dynamics within a trial — is hoisted out of the
    scan entirely and applied once per window by the fused
    ``correlation_window`` kernel (T x fewer HBM round trips).

``blocked`` (the ``auto`` default on TPU)
    ``fused`` with the last per-dt scan replaced by the time-blocked
    neuron window (``repro.kernels.neuron_scan``): the neuron state
    integrates a whole time block per step — VMEM-resident in the Pallas
    kernel on TPU (no XLA while loop over dts at all, instances on the
    kernel grid), a packed-carry scan over blocks on CPU. Bit-identical
    spikes/records to the oracle: the per-step op trees are shared
    (``adex.integrate_currents``/``membrane_step``), only their schedule
    changes. ``block_size`` tunes the CPU block (default 8, measured on
    the CPU container); ``kernel_block`` the TPU kernel's time block.

``kernel_impl`` forwards to the kernel wrappers: ``auto`` (pallas on TPU,
jnp oracle elsewhere), ``pallas``, ``interpret``, or ``ref``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.bss2 import BSS2Config
from repro.core import adex, correlation, stp, synapse
from repro.faults import inject as finject


class AnnCoreState(NamedTuple):
    neuron: adex.NeuronState
    stp: stp.STPState
    corr: correlation.CorrelationState
    syn: synapse.SynapseArray
    rate_counters: jnp.ndarray    # [..., C] spike counts since last PPU read


class AnnCore:
    """Stateless integrator bound to a config + a virtual instance.

    ``inst`` carries the mismatch realisation (see repro.verif.mismatch):
      neuron_params: dict of [..., C] arrays
      weight_gain:   [..., C]   synaptic DAC gain spread
      stp_offset:    [..., R]   driver efficacy offset (Fig. 4)
      stp_calib:     [..., R]   4-bit trim codes
      cadc_offset/cadc_gain: [..., C]

    ``backend``: "auto" | "oracle" | "fused" | "blocked" (see module
    docstring; "auto" resolves to "blocked" on TPU — the whole-trial
    on-chip path — and "fused" elsewhere).
    ``kernel_impl``: impl forwarded to the Pallas kernel wrappers.
    ``const_addr``: promise that within any one ``run`` window the event
    address on each row never changes (each driver row carries a single
    source, as in the §5 experiment wiring). Lets the fused CPU path
    resolve the address-match mask once per window into an effective
    weight matrix instead of re-deriving it per step.
    ``block_size``/``trace_block``/``kernel_block``: time-block sizes of
    the "blocked" backend (membrane scan slab, current-trace slab, and
    the Pallas kernel's VMEM-resident block).
    ``sparse_mode``: the event-sparse synaptic path of the fused/blocked
    backends — "auto" (default: route windows through the sparse
    gather-accumulate kernel when they provably fit the event capacities,
    dense otherwise — bit-identical either way), "never", or "always"
    (see ``synapse.synaptic_current_window``). ``sparse_threshold`` /
    ``sparse_max_events`` / ``sparse_k_cap`` override the density gate
    and the static stream capacities.
    ``telemetry``: when True, ``run`` threads a jit-safe
    ``repro.obs.trace.Telemetry`` counter pytree (auto-initialized per
    call unless the caller passes one) and returns it under
    ``outputs["telemetry"]`` — spike/event totals plus the synaptic
    routing decisions. Off (the default) compiles to the exact
    pre-telemetry program; on/off outputs are bit-identical.
    ``faults``: a ``repro.faults`` overlay (``None`` | ``FaultPlan`` |
    tuple of plans, injection first, blacklist reduction last) applied
    at the hook sites documented in ``repro.faults.inject``. ``None``
    is the identity on every hook — the same-jaxpr off-path contract —
    and a given overlay produces bit-identical outputs on every backend
    (the hooks sit on backend-shared dataflow).
    """

    def __init__(self, cfg: BSS2Config, inst: Dict, backend: str = "auto",
                 kernel_impl: str = "auto", const_addr: bool = False,
                 block_size: int = 8, trace_block: int = 8,
                 kernel_block: int = 32, sparse_mode: str = "auto",
                 sparse_threshold: float = None,
                 sparse_max_events: int = None, sparse_k_cap: int = None,
                 telemetry: bool = False, faults=None):
        self.cfg = cfg
        self.inst = inst
        if backend == "auto":
            backend = ("blocked" if jax.default_backend() == "tpu"
                       else "fused")
        if backend not in ("oracle", "fused", "blocked"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.kernel_impl = kernel_impl
        self.const_addr = const_addr
        self.block_size = block_size
        self.trace_block = trace_block
        self.kernel_block = kernel_block
        self.sparse_mode = sparse_mode
        self.sparse_threshold = sparse_threshold
        self.sparse_max_events = sparse_max_events
        self.sparse_k_cap = sparse_k_cap
        self.telemetry = telemetry
        self.faults = faults

    def init_state(self, prefix=()) -> AnnCoreState:
        cfg = self.cfg
        r, c = cfg.n_rows, cfg.n_cols
        return AnnCoreState(
            neuron=adex.init_state((*prefix, c), self.inst["neuron_params"]),
            stp=stp.init_state((*prefix, r)),
            corr=correlation.init_state(prefix, r, c),
            syn=synapse.init_array(prefix, r, c),
            rate_counters=jnp.zeros((*prefix, c), jnp.float32),
        )

    def step(self, state: AnnCoreState, row_spikes, row_addr, ext_current=0.0):
        """One dt of the full core (the oracle semantics).

        row_spikes: [..., R] float {0,1} events entering the drivers;
        row_addr:   [..., R] int8 event addresses;
        """
        cfg = self.cfg
        dt = cfg.dt
        row_spikes = finject.rows(self.faults, row_spikes)
        eff = stp.efficacy(state.stp, row_spikes, u=cfg.stp_u,
                           offset=self.inst["stp_offset"],
                           calib_code=self.inst["stp_calib"])
        new_stp = stp.update(state.stp, row_spikes, u=cfg.stp_u,
                             tau_rec=cfg.stp_tau_rec, dt=dt)

        # signed rows: even rows excitatory, odd rows inhibitory (Dale);
        # stuck SRAM cells override the stored weight at the analog read
        w_read = finject.weights(self.faults, state.syn.weights)
        i_cols_exc = synapse.synaptic_current(
            w_read[..., 0::2, :], state.syn.addresses[..., 0::2, :],
            eff[..., 0::2], row_addr[..., 0::2], self.inst["weight_gain"])
        i_cols_inh = synapse.synaptic_current(
            w_read[..., 1::2, :], state.syn.addresses[..., 1::2, :],
            eff[..., 1::2], row_addr[..., 1::2], self.inst["weight_gain"])

        new_neuron, out_spikes = adex.step(
            state.neuron, i_cols_exc * 60.0 + ext_current, i_cols_inh * 60.0,
            self.inst["neuron_params"], dt, adex=cfg.neuron.adex)
        # output-driver faults: hot forces 1, dead forces 0 — BEFORE the
        # sensors and counters; the membrane keeps integrating unmasked
        out_spikes = finject.spikes(self.faults, out_spikes)

        # sensor time constants ~ tau_syn: long traces let consecutive
        # pattern bursts sample each other's post-activity and flip the
        # eligibility sign (measured: elig[A->even] < 0 on A-trials with
        # 4x tau — see EXPERIMENTS.md, R-STDP bring-up log)
        new_corr = correlation.update(
            state.corr, row_spikes, out_spikes,
            tau_pre=cfg.neuron.tau_syn_exc,
            tau_post=cfg.neuron.tau_syn_exc, dt=dt)

        new_state = AnnCoreState(
            neuron=new_neuron, stp=new_stp, corr=new_corr, syn=state.syn,
            rate_counters=state.rate_counters + out_spikes)
        return new_state, out_spikes

    def run(self, state: AnnCoreState, row_spikes_t, row_addr_t,
            record_v: bool = False, unroll: Optional[int] = None,
            telemetry=None):
        """Integrate a [T, ..., R] event stream. Returns (state, outputs).

        outputs: dict(spikes=[T, ..., C], v=[T, ..., C] if record_v,
                      telemetry=Telemetry if threading telemetry)

        ``unroll=None`` picks the backend default: 1 for the oracle (the
        literal reference), 4 for the fused path (its dt-scan body is
        [.., C]-tiny, so moderate unrolling amortizes loop overhead;
        measured best on the CPU container, larger factors only grow the
        compiled loop body past cache).

        ``telemetry``: pass a ``Telemetry`` pytree to accumulate into it
        (the training scan threads it through the carry); ``None``
        auto-initializes a fresh one iff the core was built with
        ``telemetry=True``, else telemetry is off and the emitted program
        is identical to the pre-telemetry one.

        Args:
          state: ``AnnCoreState`` carry (membranes, STP, correlation
            accumulators, synapse array).
          row_spikes_t: [T, ..., R] float driver events (0/1 before STP).
          row_addr_t: [T, ..., R] int8 event addresses.
          record_v: also return the membrane trace (costs memory).
          unroll: dt-scan unroll override (``None`` = backend default).
          telemetry: ``Telemetry`` pytree, or ``None`` (see above).

        Returns:
          ``(state, outputs)`` — outputs as documented above.

        Contract pointers: the three backends are bit-identical
        (tests/test_blocked.py), the dense/sparse synaptic routes are
        bit-identical (tests/test_sparse.py), telemetry on/off is
        bit-identical and off is the same jaxpr (tests/test_obs.py),
        fault injection is backend-invariant (tests/test_faults.py).
        """
        from repro.obs import trace as obs_trace
        if telemetry is None and self.telemetry:
            telemetry = obs_trace.init_telemetry()
        # dead drivers zero their events before EVERY phase (STP, synaptic
        # matmul, correlation pre-traces, telemetry census) — one shared
        # hook site covers all backends; re-application inside the oracle
        # ``step`` is an exact no-op (masking is idempotent)
        row_spikes_t = finject.rows(self.faults, row_spikes_t)
        telemetry = obs_trace.count_faults(telemetry, self.faults)
        if self.backend == "oracle":
            return self._run_oracle(state, row_spikes_t, row_addr_t,
                                    record_v=record_v, unroll=unroll or 1,
                                    telemetry=telemetry)
        return self._run_windowed(state, row_spikes_t, row_addr_t,
                                  record_v=record_v, unroll=unroll or 4,
                                  telemetry=telemetry)

    def run_routed(self, state: AnnCoreState, routed_ev, row_spikes_t,
                   row_addr_t, router, record_v: bool = False,
                   unroll: Optional[int] = None, telemetry=None):
        """One window with the inter-chip router closed around it.

        ``routed_ev`` is the [T, K, R] delivery grid the *previous*
        window's spikes deposited (``repro.wafer.router``): it merges
        into this window's external inputs before integration, and this
        window's output spikes are routed into ``outputs["routed"]`` for
        the next window — the one-window bus-latency budget. With
        telemetry threading, the router's link census lands in the same
        ``outputs["telemetry"]`` pytree as the emulation counters.

        Args:
          state: per-chip ``AnnCoreState`` (instance prefix ``(K,)``).
          routed_ev: [T, K, R] delivery grid from the previous window
            (``router.empty_grid(T)`` for the first).
          row_spikes_t / row_addr_t: [T, K, R] external events as in
            ``run``.
          router: an ``repro.wafer.InterChipRouter``.
          record_v / unroll / telemetry: as in ``run``.

        Returns:
          ``(state, outputs)`` with ``outputs["routed"]`` the next
          window's delivery grid.

        Contract pointers: split == monolithic and transport
        interchangeability live in tests/test_wafer.py; the mapper's
        cross-K round trip (tests/test_mapper.py::TestExactness) runs
        through this entry point via ``repro.wafer.router.run_windows``.
        """
        from repro.obs import trace as obs_trace
        if telemetry is None and self.telemetry:
            telemetry = obs_trace.init_telemetry()
        ev, ad = router.merge(routed_ev, row_spikes_t, row_addr_t)
        state, out = self.run(state, ev, ad, record_v=record_v,
                              unroll=unroll, telemetry=telemetry)
        routed, tele = router.route(out["spikes"],
                                    out.get("telemetry", telemetry),
                                    routed_in=routed_ev)
        out["routed"] = routed
        if tele is not None:
            out["telemetry"] = tele
        return state, out

    def _run_oracle(self, state: AnnCoreState, row_spikes_t, row_addr_t,
                    record_v: bool = False, unroll: int = 1,
                    telemetry=None):
        from repro.obs import trace as obs_trace

        def body(s, xs):
            sp, ad = xs
            s2, out = self.step(s, sp, ad)
            rec = (out, s2.neuron.v) if record_v else (out,)
            return s2, rec

        state, recs = jax.lax.scan(body, state, (row_spikes_t, row_addr_t),
                                   unroll=unroll)
        out = dict(spikes=recs[0])
        if record_v:
            out["v"] = recs[1]
        if telemetry is not None:
            # the oracle routes every step through the per-dt dense matmul
            out["telemetry"] = obs_trace.count_run(
                telemetry, row_spikes_t, recs[0])
        return state, out

    def _window_currents(self, state: AnnCoreState, row_spikes_t,
                         row_addr_t, unroll: int, telemetry=None):
        """Phases 1+2 shared by the fused and blocked backends: the STP
        efficacy trajectory (a cheap [.., R]-wide scan) and the whole
        window's synaptic currents as ONE time-batched event x weight
        matmul with the Dale rows pre-split."""
        cfg = self.cfg
        dt = cfg.dt
        inst = self.inst

        # 1. STP efficacy trajectory: depends only on the input events, so
        #    the whole [T, .., R] trajectory comes out of a cheap scan that
        #    never touches the [.., R, C] synapse array. The calibrated
        #    mismatch scale and the recovery increment are loop-invariant
        #    (bit-exact hoists — same op trees).
        scale = stp.efficacy_scale(inst["stp_offset"], inst["stp_calib"])
        recovery = stp.recovery_factor(cfg.stp_tau_rec, dt)

        def stp_body(s, sp):
            eff = stp.efficacy(s, sp, u=cfg.stp_u, scale=scale)
            return stp.update(s, sp, u=cfg.stp_u, recovery=recovery), eff

        new_stp, eff_t = jax.lax.scan(stp_body, state.stp, row_spikes_t,
                                      unroll=unroll)

        # 2. Dale rows pre-split once per window; synaptic currents for ALL
        #    timesteps in one event x weight matmul (time = batch axis of
        #    the synray kernel).
        syn = state.syn
        gain = inst["weight_gain"]
        w_read = finject.weights(self.faults, syn.weights)
        sparse_kw = dict(sparse=self.sparse_mode,
                         sparse_threshold=self.sparse_threshold,
                         max_events=self.sparse_max_events,
                         k_cap=self.sparse_k_cap)
        i_exc_t = synapse.synaptic_current_window(
            w_read[..., 0::2, :], syn.addresses[..., 0::2, :],
            eff_t[..., 0::2], row_addr_t[..., 0::2], gain,
            impl=self.kernel_impl, const_addr=self.const_addr,
            telemetry=telemetry, **sparse_kw)
        if telemetry is not None:
            i_exc_t, telemetry = i_exc_t
        i_inh_t = synapse.synaptic_current_window(
            w_read[..., 1::2, :], syn.addresses[..., 1::2, :],
            eff_t[..., 1::2], row_addr_t[..., 1::2], gain,
            impl=self.kernel_impl, const_addr=self.const_addr,
            telemetry=telemetry, **sparse_kw)
        if telemetry is not None:
            i_inh_t, telemetry = i_inh_t
        # current scaling vectorized over the whole window, not per step
        return new_stp, i_exc_t * 60.0, i_inh_t * 60.0, telemetry

    def _neuron_window(self, neuron, rate_counters, i_exc_t, i_inh_t,
                       record_v: bool, unroll: int):
        """Phase 3: membrane integration over the pre-fused currents —
        the neuron-only dt scan (fused) or the time-blocked window
        (blocked: a whole block per step, VMEM-resident in the Pallas
        kernel, packed-carry block scan on CPU). Returns
        ``(new_neuron, rate_counters, recs)``."""
        cfg = self.cfg
        if self.backend == "blocked":
            from repro.kernels.neuron_scan import ops as neuron_ops
            return neuron_ops.neuron_window(
                neuron, rate_counters, i_exc_t, i_inh_t,
                self.inst["neuron_params"], dt=cfg.dt,
                use_adex=cfg.neuron.adex, impl=self.kernel_impl,
                block=self.block_size, trace_block=self.trace_block,
                kernel_block=self.kernel_block, record_v=record_v)

        # fused: O(C) per step with the time-invariant decay factors
        # hoisted out of the loop
        dt, inst = cfg.dt, self.inst
        decays = adex.decay_factors(inst["neuron_params"], dt)

        def body(carry, xs):
            n, rc = carry
            ie, ii = xs
            n2, out = adex.step(n, ie, ii, inst["neuron_params"], dt,
                                adex=cfg.neuron.adex, decays=decays)
            rec = (out, n2.v) if record_v else (out,)
            return (n2, rc + out), rec

        (new_neuron, rate_counters), recs = jax.lax.scan(
            body, (neuron, rate_counters), (i_exc_t, i_inh_t),
            unroll=unroll)
        return new_neuron, rate_counters, recs

    def _run_windowed(self, state: AnnCoreState, row_spikes_t, row_addr_t,
                      record_v: bool = False, unroll: int = 1,
                      telemetry=None):
        """The fused/blocked pipeline: window currents (phases 1+2) ->
        neuron window (phase 3) -> hoisted correlation window (phase 4:
        sensors never feed back into the dynamics within a window, so one
        fused kernel call replays the whole T-window per VMEM tile).
        ``repro.obs.timing.profile_phases`` times these same phase
        methods individually."""
        from repro.obs import trace as obs_trace
        cfg = self.cfg
        new_stp, i_exc_t, i_inh_t, telemetry = self._window_currents(
            state, row_spikes_t, row_addr_t, unroll, telemetry)
        new_neuron, rate_counters, recs = self._neuron_window(
            state.neuron, state.rate_counters, i_exc_t, i_inh_t,
            record_v, unroll)
        out_spikes_t = recs[0]
        if self.faults is not None:
            out_spikes_t = finject.spikes(self.faults, out_spikes_t)
            rate_counters = finject.rates(self.faults, rate_counters,
                                          state.rate_counters,
                                          row_spikes_t.shape[0])
        new_corr = correlation.window(
            state.corr, row_spikes_t, out_spikes_t,
            tau_pre=cfg.neuron.tau_syn_exc, tau_post=cfg.neuron.tau_syn_exc,
            dt=cfg.dt, impl=self.kernel_impl)
        new_state = AnnCoreState(neuron=new_neuron, stp=new_stp,
                                 corr=new_corr, syn=state.syn,
                                 rate_counters=rate_counters)
        out = dict(spikes=out_spikes_t)
        if record_v:
            out["v"] = recs[1]
        if telemetry is not None:
            out["telemetry"] = obs_trace.count_run(
                telemetry, row_spikes_t, out_spikes_t)
        return new_state, out
