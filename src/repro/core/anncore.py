"""The assembled analog network core (anncore).

One object holds the full machine state (neurons, synapses, STP, correlation
sensors) and ``run`` integrates it over a time window with ``lax.scan`` —
the accelerated-time emulation. Everything broadcasts over a leading
instance dim, so a *batch of independent chips* (virtual instances for MC
calibration, or parallel experiment seeds) runs as one vectorized program —
that is how the machine model maps onto the TPU mesh (instances over
``data``, synapse columns over ``model``).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.bss2 import BSS2Config
from repro.core import adex, correlation, stp, synapse


class AnnCoreState(NamedTuple):
    neuron: adex.NeuronState
    stp: stp.STPState
    corr: correlation.CorrelationState
    syn: synapse.SynapseArray
    rate_counters: jnp.ndarray    # [..., C] spike counts since last PPU read


class AnnCore:
    """Stateless integrator bound to a config + a virtual instance.

    ``inst`` carries the mismatch realisation (see repro.verif.mismatch):
      neuron_params: dict of [..., C] arrays
      weight_gain:   [..., C]   synaptic DAC gain spread
      stp_offset:    [..., R]   driver efficacy offset (Fig. 4)
      stp_calib:     [..., R]   4-bit trim codes
      cadc_offset/cadc_gain: [..., C]
    """

    def __init__(self, cfg: BSS2Config, inst: Dict):
        self.cfg = cfg
        self.inst = inst

    def init_state(self, prefix=()) -> AnnCoreState:
        cfg = self.cfg
        r, c = cfg.n_rows, cfg.n_cols
        return AnnCoreState(
            neuron=adex.init_state((*prefix, c), self.inst["neuron_params"]),
            stp=stp.init_state((*prefix, r)),
            corr=correlation.init_state(prefix, r, c),
            syn=synapse.init_array(prefix, r, c),
            rate_counters=jnp.zeros((*prefix, c), jnp.float32),
        )

    def step(self, state: AnnCoreState, row_spikes, row_addr, ext_current=0.0):
        """One dt of the full core.

        row_spikes: [..., R] float {0,1} events entering the drivers;
        row_addr:   [..., R] int8 event addresses;
        """
        cfg = self.cfg
        dt = cfg.dt
        eff = stp.efficacy(state.stp, row_spikes, u=cfg.stp_u,
                           offset=self.inst["stp_offset"],
                           calib_code=self.inst["stp_calib"])
        new_stp = stp.update(state.stp, row_spikes, u=cfg.stp_u,
                             tau_rec=cfg.stp_tau_rec, dt=dt)

        # signed rows: even rows excitatory, odd rows inhibitory (Dale)
        i_cols_exc = synapse.synaptic_current(
            state.syn.weights[..., 0::2, :], state.syn.addresses[..., 0::2, :],
            eff[..., 0::2], row_addr[..., 0::2], self.inst["weight_gain"])
        i_cols_inh = synapse.synaptic_current(
            state.syn.weights[..., 1::2, :], state.syn.addresses[..., 1::2, :],
            eff[..., 1::2], row_addr[..., 1::2], self.inst["weight_gain"])

        new_neuron, out_spikes = adex.step(
            state.neuron, i_cols_exc * 60.0 + ext_current, i_cols_inh * 60.0,
            self.inst["neuron_params"], dt, adex=cfg.neuron.adex)

        # sensor time constants ~ tau_syn: long traces let consecutive
        # pattern bursts sample each other's post-activity and flip the
        # eligibility sign (measured: elig[A->even] < 0 on A-trials with
        # 4x tau — see EXPERIMENTS.md, R-STDP bring-up log)
        new_corr = correlation.update(
            state.corr, row_spikes, out_spikes,
            tau_pre=cfg.neuron.tau_syn_exc,
            tau_post=cfg.neuron.tau_syn_exc, dt=dt)

        new_state = AnnCoreState(
            neuron=new_neuron, stp=new_stp, corr=new_corr, syn=state.syn,
            rate_counters=state.rate_counters + out_spikes)
        return new_state, out_spikes

    def run(self, state: AnnCoreState, row_spikes_t, row_addr_t,
            record_v: bool = False, unroll: int = 1):
        """Integrate a [T, ..., R] event stream. Returns (state, outputs).

        outputs: dict(spikes=[T, ..., C], v=[T, ..., C] if record_v)
        """
        def body(s, xs):
            sp, ad = xs
            s2, out = self.step(s, sp, ad)
            rec = (out, s2.neuron.v) if record_v else (out,)
            return s2, rec

        state, recs = jax.lax.scan(body, state, (row_spikes_t, row_addr_t),
                                   unroll=unroll)
        out = dict(spikes=recs[0])
        if record_v:
            out["v"] = recs[1]
        return state, out
