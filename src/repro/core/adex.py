"""AdEx / LIF neuron dynamics (paper §2.1, Eqs. for V and w).

  C dV/dt = -g_L (V - E_L) + g_L Δ_T exp((V - V_T)/Δ_T) - w + I
  τ_w dw/dt = a (V - E_L) - w

Integration: exponential Euler on the leak/adaptation terms, explicit on
the exponential current (clipped — the silicon circuit saturates too).
Spike condition V > V_thres + spike latch -> reset + refractory hold, as in
the full-custom digital neuron backend.

All arrays broadcast over an arbitrary leading instance/batch shape:
states are [..., N] for N neurons.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp


class NeuronState(NamedTuple):
    v: jnp.ndarray           # membrane potential [mV]
    w: jnp.ndarray           # adaptation current [pA]
    i_exc: jnp.ndarray       # excitatory synaptic current state [pA]
    i_inh: jnp.ndarray       # inhibitory synaptic current state [pA]
    refrac: jnp.ndarray      # remaining refractory time [us]


def init_state(shape, params) -> NeuronState:
    # distinct buffers per leaf: a shared zeros array would alias leaves
    # and break buffer donation of the whole state (donate-twice error)
    def z():
        return jnp.zeros(shape, jnp.float32)
    return NeuronState(v=jnp.broadcast_to(params["e_leak"], shape).astype(jnp.float32),
                       w=z(), i_exc=z(), i_inh=z(), refrac=z())


SPIKE_CLAMP = 30.0   # mV above which the exponential term is clamped


def decay_factors(params: Dict, dt: float) -> Dict:
    """Time-invariant per-step decay terms (identical formulas to the ones
    ``step`` computes inline). Precompute once and pass as ``decays`` to
    hoist 4 exps + a division per step out of scan loops."""
    tau_m = params["c_mem"] / params["g_leak"]
    return dict(de=jnp.exp(-dt / params["tau_syn_exc"]),
                di=jnp.exp(-dt / params["tau_syn_inh"]),
                alpha=jnp.exp(-dt / tau_m),
                aw=jnp.exp(-dt / params["tau_w"]))


def step(state: NeuronState, i_syn_exc, i_syn_inh, params: Dict, dt: float,
         adex: bool = True, decays: Dict = None):
    """One dt step. i_syn_*: charge injected this step [pA*us / us = pA].

    Returns (new_state, spikes[...,N] float32 in {0,1}).
    """
    g_l = params["g_leak"]
    if decays is None:
        decays = decay_factors(params, dt)

    # synaptic currents: exponential kernels, pulses add instantaneously
    i_exc = state.i_exc * decays["de"] + i_syn_exc
    i_inh = state.i_inh * decays["di"] + i_syn_inh

    i_total = i_exc - i_inh - state.w

    # exponential escape current (clamped like the saturating circuit)
    if adex:
        arg = jnp.clip((state.v - params["v_thres"]) / params["delta_t"],
                       -20.0, 3.0)
        i_exp = g_l * params["delta_t"] * jnp.exp(arg)
    else:
        i_exp = 0.0

    v_inf = params["e_leak"] + (i_total + i_exp) / g_l
    v = v_inf + (state.v - v_inf) * decays["alpha"]

    # adaptation (exponential Euler towards a(V - E_L))
    w_inf = params["a"] * (state.v - params["e_leak"])
    w = w_inf + (state.w - w_inf) * decays["aw"]

    # refractory clamp
    in_refrac = state.refrac > 0.0
    v = jnp.where(in_refrac, params["e_reset"], v)
    w = jnp.where(in_refrac, state.w, w)

    # spike detection: threshold crossing ends the integration step
    spike_v = params["v_thres"] + jnp.where(adex, 2.0 * params["delta_t"], 0.0)
    spikes = (v > spike_v) & ~in_refrac
    v = jnp.where(spikes, params["e_reset"], v)
    w = jnp.where(spikes, w + params["b"], w)
    refrac = jnp.where(spikes, params["tau_refrac"],
                       jnp.maximum(state.refrac - dt, 0.0))

    new = NeuronState(v=v, w=w, i_exc=i_exc, i_inh=i_inh, refrac=refrac)
    return new, spikes.astype(jnp.float32)
