"""AdEx / LIF neuron dynamics (paper §2.1, Eqs. for V and w).

  C dV/dt = -g_L (V - E_L) + g_L Δ_T exp((V - V_T)/Δ_T) - w + I
  τ_w dw/dt = a (V - E_L) - w

Integration: exponential Euler on the leak/adaptation terms, explicit on
the exponential current (clipped — the silicon circuit saturates too).
Spike condition V > V_thres + spike latch -> reset + refractory hold, as in
the full-custom digital neuron backend.

All arrays broadcast over an arbitrary leading instance/batch shape:
states are [..., N] for N neurons.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp


class NeuronState(NamedTuple):
    v: jnp.ndarray           # membrane potential [mV]
    w: jnp.ndarray           # adaptation current [pA]
    i_exc: jnp.ndarray       # excitatory synaptic current state [pA]
    i_inh: jnp.ndarray       # inhibitory synaptic current state [pA]
    refrac: jnp.ndarray      # remaining refractory time [us]


def init_state(shape, params) -> NeuronState:
    # distinct buffers per leaf: a shared zeros array would alias leaves
    # and break buffer donation of the whole state (donate-twice error)
    def z():
        return jnp.zeros(shape, jnp.float32)
    return NeuronState(v=jnp.broadcast_to(params["e_leak"], shape).astype(jnp.float32),
                       w=z(), i_exc=z(), i_inh=z(), refrac=z())


SPIKE_CLAMP = 30.0   # mV above which the exponential term is clamped


def decay_factors(params: Dict, dt: float) -> Dict:
    """Time-invariant per-step decay terms (identical formulas to the ones
    ``step`` computes inline). Precompute once and pass as ``decays`` to
    hoist 4 exps + a division per step out of scan loops."""
    tau_m = params["c_mem"] / params["g_leak"]
    return dict(de=jnp.exp(-dt / params["tau_syn_exc"]),
                di=jnp.exp(-dt / params["tau_syn_inh"]),
                alpha=jnp.exp(-dt / tau_m),
                aw=jnp.exp(-dt / params["tau_w"]))


def integrate_currents(i_exc, i_inh, i_syn_exc, i_syn_inh, decays: Dict):
    """One dt of the synaptic-current states: exponential kernels, pulses
    add instantaneously. This recurrence is independent of the membrane
    state, so the blocked backend hoists it into a cheap window-wide scan
    (``repro.kernels.neuron_scan``) — the op tree per step is identical to
    the inline computation ``step`` used to do, keeping results bit-exact.
    """
    return (i_exc * decays["de"] + i_syn_exc,
            i_inh * decays["di"] + i_syn_inh)


def membrane_step(v, w, refrac, i_drive, params: Dict, dt: float,
                  adex: bool = True, decays: Dict = None):
    """The sequential membrane core of one dt step.

    ``i_drive`` is the already-integrated net synaptic current
    ``i_exc - i_inh`` (see ``integrate_currents``). Returns
    ``(v, w, refrac, spikes_f32)`` — the op trees are exactly the ones
    ``step`` always computed, so every caller (oracle scan, blocked ref,
    Pallas neuron_scan kernel) produces bit-identical trajectories.
    """
    g_l = params["g_leak"]
    i_total = i_drive - w

    # exponential escape current (clamped like the saturating circuit)
    if adex:
        arg = jnp.clip((v - params["v_thres"]) / params["delta_t"],
                       -20.0, 3.0)
        i_exp = g_l * params["delta_t"] * jnp.exp(arg)
    else:
        i_exp = 0.0

    v_inf = params["e_leak"] + (i_total + i_exp) / g_l
    v_new = v_inf + (v - v_inf) * decays["alpha"]

    # adaptation (exponential Euler towards a(V - E_L))
    w_inf = params["a"] * (v - params["e_leak"])
    w_new = w_inf + (w - w_inf) * decays["aw"]

    # refractory clamp
    in_refrac = refrac > 0.0
    v_new = jnp.where(in_refrac, params["e_reset"], v_new)
    w_new = jnp.where(in_refrac, w, w_new)

    # spike detection: threshold crossing ends the integration step
    spike_v = params["v_thres"] + jnp.where(adex, 2.0 * params["delta_t"], 0.0)
    spikes = (v_new > spike_v) & ~in_refrac
    v_new = jnp.where(spikes, params["e_reset"], v_new)
    w_new = jnp.where(spikes, w_new + params["b"], w_new)
    refrac = jnp.where(spikes, params["tau_refrac"],
                       jnp.maximum(refrac - dt, 0.0))
    return v_new, w_new, refrac, spikes.astype(jnp.float32)


def step(state: NeuronState, i_syn_exc, i_syn_inh, params: Dict, dt: float,
         adex: bool = True, decays: Dict = None):
    """One dt step. i_syn_*: charge injected this step [pA*us / us = pA].

    Returns (new_state, spikes[...,N] float32 in {0,1}).
    """
    if decays is None:
        decays = decay_factors(params, dt)
    i_exc, i_inh = integrate_currents(state.i_exc, state.i_inh,
                                      i_syn_exc, i_syn_inh, decays)
    v, w, refrac, spikes = membrane_step(
        state.v, state.w, state.refrac, i_exc - i_inh, params, dt,
        adex=adex, decays=decays)
    new = NeuronState(v=v, w=w, i_exc=i_exc, i_inh=i_inh, refrac=refrac)
    return new, spikes
