"""Compact event streams for the event-sparse synaptic path.

The chip is event-driven: synapse drivers forward address-matched events,
and the silicon verification budgets the event bus at ~0.4M events/s
(fig8 reproduces ~0.4M events/s on the software path). The dense
emulation nevertheless pays the full [T, R] x [R, C] matmul per window
even when almost no rows fired. This module is the packing layer of the
sparse backend (``repro.kernels.synray_sparse``): a window's [T, R] row
events + per-row event addresses become a compact fixed-capacity stream
of ``(t, row, addr, efficacy)`` records — the software analogue of the
packed event frames SpikeHard's ``dma_controller.v`` streams.

Everything here jits: the capacity ``max_events`` is static and a
validity mask marks the live records. Records are t-major (sorted by
timestep, rows ascending within a step) — the order the event bus would
deliver them, and the order the sparse kernels rely on for bit-exact
accumulation against the dense matmul. ``n_events`` keeps the TRUE
event count even when it exceeds the capacity, so callers can detect
overflow and fall back to the dense path (``synapse.
synaptic_current_window(sparse="auto")`` does exactly that); a stream
packed over capacity silently DROPS the tail records — forcing the
sparse path without the fallback is a broken promise, proven divergent
by the contract test in ``tests/test_sparse.py``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EventStream(NamedTuple):
    """Fixed-capacity window event stream (capacity E = ``t.shape[-1]``)."""
    t: jnp.ndarray         # [E] int32 timestep of each record
    row: jnp.ndarray       # [E] int32 driver row carrying the event
    addr: jnp.ndarray      # [E] int32 6-bit source address of the event
    eff: jnp.ndarray       # [E] float32 STP efficacy forwarded with it
    valid: jnp.ndarray     # [E] bool   live-record mask
    n_events: jnp.ndarray  # [] int32   TRUE count (may exceed capacity)

    @property
    def capacity(self) -> int:
        return self.t.shape[-1]


def pack_events(row_events_t, event_addr_t, max_events: int) -> EventStream:
    """[T, R] events (0 = silent, else efficacy) -> t-major EventStream.

    ``max_events`` is the static stream capacity. Records beyond it are
    dropped (``n_events`` still reports the true count — check
    ``overflowed`` before trusting a forced-sparse result).
    """
    T, R = row_events_t.shape
    flat_eff = row_events_t.reshape(-1).astype(jnp.float32)
    flat_addr = event_addr_t.reshape(-1).astype(jnp.int32)
    fired = flat_eff != 0.0
    # t-major ordinal of every fired slot; silent slots and the overflow
    # tail land on index E (out of bounds -> dropped by the scatters)
    ordinal = jnp.cumsum(fired.astype(jnp.int32)) - 1
    n = jnp.sum(fired.astype(jnp.int32))
    dst = jnp.where(fired & (ordinal < max_events), ordinal, max_events)
    src = jnp.arange(T * R, dtype=jnp.int32)
    z = jnp.zeros((max_events,), jnp.int32)
    t = z.at[dst].set(src // R, mode="drop")
    row = z.at[dst].set(src % R, mode="drop")
    addr = z.at[dst].set(flat_addr, mode="drop")
    eff = jnp.zeros((max_events,), jnp.float32).at[dst].set(flat_eff,
                                                            mode="drop")
    valid = jnp.arange(max_events, dtype=jnp.int32) < n
    return EventStream(t=t, row=row, addr=addr, eff=eff, valid=valid,
                       n_events=n)


def unpack_events(stream: EventStream, T: int, R: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of ``pack_events`` (up to dropped overflow records).

    Returns ``(row_events_t, event_addr_t)``: efficacies scattered back
    onto the [T, R] grid, and the event addresses at fired slots (silent
    slots carry address 0 — the stream only transports addresses WITH
    events, exactly like the hardware bus).
    """
    dst = jnp.where(stream.valid, stream.t * R + stream.row, T * R)
    ev = jnp.zeros((T * R,), jnp.float32).at[dst].set(stream.eff,
                                                      mode="drop")
    ad = jnp.zeros((T * R,), jnp.int32).at[dst].set(stream.addr,
                                                    mode="drop")
    return ev.reshape(T, R), ad.reshape(T, R)


def overflowed(stream: EventStream) -> jnp.ndarray:
    """True when the window produced more events than the capacity."""
    return stream.n_events > stream.capacity


def step_counts(stream: EventStream, T: int) -> jnp.ndarray:
    """[T] record count per timestep of the *stored* records."""
    seg = jnp.where(stream.valid, stream.t, T)
    return jnp.zeros((T + 1,), jnp.int32).at[seg].add(1,
                                                      mode="drop")[:T]


def step_overflowed(stream: EventStream, T: int, k_cap: int) -> jnp.ndarray:
    """True when regrouping at ``k_cap`` would drop records.

    ``overflowed`` only flags *total*-capacity overflow; a stream can fit
    ``max_events`` while a single step holds more than ``k_cap`` records —
    ``regroup_events`` then drops that step's tail silently. This is the
    per-step twin. It also returns True whenever records are already
    missing (``n_events`` exceeds the stored records — total-capacity
    overflow or a ``truncate_stream`` cut): the dropped tail could have
    landed on any step, so the stored per-step counts understate the
    truth.
    """
    missing = stream.n_events > jnp.count_nonzero(
        stream.valid).astype(jnp.int32)
    return missing | (jnp.max(step_counts(stream, T)) > k_cap)


def census_fits(n_events, k_max, max_events: int, k_cap: int) -> jnp.ndarray:
    """The shared no-drop predicate: a window whose event census is
    ``(n_events, k_max)`` packs AND regroups losslessly into capacities
    ``(max_events, k_cap)``. Gates both the density auto-switch
    (``synapse.synaptic_current_window(sparse="auto")``) and the wafer
    router's per-link budget — one definition, so the two fallback paths
    cannot drift apart."""
    return (n_events <= max_events) & (k_max <= k_cap)


# ---------------------------------------------------------------------------
# Batched streams — the inter-chip router's per-link transport
# ---------------------------------------------------------------------------

def pack_events_batch(row_events_bt, event_addr_bt,
                      max_events: int) -> EventStream:
    """[B, T, R] grids -> EventStream with [B, E] leaves ([B] counts).

    One fixed-capacity stream per leading-batch element — the wafer
    router packs one stream per inter-chip link this way."""
    return jax.vmap(pack_events, in_axes=(0, 0, None))(
        row_events_bt, event_addr_bt, max_events)


def unpack_events_batch(stream: EventStream, T: int, R: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of ``pack_events_batch``: [B, E] stream leaves ->
    ([B, T, R] efficacies, [B, T, R] addresses)."""
    return jax.vmap(unpack_events, in_axes=(0, None, None))(stream, T, R)


def truncate_stream(stream: EventStream, T: int,
                    step_budget: int) -> EventStream:
    """Drop records beyond the first ``step_budget`` of each timestep.

    Models a per-step link bandwidth: the kept records stay t-major and
    the stream stays drop-detectable — ``n_events`` is left at the TRUE
    count, so ``step_overflowed`` sees more true records than stored
    ones and reports the cut. Works on single ([E]) and batched
    ([B, E]) streams."""
    e = jnp.arange(stream.capacity, dtype=jnp.int32)
    seg = jnp.where(stream.valid, stream.t, T)

    def _counts(s):
        return jnp.zeros((T + 1,), jnp.int32).at[s].add(1, mode="drop")

    counts = _counts(seg) if seg.ndim == 1 else jax.vmap(_counts)(seg)
    offset = jnp.concatenate(
        [jnp.zeros((*counts.shape[:-1], 1), jnp.int32),
         jnp.cumsum(counts[..., :-1], axis=-1)], axis=-1)
    slot = e - jnp.take_along_axis(
        offset, jnp.clip(stream.t, 0, T), axis=-1)
    keep = stream.valid & (slot < step_budget)
    return stream._replace(eff=jnp.where(keep, stream.eff, 0.0),
                           valid=keep)


def window_stats(row_events_t) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(worst per-instance event count, worst per-instance-step count) of
    a [T, .., R] window — the quantities the density auto-switch gates on.
    Each instance of the prefix packs its own capacity-``max_events``
    stream, so the gate must hold for the worst instance."""
    fired = (row_events_t != 0.0).astype(jnp.int32)
    per_step = jnp.sum(fired, axis=-1)          # [T, ..]
    return jnp.max(jnp.sum(per_step, axis=0)), jnp.max(per_step)


def regroup_events(stream: EventStream, T: int, k_cap: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stream -> per-step [T, K] record grid (K = ``k_cap`` static).

    ``rows_tk/addr_tk/eff_tk``: slot k of step t holds that step's k-th
    event (row-ascending, the stream order); empty slots carry
    ``eff == 0`` so they contribute exactly nothing to the gathered
    reduction. Steps with more than ``k_cap`` events drop the tail —
    the same broken-promise regime as stream overflow, and gated by the
    same auto-switch fallback.
    """
    e = jnp.arange(stream.capacity, dtype=jnp.int32)
    seg = jnp.where(stream.valid, stream.t, T)
    counts = jnp.zeros((T + 1,), jnp.int32).at[seg].add(1)
    offset = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts[:-1])])
    slot = e - offset[jnp.clip(stream.t, 0, T)]
    dst = jnp.where(stream.valid & (slot < k_cap),
                    stream.t * k_cap + slot, T * k_cap)
    zi = jnp.zeros((T * k_cap,), jnp.int32)
    rows_tk = zi.at[dst].set(stream.row, mode="drop").reshape(T, k_cap)
    addr_tk = zi.at[dst].set(stream.addr, mode="drop").reshape(T, k_cap)
    eff_tk = jnp.zeros((T * k_cap,), jnp.float32).at[dst].set(
        stream.eff, mode="drop").reshape(T, k_cap)
    return rows_tk, addr_tk, eff_tk


def default_max_events(T: int, R: int, threshold: float) -> int:
    """Stream capacity implied by a density threshold: the auto-switch
    takes the sparse path only while the window fits, so the capacity IS
    the density gate (rounded up to a lane-friendly multiple of 8)."""
    cap = int(math.ceil(threshold * T * R))
    return max(32, min(T * R, ((cap + 7) // 8) * 8))


def default_k_cap(R: int, threshold: float) -> int:
    """Per-step record capacity: sized for a Bernoulli(threshold) row
    census with generous Poisson headroom, so sub-threshold windows
    essentially never overflow a single step."""
    cap = int(math.ceil(4.0 * threshold * R)) + 4
    return max(8, min(R, ((cap + 3) // 4) * 4))
