"""moonshot-v1-16b-a3b — kimi/Moonlight DeepSeek-style fine-grained MoE.

[moe] 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]

DeepSeek-V3-style details kept: 2 shared experts, first layer dense
(d_ff 11264 = 8 x 1408).
"""
from repro.config import ArchConfig, MoEConfig, register

MOONSHOT_16B_A3B = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # all FFN capacity lives in the MoE config
    vocab=163840,
    rope_theta=50000.0,
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared_experts=2,
        capacity_factor=1.25,
        first_k_dense=1,
        d_ff_dense_first=11264,
    ),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
