"""smollm-360m — llama-arch small dense LM.

[dense] 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.config import ArchConfig, register

SMOLLM_360M = register(ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))
