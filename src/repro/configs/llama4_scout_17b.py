"""llama4-scout-17b-a16e — MoE with top-1 routing + shared expert.

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.config import ArchConfig, MoEConfig, register

LLAMA4_SCOUT_17B = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab=202048,
    rope_theta=500000.0,
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8192,
        n_shared_experts=1,
        capacity_factor=1.25,
    ),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
