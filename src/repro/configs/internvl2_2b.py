"""internvl2-2b — VLM: InternViT frontend (stub) + InternLM2 backbone.

[vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]

The modality frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings at vit_dim=1024 (InternViT-300M output, 256 tokens after pixel
shuffle); the in-model projector (2-layer MLP) maps them into the backbone.
"""
from repro.config import ArchConfig, register

INTERNVL2_2B = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    rope_theta=1000000.0,
    tie_embeddings=True,
    vit_dim=1024,
    n_patches=256,
    source="arXiv:2404.16821; hf",
))
