"""Architecture configs (one module per assigned architecture).

Importing this package registers every config with ``repro.config``.
"""
from repro.configs import (  # noqa: F401
    smollm_360m,
    minitron_4b,
    qwen15_05b,
    phi4_mini_38b,
    internvl2_2b,
    moonshot_16b_a3b,
    llama4_scout_17b,
    hubert_xlarge,
    hymba_15b,
    mamba2_130m,
    bss2,
)
