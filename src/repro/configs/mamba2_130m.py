"""mamba2-130m — attention-free SSM (SSD, state-space duality).

[ssm] 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

Standard mamba2 block: in_proj -> (z, xBC, dt); causal depthwise conv (k=4)
on xBC; SSD chunked recurrence (headdim 64 => 24 heads at expand=2); gated
RMSNorm; out_proj. No attention, no MLP (d_ff=0).
"""
from repro.config import ArchConfig, SSMConfig, register

MAMBA2_130M = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    plasticity_observable="state",
    source="arXiv:2405.21060; unverified",
))
