"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).

[audio] 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

Encoder-only: bidirectional attention, no decode shapes. The conv waveform
frontend is a STUB: ``input_specs()`` supplies precomputed frame embeddings
(dim 512, the w2v2 conv-stack output width). Training objective: masked
unit prediction over the 504-unit codebook.
"""
from repro.config import ArchConfig, register

HUBERT_XLARGE = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    rope_theta=0.0,      # absolute (sinusoidal) positions added at the frontend
    causal=False,
    tie_embeddings=False,
    frame_dim=512,
    source="arXiv:2106.07447; unverified",
))
