"""hymba-1.5b — hybrid: parallel attention + mamba heads per block.

[hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]

Hymba details kept: 128 learnable meta tokens prepended; sliding-window
attention (1024) on all but 3 global-attention layers (first / middle /
last), which keeps the arch sub-quadratic for the 500k-context shape; each
block fuses a parallel SSM path (state 16) with the attention path by
averaging the two normed branch outputs.

Simplification (noted in DESIGN.md): the SSM heads use the SSD (mamba-2
style, scalar dt per head) formulation rather than mamba-1 selective scan —
behaviourally close, and it is the TPU/MXU-friendly matmul form. Cross-layer
KV sharing is not modeled.
"""
from repro.config import ArchConfig, SSMConfig, register

HYMBA_15B = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    rope_theta=10000.0,
    tie_embeddings=True,
    swa_window=1024,
    global_attn_layers=(0, 15, 31),
    n_meta_tokens=128,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=128, n_groups=1,
                  chunk=256),
    plasticity_observable="state",
    source="arXiv:2411.13676; hf",
))
