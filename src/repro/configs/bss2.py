"""bss2 — the paper's own machine: BrainScaleS-2 full-size ASIC model.

512 AdEx neuron circuits, 131072 synapses (256 rows x 512 columns, 4
quadrants), 2 PPUs, CADC per column, analog parameter storage (capmem).
Hardware acceleration factor 1000x vs biology: all time constants below are
in MODEL time (us of emulated hardware time; multiply by 1000 for the
biological equivalent).

This config drives the `repro.core` machine model (the paper's C1
contribution) and is selectable in the dry-run as ``--arch bss2`` — the
lowered program is the fused hybrid-plasticity experiment step, batched over
independent chip instances (data axis) and sharded over synapse columns
(model axis), i.e. the "several anncore+PPU blocks per reticle" scale-up the
paper's discussion section anticipates.
"""
from dataclasses import dataclass, field

from repro.config import ArchConfig, register


@dataclass(frozen=True)
class NeuronParams:
    """AdEx parameters (model-time units: us, nS, pF, mV)."""
    c_mem: float = 200.0          # membrane capacitance [pF]
    g_leak: float = 20.0          # leak conductance [nS] -> tau_m = 10 us
    e_leak: float = -65.0         # leak reversal [mV]
    e_reset: float = -70.0        # reset potential [mV]
    v_thres: float = -50.0        # spike threshold [mV]
    v_exp: float = -54.0          # exponential soft threshold [mV]
    delta_t: float = 2.0          # exponential slope [mV]
    tau_w: float = 100.0          # adaptation time constant [us]
    a: float = 4.0                # subthreshold adaptation [nS]
    b: float = 20.0               # spike-triggered adaptation increment [pA]
    tau_refrac: float = 2.0       # refractory period [us]
    tau_syn_exc: float = 5.0      # excitatory synaptic time constant [us]
    tau_syn_inh: float = 5.0      # inhibitory synaptic time constant [us]
    e_syn_exc: float = 0.0        # only used in COBA mode
    e_syn_inh: float = -80.0
    adex: bool = True             # False -> plain LIF


@dataclass(frozen=True)
class MismatchParams:
    """Transistor-mismatch model for virtual instances (relative sigmas)."""
    sigma_g_leak: float = 0.15
    sigma_tau_syn: float = 0.10
    sigma_v_thres: float = 1.5    # absolute [mV]
    sigma_weight_gain: float = 0.20   # synaptic DAC gain spread
    sigma_stp_offset: float = 0.25    # STP efficacy offset (Fig. 4 target)
    sigma_cadc_offset: float = 4.0    # CADC per-column offset [LSB]
    sigma_cadc_gain: float = 0.05
    sigma_capmem: float = 0.05        # analog parameter storage cell spread


@dataclass(frozen=True)
class BSS2Config:
    name: str = "bss2"
    n_neurons: int = 512
    n_rows: int = 256             # synapse rows (drivers)
    n_cols: int = 512             # synapse columns == neurons
    weight_bits: int = 6
    address_bits: int = 6
    cadc_bits: int = 8
    calib_bits: int = 4           # STP offset calibration code width (Fig. 4)
    dt: float = 0.2               # integration step [us model time]
    speedup: float = 1000.0       # acceleration factor vs biology
    ppu_clock_mhz: float = 400.0  # measured silicon value (paper Sec. 4.5)
    neuron: NeuronParams = field(default_factory=NeuronParams)
    mismatch: MismatchParams = field(default_factory=MismatchParams)
    # STP (Tsodyks-Markram) defaults
    stp_u: float = 0.2            # utilization
    stp_tau_rec: float = 20.0     # recovery time constant [us]

    @property
    def n_synapses(self) -> int:
        return self.n_rows * self.n_cols

    def reduced(self) -> "BSS2Config":
        from dataclasses import replace
        return replace(self, n_neurons=16, n_rows=16, n_cols=16)


BSS2 = BSS2Config()
assert BSS2.n_synapses == 131072  # paper: "512 neurons and 130K synapses"

# Thin ArchConfig shim so `--arch bss2` works in the launcher/dry-run.
BSS2_ARCH = register(ArchConfig(
    name="bss2",
    family="neuromorphic",
    n_layers=1,
    d_model=512,          # neurons
    n_heads=0,
    n_kv_heads=0,
    d_ff=256,             # synapse rows
    vocab=0,
    tie_embeddings=False,
    source="this paper (Gruebl et al. 2020); full-size BSS-2 ASIC",
))
