"""qwen1.5-0.5b — dense LM with QKV bias.

[dense] 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936
[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.config import ArchConfig, register

QWEN15_05B = register(ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab=151936,
    rope_theta=1000000.0,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
))
