"""minitron-4b — pruned Nemotron dense LM.

[dense] 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679; hf]
"""
from repro.config import ArchConfig, register

MINITRON_4B = register(ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2407.14679; hf",
))
