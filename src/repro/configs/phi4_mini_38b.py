"""phi4-mini-3.8b — dense LM, RoPE + SwiGLU + GQA.

[dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064
[arXiv:2412.08905; hf]
"""
from repro.config import ArchConfig, register

PHI4_MINI_38B = register(ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905; hf",
))
