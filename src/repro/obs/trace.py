"""Jit-safe telemetry counters for the emulation stack.

``Telemetry`` is a pytree of scalar counters (plus one fixed-size
histogram) threaded through the training scan as part of the carry. The
contract that makes it free when unused:

  * OFF is ``None``. Every update helper returns ``None`` for ``None``
    input without emitting a single op, so the disabled program is the
    *same jaxpr* as before telemetry existed — zero overhead, zero
    retrace risk, and trivially bit-identical outputs.
  * ON is read-only on the existing dataflow: counters are derived from
    values the emulation already computes (recorded spikes, the sparse
    gate's event census, the VM's returned register file, the rule's
    weight delta). No operand of the original math is touched, so
    spikes/weights/VM state are bit-identical with telemetry on — the
    invariant ``tests/test_obs.py`` asserts with ``assert_array_equal``
    across the fused/blocked/oracle/sparse backends.
  * Shapes are static. Counters are rank-0 ``int32``/``float32`` and the
    weight-update histogram has a fixed bin count, so the pytree carries
    through ``lax.scan`` unchanged regardless of network size, trial
    count, or instance prefix (counters are fleet-wide totals).

Counter catalogue (see README "Observability" for the full matrix):

  steps / trials           integrated dt steps, completed PPU trials
  in_events / out_spikes   nonzero driver events in, neuron spikes out
  rate_total               sum of rate counters at PPU read time
  dense_windows / sparse_windows
                           synaptic-window routing decisions (static
                           routes count too; one window call = one count)
  gated_windows            windows that went through the runtime
                           ``lax.cond`` census gate of ``sparse="auto"``
  overflow_fallbacks       auto-gated windows whose event census did NOT
                           fit the static stream capacities and fell back
                           to dense — the previously *silent* PR 6 path
  census_events_max / census_k_max
                           worst window event count / per-step count the
                           gate measured (capacity headroom indicator)
  routed_events / link_overflows / link_events_max
                           inter-chip events the wafer router placed on
                           the event bus (per-link-deduped records), the
                           number of link exchanges whose census exceeded
                           the per-link budget (compact mode: dropped
                           tails; auto mode: counted dense fallbacks —
                           either way never silent), and the worst
                           per-link event count seen (bus headroom
                           against the ~0.4M events/s budget)
  vm_runs / vm_sat_hits    PPU-VM program executions, and final register
                           lanes resting on the Q8.8 saturation rails
                           (0x7FFF / 0x8000 — fracsat clipping happened)
  dw_updates / dw_abs_max / dw_hist
                           weight-update count, largest |dw| (weight
                           LSBs), and a fixed-bin |dw| magnitude
                           histogram over all synapses and trials
  faults_injected          gauge: active fault SITES of the threaded
                           injection ``FaultPlan`` chain (stuck cells +
                           dead rows/neurons + CADC/store/link faults) —
                           any faulted run announces itself here
  faults_detected / blacklisted_rows
                           gauges: entries of the threaded *blacklist*
                           reduction plan (rows + neurons + links, and
                           the row count alone) — degradation is never
                           silent, same contract as the overflow paths
  link_reroutes            inter-chip events delivered through a
                           failover FORWARD rule (``WaferPlan`` reroute
                           around a dead link) instead of their original
                           route — counts the rerouted bus traffic
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

# |dw| histogram bin edges in weight LSBs: bin 0 is "below one Q8.8 LSB"
# (effectively unchanged), the rest are log2-spaced up to the ±45 clip
# range of the §5 signed weights. searchsorted(E, x) -> bin index.
DW_EDGES = np.asarray([1.0 / 256, 1.0 / 64, 1.0 / 16, 0.25, 0.5,
                       1.0, 2.0, 4.0, 8.0, 16.0, 32.0], np.float32)
DW_BINS = len(DW_EDGES) + 1

_I32_FIELDS = ("steps", "trials", "in_events", "out_spikes",
               "dense_windows", "sparse_windows", "gated_windows",
               "overflow_fallbacks", "census_events_max", "census_k_max",
               "routed_events", "link_overflows", "link_events_max",
               "vm_runs", "vm_sat_hits", "dw_updates",
               "faults_injected", "faults_detected", "blacklisted_rows",
               "link_reroutes")


class Telemetry(NamedTuple):
    steps: jnp.ndarray               # [] i32 integrated dt steps
    trials: jnp.ndarray              # [] i32 completed trials
    in_events: jnp.ndarray           # [] i32 nonzero input row events
    out_spikes: jnp.ndarray          # [] i32 output spikes
    rate_total: jnp.ndarray          # [] f32 rate counters at PPU reads
    dense_windows: jnp.ndarray       # [] i32 windows routed dense
    sparse_windows: jnp.ndarray      # [] i32 windows routed sparse
    gated_windows: jnp.ndarray       # [] i32 runtime census-gated windows
    overflow_fallbacks: jnp.ndarray  # [] i32 census overflow -> dense
    census_events_max: jnp.ndarray   # [] i32 worst gated window events
    census_k_max: jnp.ndarray        # [] i32 worst gated per-step events
    routed_events: jnp.ndarray       # [] i32 inter-chip events routed
    link_overflows: jnp.ndarray      # [] i32 link censuses over budget
    link_events_max: jnp.ndarray     # [] i32 worst per-link event count
    vm_runs: jnp.ndarray             # [] i32 PPU-VM program executions
    vm_sat_hits: jnp.ndarray         # [] i32 register lanes on the rails
    dw_updates: jnp.ndarray          # [] i32 weight-update applications
    faults_injected: jnp.ndarray     # [] i32 gauge: injected fault sites
    faults_detected: jnp.ndarray     # [] i32 gauge: blacklist entries
    blacklisted_rows: jnp.ndarray    # [] i32 gauge: blacklisted rows
    link_reroutes: jnp.ndarray       # [] i32 events on failover forwards
    dw_abs_max: jnp.ndarray          # [] f32 largest |dw| seen (LSBs)
    dw_hist: jnp.ndarray             # [DW_BINS] i32 |dw| histogram


def init_telemetry() -> Telemetry:
    # one DISTINCT zero buffer per field: training donates the scan carry,
    # and donation rejects the same buffer appearing twice in it
    return Telemetry(
        **{f: jnp.array(0, jnp.int32) for f in _I32_FIELDS},
        rate_total=jnp.array(0.0, jnp.float32),
        dw_abs_max=jnp.array(0.0, jnp.float32),
        dw_hist=jnp.zeros((DW_BINS,), jnp.int32))


# ---------------------------------------------------------------------------
# Update helpers — every one is the identity on None (telemetry OFF)
# ---------------------------------------------------------------------------

def count_run(tele: Optional[Telemetry], row_spikes_t, out_spikes_t
              ) -> Optional[Telemetry]:
    """One integrated window: dt steps, input events, output spikes.

    Reads the window's *recorded* inputs/outputs (outside the dt scan),
    so the emulation loop itself is untouched. Totals sum over any
    instance prefix.
    """
    if tele is None:
        return None
    T = row_spikes_t.shape[0]
    return tele._replace(
        steps=tele.steps + jnp.int32(T),
        in_events=tele.in_events
        + jnp.count_nonzero(row_spikes_t).astype(jnp.int32),
        out_spikes=tele.out_spikes
        + jnp.sum(out_spikes_t).astype(jnp.int32))


def count_route(tele: Optional[Telemetry], sparse: bool
                ) -> Optional[Telemetry]:
    """A *statically* routed synaptic window (no runtime gate): the
    ``sparse="never"``/work-floor dense program or forced ``"always"``."""
    if tele is None:
        return None
    if sparse:
        return tele._replace(sparse_windows=tele.sparse_windows + 1)
    return tele._replace(dense_windows=tele.dense_windows + 1)


def count_gate(tele: Optional[Telemetry], fits, n_events, k_max
               ) -> Optional[Telemetry]:
    """One ``sparse="auto"`` census-gate decision: ``fits`` routed sparse,
    ``~fits`` is a capacity-overflow fallback to dense (the event stream
    would have dropped records — PR 6 took this branch silently)."""
    if tele is None:
        return None
    took = fits.astype(jnp.int32)
    return tele._replace(
        gated_windows=tele.gated_windows + 1,
        sparse_windows=tele.sparse_windows + took,
        dense_windows=tele.dense_windows + (1 - took),
        overflow_fallbacks=tele.overflow_fallbacks + (1 - took),
        census_events_max=jnp.maximum(tele.census_events_max,
                                      n_events.astype(jnp.int32)),
        census_k_max=jnp.maximum(tele.census_k_max,
                                 k_max.astype(jnp.int32)))


def count_links(tele: Optional[Telemetry], n_link, fits_link
                ) -> Optional[Telemetry]:
    """One inter-chip routing exchange: ``n_link`` is the per-link event
    census ([L] i32, records after per-link dedup — the counts the bus
    would carry), ``fits_link`` the per-link budget verdict ([L] bool from
    ``events.census_fits``). A link over budget is an overflow: the
    compact transport DROPPED its tail, the auto transport fell back to
    the dense exchange — both land in ``link_overflows``, so the PR 6
    silent-drop regime cannot recur on the wafer bus."""
    if tele is None:
        return None
    n_link = n_link.astype(jnp.int32)
    return tele._replace(
        routed_events=tele.routed_events + jnp.sum(n_link),
        link_overflows=tele.link_overflows
        + jnp.count_nonzero(~fits_link).astype(jnp.int32),
        link_events_max=jnp.maximum(tele.link_events_max,
                                    jnp.max(n_link)))


def count_trial(tele: Optional[Telemetry], rate_counters
                ) -> Optional[Telemetry]:
    """One completed trial; ``rate_counters`` as read by the PPU (before
    the post-read reset)."""
    if tele is None:
        return None
    return tele._replace(
        trials=tele.trials + 1,
        rate_total=tele.rate_total
        + jnp.sum(rate_counters).astype(jnp.float32))


def count_vm(tele: Optional[Telemetry], regs) -> Optional[Telemetry]:
    """One PPU-VM program execution: count final register lanes resting
    on the Q8.8 fracsat rails (0x7FFF / 0x8000) — evidence that the
    saturating arithmetic clipped. Reads the register file the executor
    already returns, so every executor (numpy/scan/specialized/pallas)
    reports identically."""
    if tele is None:
        return None
    from repro.ppuvm import isa
    on_rail = (regs == isa.I16MAX) | (regs == isa.I16MIN)
    return tele._replace(
        vm_runs=tele.vm_runs + 1,
        vm_sat_hits=tele.vm_sat_hits
        + jnp.count_nonzero(on_rail).astype(jnp.int32))


def count_dw(tele: Optional[Telemetry], w_old, w_new
             ) -> Optional[Telemetry]:
    """One weight update: |dw| magnitude histogram over all synapses
    (weight-LSB units; bin edges ``DW_EDGES``)."""
    if tele is None:
        return None
    dw = jnp.abs(jnp.asarray(w_new, jnp.float32)
                 - jnp.asarray(w_old, jnp.float32)).reshape(-1)
    idx = jnp.searchsorted(jnp.asarray(DW_EDGES), dw)
    return tele._replace(
        dw_updates=tele.dw_updates + 1,
        dw_abs_max=jnp.maximum(tele.dw_abs_max, jnp.max(dw)),
        dw_hist=tele.dw_hist.at[idx].add(1))


def count_faults(tele: Optional[Telemetry], faults) -> Optional[Telemetry]:
    """Announce the threaded fault overlays (``repro.faults``): gauges set
    by ``maximum`` so every hook site (AnnCore window, router exchange,
    VM store) reports the same totals without double counting. Injection
    plans land in ``faults_injected`` (their active site count), blacklist
    reduction plans in ``faults_detected``/``blacklisted_rows``. All
    counts are host constants of the plan — identity on ``None`` faults
    AND on ``None`` telemetry, so the off path stays the same jaxpr."""
    if tele is None or faults is None:
        return None if tele is None else tele
    from repro.faults.model import as_plans
    inj = det = rows = 0
    for p in as_plans(faults):
        if p.is_blacklist:
            det += p.total_sites
            rows += p.n_dead_rows
        else:
            inj += p.total_sites
    if inj:
        tele = tele._replace(faults_injected=jnp.maximum(
            tele.faults_injected, jnp.int32(inj)))
    if det:
        tele = tele._replace(
            faults_detected=jnp.maximum(tele.faults_detected,
                                        jnp.int32(det)),
            blacklisted_rows=jnp.maximum(tele.blacklisted_rows,
                                         jnp.int32(rows)))
    return tele


def count_reroutes(tele: Optional[Telemetry], n_fwd) -> Optional[Telemetry]:
    """One routing exchange's failover traffic: ``n_fwd`` is the event
    census of the forward-rule delivery grids (events a ``WaferPlan``
    reroute carried around a dead link). Identity on ``None`` telemetry
    or when the plan has no forward rules (``n_fwd is None``)."""
    if tele is None or n_fwd is None:
        return tele
    return tele._replace(
        link_reroutes=tele.link_reroutes + n_fwd.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Host-side summary
# ---------------------------------------------------------------------------

def summary(tele: Optional[Telemetry]) -> Optional[dict]:
    """Pull the counters to the host as plain Python numbers (the form
    the run report embeds). Pure host-side read — emitting (or not
    emitting) a report never touches the compiled program, which is what
    the zero-retrace test pins down."""
    if tele is None:
        return None
    d = {}
    for k, v in tele._asdict().items():
        a = np.asarray(v)
        d[k] = a.tolist() if a.ndim else a.item()
    d["dw_hist_edges"] = DW_EDGES.tolist()
    return d
