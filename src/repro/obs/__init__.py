"""Chip telemetry: jit-safe counters, phase timing, and run reports.

The source paper's contribution is *verification methodology* — automated
monitoring of the circuits under simulation and emulation (§3). This
package is that methodology applied to the machine model itself: every
silent runtime decision of the emulation stack (sparse-vs-dense gate,
event-stream overflow fallback, VM saturation, specializer cache churn)
becomes an observable counter, every phase a measurable span, and every
run a structured report.

Three layers:

``repro.obs.trace``
    A jit-safe ``Telemetry`` pytree of counters carried through the
    training scan. ``None`` means OFF and compiles to *nothing*: every
    update helper is the identity on ``None``, so the telemetry-off
    program graph is byte-identical to the pre-telemetry one, and
    telemetry on/off is bit-identical in spikes/weights (the counters
    only read the existing dataflow).

``repro.obs.timing``
    Host-side phase profiling: ``block_until_ready``-bracketed spans
    (``PhaseTimer``), per-phase AnnCore profiling (``profile_phases``),
    ``jax.profiler`` trace hooks, and specializer-cache snapshots with
    eviction-storm detection.

``repro.obs.report``
    Structured run reports (JSON + markdown) merging counters, timings,
    cache stats, config, and git SHA.
"""
from repro.obs.trace import Telemetry, init_telemetry, summary  # noqa: F401
