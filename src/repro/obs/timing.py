"""Phase-level timing harness for the emulation stack.

Everything here is HOST-side instrumentation: jitted programs cannot be
timed from inside, so phases are measured by bracketing dispatches with
``jax.block_until_ready`` (async dispatch otherwise attributes a phase's
cost to whoever synchronizes first). Three tools:

``PhaseTimer``
    Accumulating named spans. ``with timer.span("synray") as mark:``
    times the body; register device values with ``mark(x)`` and the span
    blocks on them before reading the clock. ``summary()`` gives
    count/total/mean/best per phase.

``profile_phases``
    Times the AnnCore window phase-by-phase — the STP + synray current
    phase, the neuron integration, and the hoisted correlation window —
    by jitting each phase function separately (the same op trees the
    fused program runs; per-phase dispatch adds overhead, so the split
    is attribution, not an end-to-end time — ``total`` times the real
    fused ``run`` for that).

``profiler_trace`` / ``cache_snapshot`` / ``CacheDelta``
    ``jax.profiler`` trace hook (no-op when unavailable), and
    specializer-cache snapshots with eviction-storm detection: more
    misses than the LRU capacity within one delta means the working set
    thrashes the cache and every upload recompiles.
"""
from __future__ import annotations

import time
import warnings
from contextlib import contextmanager
from typing import Dict, List, Optional

import jax


class PhaseTimer:
    """Accumulating ``block_until_ready``-bracketed named spans."""

    def __init__(self):
        self.samples: Dict[str, List[float]] = {}

    @contextmanager
    def span(self, name: str):
        marks = []
        t0 = time.perf_counter()
        yield marks.append
        if marks:
            jax.block_until_ready(marks)
        self.samples.setdefault(name, []).append(time.perf_counter() - t0)

    def time_fn(self, name: str, fn, *args, iters: int = 1, warmup: int = 1,
                **kw):
        """Time ``fn(*args, **kw)`` ``iters`` times (after ``warmup``
        unrecorded calls — compile + cache fill), recording one span per
        iteration. Returns the last result."""
        out = None
        for _ in range(warmup):
            out = fn(*args, **kw)
            jax.block_until_ready(out)
        for _ in range(iters):
            with self.span(name) as mark:
                out = fn(*args, **kw)
                mark(out)
        return out

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-phase {count, total_us, mean_us, best_us}."""
        out = {}
        for name, ts in self.samples.items():
            out[name] = dict(count=len(ts), total_us=sum(ts) * 1e6,
                             mean_us=sum(ts) / len(ts) * 1e6,
                             best_us=min(ts) * 1e6)
        return out


def profile_phases(core, state, row_spikes_t, row_addr_t,
                   iters: int = 5, timer: Optional[PhaseTimer] = None
                   ) -> Dict[str, Dict[str, float]]:
    """Per-phase timings of one AnnCore window on ``core``'s backend.

    Phases (the fused/blocked pipeline of ``AnnCore._run_windowed``):
      ``synray``  STP efficacy scan + whole-window synaptic currents
      ``neuron``  membrane integration (per-dt scan or time-blocked)
      ``corr``    hoisted correlation-sensor window
      ``total``   the actual fused ``core.run`` dispatch (ground truth —
                  the phase split re-dispatches per phase)
    """
    timer = timer or PhaseTimer()
    unroll = 4

    win = jax.jit(lambda s, ev, ad: core._window_currents(
        s, ev, ad, unroll)[:3])
    _, i_exc_t, i_inh_t = timer.time_fn(
        "synray", win, state, row_spikes_t, row_addr_t, iters=iters)

    neuron = jax.jit(lambda n, rc, ie, ii: core._neuron_window(
        n, rc, ie, ii, record_v=False, unroll=unroll))
    timer.time_fn("neuron", neuron, state.neuron, state.rate_counters,
                  i_exc_t, i_inh_t, iters=iters)

    from repro.core import correlation
    cfg = core.cfg
    corr = jax.jit(lambda c, ev, sp: correlation.window(
        c, ev, sp, tau_pre=cfg.neuron.tau_syn_exc,
        tau_post=cfg.neuron.tau_syn_exc, dt=cfg.dt,
        impl=core.kernel_impl))
    zero_sp = jax.numpy.zeros(
        (*row_spikes_t.shape[:-1], cfg.n_cols), jax.numpy.float32)
    timer.time_fn("corr", corr, state.corr, row_spikes_t, zero_sp,
                  iters=iters)

    total = jax.jit(core.run)
    timer.time_fn("total", total, state, row_spikes_t, row_addr_t,
                  iters=iters)
    return timer.summary()


@contextmanager
def profiler_trace(logdir: Optional[str]):
    """``jax.profiler.trace`` hook: collect a device trace into ``logdir``
    (viewable in TensorBoard / Perfetto). ``None`` — or an unavailable
    profiler — makes this a no-op, so callers can thread a knob through
    unconditionally."""
    if logdir is None:
        yield
        return
    try:
        jax.profiler.start_trace(logdir)
    except Exception:                   # profiler backend unavailable
        yield
        return
    try:
        yield
    finally:
        jax.profiler.stop_trace()


# ---------------------------------------------------------------------------
# Specializer-cache observability
# ---------------------------------------------------------------------------

def cache_snapshot() -> dict:
    """Current ``repro.ppuvm.specialize`` cache stats
    (hits/misses/evictions/size/max_size)."""
    from repro.ppuvm import specialize
    return specialize.cache_stats()


def eviction_storm(delta: dict) -> bool:
    """True when a stats *delta* shows more misses than the LRU capacity:
    the program working set cannot fit, every upload re-specializes, and
    the cache degrades to pure overhead. Raise the cap or deduplicate the
    program stream."""
    return delta.get("misses", 0) > delta.get("max_size", 0) > 0


class CacheDelta:
    """Context manager capturing the specializer-cache stats delta over a
    run; warns on an eviction storm.

        with CacheDelta() as cd: ...
        cd.delta  # {"hits": ..., "misses": ..., "evictions": ...}
    """

    def __init__(self, warn: bool = True):
        self.warn = warn
        self.delta: dict = {}

    def __enter__(self):
        self._before = cache_snapshot()
        return self

    def __exit__(self, *exc):
        after = cache_snapshot()
        self.delta = {k: after[k] - self._before[k]
                      for k in ("hits", "misses", "evictions")}
        self.delta["size"] = after["size"]
        self.delta["max_size"] = after["max_size"]
        if self.warn and eviction_storm(self.delta):
            warnings.warn(
                f"specializer-cache eviction storm: {self.delta['misses']} "
                f"misses / {self.delta['evictions']} evictions exceed the "
                f"LRU capacity ({after['max_size']}) within one run — the "
                "program working set thrashes the cache",
                RuntimeWarning, stacklevel=2)
        return False
