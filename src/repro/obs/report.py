"""Structured run reports: counters + timings + config + provenance.

The commissioning workflow the follow-on paper describes ("From Clean
Room to Machine Room") starts every debugging session from a run report:
what ran, on which commit and backend, what the health counters said,
where the time went. ``build_report`` merges those sections into one
JSON-able dict; ``to_markdown`` renders it for humans; ``write_report``
persists both. ``benchmarks/run.py`` and ``examples/telemetry_report.py``
emit these, and the tier-2 CI job uploads one as a build artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Optional


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """HEAD commit of the repo containing ``cwd`` (default: this file)."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return None


def host_header() -> dict:
    """Provenance header: commit, accelerator, default AnnCore backend
    (reports and BENCH_* files travel across machines)."""
    import jax
    backend = jax.default_backend()
    return dict(git_sha=git_sha(), jax_backend=backend,
                anncore_backend="blocked" if backend == "tpu" else "fused")


def jsonable(x):
    """Best-effort conversion of numpy/jax scalars and containers."""
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    if hasattr(x, "item") and getattr(x, "ndim", 1) == 0:
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    if isinstance(x, (int, float, str, bool)) or x is None:
        return x
    return repr(x)


def _config_section(config) -> Optional[dict]:
    """Dataclass / NamedTuple / dict config -> JSON-able dict."""
    if config is None:
        return None
    import dataclasses
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        return jsonable(dataclasses.asdict(config))
    if hasattr(config, "_asdict"):
        return jsonable(config._asdict())
    if isinstance(config, dict):
        return jsonable(config)
    return {"repr": repr(config)}


def build_report(label: str, telemetry: Optional[dict] = None,
                 timings: Optional[dict] = None,
                 cache: Optional[dict] = None,
                 config=None, extra: Optional[dict] = None) -> dict:
    """Merge one run's observability sections into a report dict.

    ``telemetry``: ``repro.obs.trace.summary`` output; ``timings``:
    ``PhaseTimer.summary`` output; ``cache``: specializer-cache stats or
    a ``CacheDelta.delta``; ``config``: any dataclass/NamedTuple/dict.
    Health warnings (overflow fallbacks, saturation, eviction storms)
    are derived here so every emitter surfaces them uniformly.
    """
    from repro.obs.timing import eviction_storm

    report = dict(label=label,
                  timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                  **host_header())
    warnings = []
    if telemetry is not None:
        report["telemetry"] = jsonable(telemetry)
        if telemetry.get("overflow_fallbacks", 0) > 0:
            warnings.append(
                f"{telemetry['overflow_fallbacks']} sparse-gate capacity "
                f"overflow(s) fell back to dense (census max "
                f"{telemetry.get('census_events_max')} events) — raise "
                "sparse_max_events/sparse_threshold to keep the sparse "
                "path")
        if telemetry.get("vm_sat_hits", 0) > 0:
            warnings.append(
                f"{telemetry['vm_sat_hits']} PPU-VM register lanes ended "
                "on the Q8.8 saturation rails (0x7FFF/0x8000) — the rule "
                "clips; rescale its operands if unintended")
    if timings is not None:
        report["timings"] = jsonable(timings)
    if cache is not None:
        report["specialize_cache"] = jsonable(cache)
        if eviction_storm(cache):
            warnings.append(
                f"specializer-cache eviction storm: {cache['misses']} "
                f"misses exceed the LRU capacity ({cache['max_size']}) "
                "within this run")
    if config is not None:
        report["config"] = _config_section(config)
    if extra:
        report["extra"] = jsonable(extra)
    report["warnings"] = warnings
    return report


def to_markdown(report: dict) -> str:
    """Human-readable rendering of ``build_report`` output."""
    lines = [f"# Run report — {report.get('label', '?')}", ""]
    lines.append(f"- timestamp: `{report.get('timestamp')}`")
    lines.append(f"- git: `{report.get('git_sha')}`")
    lines.append(f"- jax backend: `{report.get('jax_backend')}` "
                 f"(anncore `{report.get('anncore_backend')}`)")
    for w in report.get("warnings", []):
        lines.append(f"- **WARNING**: {w}")
    tele = report.get("telemetry")
    if tele:
        lines += ["", "## Counters", "", "| counter | value |",
                  "|---|---|"]
        hist_keys = ("dw_hist", "dw_hist_edges")
        for k, v in tele.items():
            if k not in hist_keys:
                lines.append(f"| {k} | {v} |")
        if "dw_hist" in tele:
            edges = tele.get("dw_hist_edges", [])
            labels = (["<%g" % edges[0]]
                      + ["≥%g" % e for e in edges]) if edges else []
            pairs = ", ".join(f"{l}:{n}" for l, n in
                              zip(labels, tele["dw_hist"]) if n)
            lines.append(f"| dw_hist (\\|dw\\| LSBs) | {pairs or '0'} |")
    tim = report.get("timings")
    if tim:
        lines += ["", "## Phase timings", "",
                  "| phase | mean us | best us | calls |", "|---|---|---|---|"]
        for name, s in tim.items():
            lines.append(f"| {name} | {s['mean_us']:.1f} | "
                         f"{s['best_us']:.1f} | {s['count']} |")
    cache = report.get("specialize_cache")
    if cache:
        lines += ["", "## Specializer cache", "",
                  "| hits | misses | evictions | size/cap |", "|---|---|---|---|"]
        lines.append(f"| {cache.get('hits')} | {cache.get('misses')} | "
                     f"{cache.get('evictions')} | {cache.get('size')}/"
                     f"{cache.get('max_size')} |")
    cfgs = report.get("config")
    if cfgs:
        lines += ["", "## Config", "", "```json",
                  json.dumps(cfgs, indent=1, default=repr), "```"]
    extra = report.get("extra")
    if extra:
        lines += ["", "## Extra", "", "```json",
                  json.dumps(extra, indent=1, default=repr), "```"]
    return "\n".join(lines) + "\n"


def write_report(report: dict, json_path: str,
                 md_path: Optional[str] = None) -> dict:
    """Persist the report (JSON always; markdown beside it unless given).
    Returns ``{"json": path, "md": path}``."""
    os.makedirs(os.path.dirname(os.path.abspath(json_path)), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1, default=repr)
    if md_path is None:
        md_path = os.path.splitext(json_path)[0] + ".md"
    with open(md_path, "w") as f:
        f.write(to_markdown(report))
    return dict(json=json_path, md=md_path)
