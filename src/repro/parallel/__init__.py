from repro.parallel.sharding import (  # noqa: F401
    Ax, ShardingCtx, ParamDecl, init_params, abstract_params, tree_pspecs,
)
