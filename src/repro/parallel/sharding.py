"""Logical-axis sharding system.

Every parameter and activation dimension carries a *logical* axis name; two
rule tables (params vs activations) map logical axes onto mesh axes. This is
the single source of truth for the distribution strategy:

  * params:  FSDP over ``data`` (embed dim) x tensor-parallel over ``model``
             (ff / heads_out / vocab / expert dims)  => 256-way param sharding.
  * acts:    batch over the data axes (incl. ``pod`` in multi-pod), sequence
             over ``model`` at block boundaries (Megatron-SP) and inside
             attention (context parallel).

The ``ShardingCtx`` degrades gracefully: with ``mesh=None`` every constraint
is the identity, so the same model code runs in single-device smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PSpec

from repro.config import MeshConfig


class Ax:
    """Logical axis vocabulary."""
    # activation axes
    BATCH = "batch"
    SEQ = "seq"            # activation sequence (CP/SP sharded)
    KV_SEQ = "kv_seq"      # KV-cache sequence
    EMBED_ACT = "embed_act"
    HEADS_ACT = "heads_act"
    VOCAB_ACT = "vocab_act"
    EXPERT_ACT = "expert_act"
    DP_GROUP = "dp_group"  # leading MoE dispatch-group dim
    # param axes
    EMBED = "embed"        # FSDP dim
    FF = "ff"
    HEADS_OUT = "heads_out"
    VOCAB = "vocab"
    EXPERT = "expert"
    # neuromorphic axes (BSS-2 machine model)
    NRN = "neuron"         # synapse columns / neurons
    ROW = "row"            # synapse rows / drivers
    INSTANCE = "instance"  # independent chip instances (batch of networks)
    NONE = None


def _rules(mesh_cfg: MeshConfig):
    data_axes = mesh_cfg.data_axes          # ("data",) or ("pod","data")
    param_rules = {
        Ax.EMBED: "data",                   # FSDP: never crosses pods
        Ax.FF: "model",
        Ax.HEADS_OUT: "model",
        Ax.VOCAB: "model",
        Ax.EXPERT: "model",
        Ax.NRN: "model",
        Ax.ROW: None,
        # buffer-like decls (KV caches, optimizer state aliases, machine state)
        Ax.BATCH: data_axes,
        Ax.KV_SEQ: "model",
        Ax.INSTANCE: data_axes,
    }
    act_rules = {
        Ax.BATCH: data_axes,
        Ax.SEQ: "model",
        Ax.KV_SEQ: "model",
        Ax.EMBED_ACT: None,
        Ax.HEADS_ACT: None,
        Ax.VOCAB_ACT: "model",
        Ax.EXPERT_ACT: "model",
        Ax.DP_GROUP: data_axes,
        Ax.NRN: "model",
        Ax.ROW: None,
        Ax.INSTANCE: data_axes,
    }
    return param_rules, act_rules


@dataclass
class ShardingCtx:
    """Carries mesh + rules + dtype policy through model code."""
    mesh: Optional[Mesh] = None
    mesh_cfg: MeshConfig = field(default_factory=MeshConfig)
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    # dry-run mode: python-unroll every inner loop so HLO cost analysis is
    # exact (a `while` body is costed once by XLA).
    unroll: bool = False
    overrides: dict = field(default_factory=dict)  # hillclimb knobs

    def __post_init__(self):
        self.param_rules, self.act_rules = _rules(self.mesh_cfg)
        self.param_rules.update(self.overrides.get("param_rules", {}))
        self.act_rules.update(self.overrides.get("act_rules", {}))

    # -- spec builders -------------------------------------------------------
    def _axis_size(self, mesh_axis) -> int:
        if self.mesh is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if isinstance(mesh_axis, (tuple, list)):
            n = 1
            for a in mesh_axis:
                n *= sizes[a]
            return n
        return sizes[mesh_axis]

    def _pspec(self, axes, rules, shape=None) -> PSpec:
        """Map logical axes -> mesh axes, dropping mappings the dim size
        cannot be evenly split over (e.g. batch=1 long-context cells)."""
        parts = []
        for i, ax in enumerate(axes):
            r = rules.get(ax, None) if ax is not None else None
            if r is not None and shape is not None:
                if shape[i] % self._axis_size(r) != 0:
                    r = None
            parts.append(tuple(r) if isinstance(r, list) else r)
        return PSpec(*parts)

    def param_pspec(self, axes, shape=None) -> PSpec:
        return self._pspec(axes, self.param_rules, shape)

    def act_pspec(self, axes, shape=None) -> PSpec:
        return self._pspec(axes, self.act_rules, shape)

    def param_sharding(self, axes, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.param_pspec(axes, shape))

    def act_sharding(self, axes, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.act_pspec(axes, shape))

    # -- activation constraint ----------------------------------------------
    def constrain(self, x, *axes):
        """with_sharding_constraint by logical axes (identity without mesh)."""
        if self.mesh is None:
            return x
        assert len(axes) == x.ndim, (axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.act_pspec(axes, x.shape)))

    @property
    def dp_size(self) -> int:
        """Number of data-parallel groups (for MoE dispatch grouping)."""
        if self.mesh is None:
            return 1
        n = 1
        for ax in self.mesh_cfg.data_axes:
            n *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[ax]
        return n

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))["model"]

    def cast(self, p):
        """Cast a param to the compute dtype."""
        return p.astype(self.compute_dtype) if p.dtype != self.compute_dtype else p

    # -- machine-model instance axis -----------------------------------------
    def instance_sharding(self, shape, cols: Optional[int] = None
                          ) -> Optional[NamedSharding]:
        """Sharding for a machine-state leaf of the BSS-2 fleet: a leading
        ``Ax.INSTANCE`` dim over the data axes, a trailing synapse-column
        dim over ``model`` when divisible.

        This is the mesh-side twin of the kernels' instance **grid** axis
        (``repro.kernels.fold_instance``): the same leading dim the
        blocked/fused kernels iterate as their outermost grid dimension is
        the one the mesh distributes over ``data`` — the fleet maps onto
        pods without reshuffling between the kernel and collective views.

        Routes through ``_pspec`` so the divisibility demotion applies
        like every other spec builder: a fleet whose instance count does
        not divide the data-axis size (or whose column dim is not
        divisible by ``model``) degrades to replicated on that dim
        instead of producing an invalid ``NamedSharding``.
        """
        if self.mesh is None:
            return None
        axes = [None] * len(shape)
        axes[0] = Ax.INSTANCE
        if cols is not None and len(shape) >= 2 and shape[-1] == cols:
            axes[-1] = Ax.NRN
        return NamedSharding(self.mesh,
                             self._pspec(axes, self.act_rules, shape))

    # -- wafer link collectives ----------------------------------------------
    def instance_axis_name(self) -> Optional[str]:
        """The single mesh axis name inter-chip link collectives run over
        (``ppermute``/``all_gather`` take it as ``axis_name``). ``None``
        when there is no mesh or the instance rule spans several mesh
        axes — the wafer router then degrades to its local transport,
        the same graceful-degradation contract as ``_pspec``."""
        if self.mesh is None:
            return None
        r = self.act_rules[Ax.INSTANCE]
        if isinstance(r, (tuple, list)):
            if len(r) != 1:
                return None
            r = r[0]
        return r

    def link_specs(self, chip_dim: int, ndim: int) -> Tuple[PSpec, PSpec]:
        """(sharded, replicated) PartitionSpecs for the wafer router's
        ``shard_map``: chip-major arrays carry the instance rule on
        ``chip_dim``; link censuses come back replicated."""
        parts = [None] * ndim
        r = self.act_rules[Ax.INSTANCE]
        parts[chip_dim] = tuple(r) if isinstance(r, list) else r
        return PSpec(*parts), PSpec()


# ---------------------------------------------------------------------------
# Declarative parameters
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed | custom
    scale: Optional[float] = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(decl: ParamDecl, key):
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, decl.dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, decl.dtype)
    if decl.init == "embed":
        return jax.random.normal(key, decl.shape, decl.dtype) * 0.02
    # fan-in scaled normal
    fan_in = decl.shape[0] if len(decl.shape) == 1 else int(np.prod(decl.shape[:-1]))
    scale = decl.scale if decl.scale is not None else 1.0 / max(fan_in, 1) ** 0.5
    return jax.random.normal(key, decl.shape, decl.dtype) * scale


def _is_decl(x):
    return isinstance(x, ParamDecl)


def init_params(decls, key, ctx: Optional[ShardingCtx] = None):
    """Materialize a tree of ParamDecl into arrays (optionally sharded)."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    out = []
    for decl, k in zip(leaves, keys):
        arr = _init_leaf(decl, k)
        if ctx is not None and ctx.mesh is not None:
            arr = jax.device_put(arr, ctx.param_sharding(decl.axes))
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_params(decls):
    """ShapeDtypeStruct tree for .lower() — no allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls, is_leaf=_is_decl)


def tree_pspecs(decls, ctx: ShardingCtx, as_sharding: bool = True):
    """PartitionSpec/NamedSharding tree matching a ParamDecl tree."""
    fn = ctx.param_sharding if as_sharding else ctx.param_pspec
    return jax.tree.map(lambda d: fn(d.axes, d.shape), decls, is_leaf=_is_decl)


def param_bytes(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=_is_decl)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
