"""Gradient compression with error feedback for the cross-pod all-reduce.

At multi-pod scale the only inter-pod collective is the data-parallel
gradient all-reduce over the ``pod`` axis (DESIGN.md §4). Int8 quantization
with per-tensor scale cuts that traffic 4x (vs fp32 moments) / 2x (vs bf16);
the *error-feedback* accumulator re-injects the quantization residual into
the next step's gradient, which keeps SGD/Adam convergence (Seide et al.
1-bit SGD; Karimireddy et al. EF-SGD).

``compress``/``decompress`` are pure functions usable inside the jitted
train step; ``ef_transform_grads`` wraps a gradient tree with the error
state. The quantized all-reduce itself is expressed as sum-of-dequantized
(XLA lowers the pod-axis psum on the int8->fp32 product); on hardware with
int8 collectives the same interface maps 1:1.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def compress(g, bits: int = 8):
    """Per-tensor symmetric int quantization. Returns (q, scale)."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g)) / qmax
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def ef_init(params):
    """Zero error-feedback accumulators matching the gradient tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_grads(grads, err, bits: int = 8):
    """Returns (compressed-and-decompressed grads, new error state).

    The returned grads are exactly what every pod would reconstruct after
    the quantized all-reduce; ``new_err`` carries the residual forward.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = compress(g32, bits)
        deq = decompress(q, s)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, err)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
