"""Configuration system for the repro framework.

Three config families:
  * ``ArchConfig``  — one per supported architecture (the 10 assigned archs,
    plus the paper's own BSS-2 machine model).
  * ``ShapeConfig`` — the assigned input shapes (train_4k / prefill_32k /
    decode_32k / long_500k).
  * ``MeshConfig``  — logical mesh + sharding-rule selection.

Configs are plain frozen dataclasses so they can be hashed into jit static
arguments and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "vlm", "audio", "hybrid", "ssm", "neuromorphic")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # DeepSeek-style: first k layers stay dense (with d_ff_dense_first).
    first_k_dense: int = 0
    d_ff_dense_first: int = 0
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256            # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    causal: bool = True              # False for encoder-only (hubert)
    source: str = ""                 # provenance tag [source; verified-tier]

    # MoE / SSM sub-configs (empty defaults for dense archs)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # hybrid (hymba): sliding-window attention + parallel SSM heads
    swa_window: int = 0              # 0 -> full attention
    global_attn_layers: Tuple[int, ...] = ()   # layers with full attention
    n_meta_tokens: int = 0           # hymba learnable prefix tokens

    # vlm: patch-embedding stub frontend
    vit_dim: int = 0
    n_patches: int = 0

    # audio: frame-embedding stub frontend
    frame_dim: int = 0

    # paper technique: hybrid-plasticity knobs (C1'); see repro/plasticity
    plasticity_bits: int = 6         # BSS-2 synaptic weight resolution
    plasticity_observable: str = "activity"   # activity | state (ssm)

    # distribution
    attn_shard: str = "cp"           # "cp" (context parallel) | "heads"
    remat: bool = True
    remat_policy: str = "dots"       # "dots" (save matmul outputs) | "full"

    # ---- derived -----------------------------------------------------------
    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 (TP-divisible, MXU-aligned).

        Embedding/unembedding tables are allocated at this size; padded
        logit columns are masked to -inf everywhere (loss + serving)."""
        return ((self.vocab + 127) // 128) * 128 if self.vocab else 0

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports >=500k context (SSM / hybrid w/ SWA)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.swa_window > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                      # embedding (tied)
        if not self.tie_embeddings:
            n += self.vocab * d
        for i in range(L):
            n += self._layer_params(i)
        if self.vit_dim:
            n += self.vit_dim * d + d * d       # projector MLP
        if self.frame_dim:
            n += self.frame_dim * d
        n += self.n_meta_tokens * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        for i in range(L):
            n += self._layer_params(i, active_only=True)
        if self.vit_dim:
            n += self.vit_dim * d + d * d
        if self.frame_dim:
            n += self.frame_dim * d
        return n

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        n = 0
        if self.n_heads:
            hd = self.head_dim
            n += d * self.n_heads * hd          # wq
            n += 2 * d * self.n_kv_heads * hd   # wk, wv
            n += self.n_heads * hd * d          # wo
            if self.qkv_bias:
                n += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.family == "ssm" or (self.family == "hybrid"):
            n += self._ssm_layer_params()
        if self.moe.n_experts and i >= self.moe.first_k_dense:
            fe = self.moe.d_ff_expert
            per_expert = 3 * d * fe
            n += d * self.moe.n_experts         # router
            n += self.moe.n_shared_experts * per_expert
            if active_only:
                n += self.moe.top_k * per_expert
            else:
                n += self.moe.n_experts * per_expert
        elif self.moe.n_experts and i < self.moe.first_k_dense:
            n += 3 * d * self.moe.d_ff_dense_first
        elif self.d_ff:
            n += 3 * d * self.d_ff              # SwiGLU: w1, wg, w2
        n += 2 * d                              # norms
        return n

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        di = d * self.ssm.expand
        nh = di // self.ssm.head_dim
        ng, ns = self.ssm.n_groups, self.ssm.d_state
        conv_dim = di + 2 * ng * ns
        n = d * (2 * di + 2 * ng * ns + nh)     # in_proj (z, x, B, C, dt)
        n += conv_dim * self.ssm.d_conv         # depthwise conv
        n += 2 * nh                             # A_log, D
        n += di                                 # gate norm
        n += di * d                             # out_proj
        return n

    # ---- reduced config for CPU smoke tests --------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for single-device smoke tests."""
        moe = self.moe
        if moe.n_experts:
            moe = replace(moe, n_experts=min(8, moe.n_experts),
                          top_k=min(2, moe.top_k), d_ff_expert=64,
                          n_shared_experts=min(1, moe.n_shared_experts),
                          first_k_dense=min(1, moe.first_k_dense),
                          d_ff_dense_first=96 if moe.first_k_dense else 0)
        ssm = self.ssm
        if ssm.d_state:
            ssm = replace(ssm, d_state=16, head_dim=16, chunk=16)
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_heads else 0
        n_h = 0
        if self.n_heads:
            ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
            n_h = n_kv * min(ratio, 3)
        return replace(
            self,
            n_layers=2 if not self.global_attn_layers else 3,
            d_model=64, n_heads=n_h, n_kv_heads=n_kv, head_dim=16 if n_h else 0,
            d_ff=96 if self.d_ff else 0, vocab=503 if self.vocab else 0,
            moe=moe, ssm=ssm,
            swa_window=8 if self.swa_window else 0,
            global_attn_layers=(1,) if self.global_attn_layers else (),
            n_meta_tokens=4 if self.n_meta_tokens else 0,
            vit_dim=32 if self.vit_dim else 0,
            n_patches=4 if self.n_patches else 0,
            frame_dim=24 if self.frame_dim else 0,
        )


# ---------------------------------------------------------------------------
# Shape config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"

    def reduced(self) -> "ShapeConfig":
        return replace(self, seq_len=32, global_batch=2)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules for the 40-cell (arch x shape) matrix.

    Returns (runnable, reason-if-skipped).
    """
    if shape.kind == "decode" and arch.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "500k context needs sub-quadratic attention (full-attention arch)"
    return True, ""


# ---------------------------------------------------------------------------
# Mesh config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Mesh axes carrying the batch dimension."""
        return ("pod", "data") if self.multi_pod else ("data",)


# TPU v5e-class hardware model used by the roofline analysis.
@dataclass(frozen=True)
class HardwareConfig:
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw_per_link: float = 50e9       # bytes/s per link
    ici_links: int = 4                  # links/chip usable on a 2D torus
    hbm_bytes: int = 16 * 2**30


HW = HardwareConfig()

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from repro import configs as _configs  # noqa: F401  (side-effect registry)


ASSIGNED_ARCHS = (
    "smollm-360m", "minitron-4b", "qwen1.5-0.5b", "phi4-mini-3.8b",
    "internvl2-2b", "moonshot-v1-16b-a3b", "llama4-scout-17b-a16e",
    "hubert-xlarge", "hymba-1.5b", "mamba2-130m",
)
