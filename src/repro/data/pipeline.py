"""Deterministic, checkpointable, sharded synthetic data pipeline.

Sequences come from a seeded order-1 Markov chain over an effective vocab,
so models *can* learn (loss decreases measurably within tens of steps) and
every (seed, step, host) triple regenerates identical data — the pipeline
cursor is just ``(seed, step)`` and lives inside the checkpoint. In a
multi-host job each process generates only its batch shard
(``shard_index/num_shards``), so there is no data redistribution on
elastic restarts — the cursor semantics are host-count independent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, ShapeConfig
from repro.models.transformer import prefix_len


@dataclasses.dataclass
class SyntheticLMPipeline:
    arch: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    step: int = 0
    shard_index: int = 0
    num_shards: int = 1
    markov_states: int = 64

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # sparse-ish row-stochastic transition matrix over markov_states
        logits = rng.randn(self.markov_states, self.markov_states) * 2.0
        self._trans = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
        self._proj = rng.randint(
            0, max(self.arch.vocab, 2), size=self.markov_states)

    # -- checkpointable cursor ------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        return dict(seed=np.int64(self.seed), step=np.int64(self.step))

    def load_state_dict(self, st):
        self.seed = int(st["seed"])
        self.step = int(st["step"])

    # -- batch generation -------------------------------------------------
    def _tokens(self, rng, b, s):
        x = np.zeros((b, s), np.int64)
        state = rng.randint(0, self.markov_states, size=b)
        for t in range(s):
            x[:, t] = state
            u = rng.rand(b, 1)
            cdf = np.cumsum(self._trans[state], axis=1)
            state = (u < cdf).argmax(axis=1)
        return self._proj[x]

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + self.step * 131 + self.shard_index)
            % (2 ** 31))
        self.step += 1
        b = self.shape.global_batch // self.num_shards
        pl = prefix_len(self.arch)
        s = self.shape.seq_len - pl
        if self.arch.family == "audio":
            frames = rng.randn(b, self.shape.seq_len,
                               self.arch.frame_dim).astype(np.float32)
            labels = rng.randint(0, self.arch.vocab,
                                 size=(b, self.shape.seq_len))
            return dict(frames=jnp.asarray(frames),
                        labels=jnp.asarray(labels, jnp.int32))
        toks = self._tokens(rng, b, s + 1)
        batch = dict(tokens=jnp.asarray(toks[:, :-1], jnp.int32),
                     labels=jnp.asarray(toks[:, 1:], jnp.int32))
        if self.arch.vit_dim:
            pe = rng.randn(b, self.arch.n_patches,
                           self.arch.vit_dim).astype(np.float32)
            batch["patch_embeds"] = jnp.asarray(pe)
        return batch
