"""Two interchangeable executors for the PPU-VM ISA (paper §3.1).

``run_program_jax``
    The production executor: a ``lax.scan`` over the instruction words with
    a ``lax.switch`` over opcodes — one jit-able pure function, so a VM
    program can run *inside* the fused training scan (the hybrid-plasticity
    property: rule execution never leaves the device program).

``run_program_np``
    An independent straight-loop NumPy interpreter with the same integer
    semantics, used by the RefBackend of the playback co-simulation. Both
    executors are integer-exact: given identical inputs they must produce
    bit-identical registers and weights — that equality is the
    transparent-interchange check, now for *programs* instead of traces.

Inputs (see ``repro.ppuvm.isa`` for the numeric model):
  words    [P]            int32 instruction stream
  weights  [..., R, C]    integer synapse weights (0..63)
  qc, qa   [..., R, C]    int CADC causal / anti-causal codes (0..255)
  rates    [..., C]       per-column rate counters (integer-valued)
  mod      [n_mod, ..., C] Q8.8 per-column modulator slots
  noise    [..., R, C]    Q8.8 per-synapse noise plane

Returns ``(weights_out, regs)`` with ``weights_out`` int32 ``[..., R, C]``
and ``regs`` the final ``[N_REGS, ..., R, C]`` register file (programs use
it as a scratch readout, like the PPU's scratch SRAM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ppuvm import isa

assert isa.FRAC == 8, "CADC fractional loads assume Q8.8"


# ---------------------------------------------------------------------------
# JAX executor
# ---------------------------------------------------------------------------

def run_program_jax(words, weights, qc, qa, rates, mod=None, noise=None):
    lane_shape = weights.shape
    weights = weights.astype(jnp.int32)
    qc = jnp.broadcast_to(qc, lane_shape).astype(jnp.int32)
    qa = jnp.broadcast_to(qa, lane_shape).astype(jnp.int32)
    rates_fx = _sat_j(jnp.round(rates).astype(jnp.int32) << isa.FRAC)
    rates_fx = jnp.broadcast_to(rates_fx[..., None, :], lane_shape)
    if mod is None:
        mod = jnp.zeros((1, *lane_shape[:-2], lane_shape[-1]), jnp.int32)
    mod = jnp.broadcast_to(mod[..., None, :],
                           (mod.shape[0], *lane_shape)).astype(jnp.int32)
    if noise is None:
        noise = jnp.zeros(lane_shape, jnp.int32)
    noise = jnp.broadcast_to(noise, lane_shape).astype(jnp.int32)

    regs0 = jnp.zeros((isa.N_REGS, *lane_shape), jnp.int32)

    def sel_branch(regs, wmem, a, b, rd, sh, simm):
        mask = regs[rd] != 0
        return regs.at[rd].set(jnp.where(mask, a, b)), wmem

    def stw_branch(regs, wmem, a, b, rd, sh, simm):
        return regs, jnp.clip((a + (isa.ONE >> 1)) >> isa.FRAC, 0, isa.WMAX)

    def ldmod_branch(regs, wmem, a, b, rd, sh, simm):
        slot = jnp.clip(simm & 0xFF, 0, mod.shape[0] - 1)
        return regs.at[rd].set(mod[slot]), wmem

    def _valb(fn):
        def br(regs, wmem, a, b, rd, sh, simm):
            return regs.at[rd].set(fn(a, b, sh, simm)), wmem
        return br

    branches = [None] * isa.N_OPS
    branches[isa.NOP] = lambda regs, wmem, a, b, rd, sh, simm: (regs, wmem)
    branches[isa.SPLAT] = _valb(
        lambda a, b, sh, simm: jnp.broadcast_to(simm, lane_shape))
    branches[isa.MOV] = _valb(lambda a, b, sh, simm: a)
    branches[isa.ADD] = _valb(lambda a, b, sh, simm: _sat_j(a + b))
    branches[isa.SUB] = _valb(lambda a, b, sh, simm: _sat_j(a - b))
    # shift clamp 16: registers are Q8.8 halfwords, so larger shifts are
    # meaningless — and 1 << sh must stay well inside int32
    branches[isa.MULF] = _valb(
        lambda a, b, sh, simm: _sat_j(
            (a * b + ((1 << jnp.minimum(sh, 16)) >> 1))
            >> jnp.minimum(sh, 16)))
    branches[isa.SHL] = _valb(
        lambda a, b, sh, simm: _sat_j(a << jnp.minimum(sh, 15)))
    branches[isa.SHR] = _valb(lambda a, b, sh, simm: a >> jnp.minimum(sh, 31))
    branches[isa.CMPGE] = _valb(
        lambda a, b, sh, simm: jnp.where(a >= b, isa.ONE, 0))
    branches[isa.SEL] = sel_branch
    branches[isa.MAXS] = _valb(lambda a, b, sh, simm: jnp.maximum(a, b))
    branches[isa.MINS] = _valb(lambda a, b, sh, simm: jnp.minimum(a, b))
    branches[isa.LDW] = lambda regs, wmem, a, b, rd, sh, simm: (
        regs.at[rd].set(wmem << isa.FRAC), wmem)
    branches[isa.STW] = stw_branch
    branches[isa.LDCAUSAL] = _valb(lambda a, b, sh, simm: qc)
    branches[isa.LDACAUSAL] = _valb(lambda a, b, sh, simm: qa)
    branches[isa.LDRATE] = _valb(lambda a, b, sh, simm: rates_fx)
    branches[isa.LDMOD] = ldmod_branch
    branches[isa.LDNOISE] = _valb(lambda a, b, sh, simm: noise)

    def step(carry, word):
        regs, wmem = carry
        op = (word >> 26) & 0x3F
        rd = (word >> 21) & 0x1F
        ra = (word >> 16) & 0x1F
        imm = word & 0xFFFF
        simm = imm - ((imm & 0x8000) << 1)
        rb = (imm >> 8) & 0x1F
        sh = imm & 0xFF
        a = regs[ra % isa.N_REGS]
        b = regs[rb % isa.N_REGS]
        # unknown opcodes execute as NOP — identical in both executors,
        # so the bit-interchange contract holds for ANY word stream;
        # playback's WRITE_PPU_PROGRAM additionally rejects them up front
        regs, wmem = jax.lax.switch(
            jnp.where(op < isa.N_OPS, op, isa.NOP), branches,
            regs, wmem, a, b, rd % isa.N_REGS, sh, simm)
        return (regs, wmem), None

    (regs, wmem), _ = jax.lax.scan(step, (regs0, weights),
                                   jnp.asarray(words, jnp.int32))
    return wmem, regs


def _sat_j(x):
    return jnp.clip(x, isa.I16MIN, isa.I16MAX)


# ---------------------------------------------------------------------------
# NumPy executor (independent reference — keep free of jax)
# ---------------------------------------------------------------------------

def run_program_np(words, weights, qc, qa, rates, mod=None, noise=None):
    lane_shape = np.shape(weights)
    wmem = np.asarray(weights, np.int32).copy()
    qc = np.broadcast_to(np.asarray(qc, np.int32), lane_shape)
    qa = np.broadcast_to(np.asarray(qa, np.int32), lane_shape)
    rates_fx = _sat_n(np.round(np.asarray(rates)).astype(np.int32)
                      << isa.FRAC)
    rates_fx = np.broadcast_to(rates_fx[..., None, :], lane_shape)
    if mod is None:
        mod = np.zeros((1, *lane_shape[:-2], lane_shape[-1]), np.int32)
    mod = np.asarray(mod, np.int32)
    if noise is None:
        noise = np.zeros(lane_shape, np.int32)
    noise = np.broadcast_to(np.asarray(noise, np.int32), lane_shape)

    regs = np.zeros((isa.N_REGS, *lane_shape), np.int32)
    for word in np.asarray(words, np.int64):
        op, rd, ra, rb, sh, simm = isa.decode(int(word))
        rd %= isa.N_REGS
        a = regs[ra % isa.N_REGS]
        b = regs[rb % isa.N_REGS]
        if op == isa.NOP:
            pass
        elif op == isa.SPLAT:
            regs[rd] = simm
        elif op == isa.MOV:
            regs[rd] = a
        elif op == isa.ADD:
            regs[rd] = _sat_n(a + b)
        elif op == isa.SUB:
            regs[rd] = _sat_n(a - b)
        elif op == isa.MULF:
            shc = min(sh, 16)
            regs[rd] = _sat_n((a * b + ((1 << shc) >> 1)) >> shc)
        elif op == isa.SHL:
            regs[rd] = _sat_n(a << min(sh, 15))
        elif op == isa.SHR:
            regs[rd] = a >> min(sh, 31)
        elif op == isa.CMPGE:
            regs[rd] = np.where(a >= b, isa.ONE, 0)
        elif op == isa.SEL:
            regs[rd] = np.where(regs[rd] != 0, a, b)
        elif op == isa.MAXS:
            regs[rd] = np.maximum(a, b)
        elif op == isa.MINS:
            regs[rd] = np.minimum(a, b)
        elif op == isa.LDW:
            regs[rd] = wmem << isa.FRAC
        elif op == isa.STW:
            wmem = np.clip((a + (isa.ONE >> 1)) >> isa.FRAC,
                           0, isa.WMAX).astype(np.int32)
        elif op == isa.LDCAUSAL:
            regs[rd] = qc
        elif op == isa.LDACAUSAL:
            regs[rd] = qa
        elif op == isa.LDRATE:
            regs[rd] = rates_fx
        elif op == isa.LDMOD:
            regs[rd] = np.broadcast_to(
                mod[min(simm & 0xFF, mod.shape[0] - 1)][..., None, :],
                lane_shape)
        elif op == isa.LDNOISE:
            regs[rd] = noise
        # unknown opcodes are NOPs, matching the JAX executor
    return wmem, regs


def _sat_n(x):
    return np.clip(x, isa.I16MIN, isa.I16MAX).astype(np.int32)
