"""Executors for the PPU-VM ISA (paper §3.1) and the executor registry.

Four interchangeable implementations run the same int32 word stream:

``run_program_jax``   ("scan")
    A ``lax.scan`` over the instruction words with a ``lax.switch`` over
    opcodes — one jit-able pure function that works for *traced* word
    streams, so a VM program can be an argument of a jitted function.

``repro.ppuvm.specialize.run_program_specialized``   ("specialized")
    The trace-time specializer: when the word stream is concrete at jit
    time it is decoded in Python and unrolled into straight-line jnp ops
    (no scan, no switch) — the compiled-program fast path.

``repro.kernels.ppuvm_exec``   ("pallas" / "pallas_interpret")
    A Pallas kernel that runs the whole program per VMEM tile — registers
    live on-chip for the entire program, one grid pass over the synapse
    array, the TPU analogue of the PPU executing its kernel out of SRAM.

``run_program_np``   ("numpy")
    An independent straight-loop NumPy interpreter with the same integer
    semantics, used by the RefBackend of the playback co-simulation.

All four are integer-exact: given identical inputs they must produce
bit-identical registers and weights — the transparent-interchange check
of the paper, enforced across random programs by
``tests/test_ppuvm_fuzz.py`` (the differential fuzz harness).

``run_program(words, ..., executor="auto")`` is the front door: ``auto``
picks the specializer when the words are concrete (host array or
closed-over constant under jit) and the scan interpreter when they are a
tracer. The JAX-side semantics live in ONE place — ``make_branches`` /
``step_word`` — which the scan interpreter, the specializer, and the
Pallas kernel all dispatch through, so a semantics change cannot
silently fork the executors.

Inputs (see ``repro.ppuvm.isa`` for the numeric model):
  words    [P]            int32 instruction stream
  weights  [..., R, C]    integer synapse weights (0..63)
  qc, qa   [..., R, C]    int CADC causal / anti-causal codes (0..255)
  rates    [..., C]       per-column rate counters (integer-valued)
  mod      [n_mod, ..., C] Q8.8 per-column modulator slots
  noise    [..., R, C]    Q8.8 per-synapse noise plane

Returns ``(weights_out, regs)`` with ``weights_out`` int32 ``[..., R, C]``
and ``regs`` the final ``[N_REGS, ..., R, C]`` register file (programs use
it as a scratch readout, like the PPU's scratch SRAM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ppuvm import isa

assert isa.FRAC == 8, "CADC fractional loads assume Q8.8"

#: executor names accepted by ``run_program`` (and everything that
#: threads an ``executor=`` through to it: ``VectorUnit.run_program``,
#: ``hybrid.make_experiment(vm_executor=...)``, playback's
#: ``FastBackend(ppu_executor=...)``).
EXECUTORS = ("auto", "scan", "specialized", "pallas", "pallas_interpret",
             "numpy")


# ---------------------------------------------------------------------------
# Shared JAX semantics: operand preparation, branch table, one-word step
# ---------------------------------------------------------------------------

def prepare_operands(weights, qc, qa, rates, mod=None, noise=None):
    """Broadcast/digitize the operand planes to the lane shape (the form
    every JAX executor consumes): int32 weights, int32 qc/qa, saturated
    fixed-point rates, [n_mod, *lane] modulator slots, int32 noise."""
    lane_shape = weights.shape
    wmem = weights.astype(jnp.int32)
    qc = jnp.broadcast_to(qc, lane_shape).astype(jnp.int32)
    qa = jnp.broadcast_to(qa, lane_shape).astype(jnp.int32)
    rates_fx = rates_to_fixed(rates)
    rates_fx = jnp.broadcast_to(rates_fx[..., None, :], lane_shape)
    if mod is None:
        mod = jnp.zeros((1, *lane_shape[:-2], lane_shape[-1]), jnp.int32)
    mod = jnp.broadcast_to(mod[..., None, :],
                           (mod.shape[0], *lane_shape)).astype(jnp.int32)
    if noise is None:
        noise = jnp.zeros(lane_shape, jnp.int32)
    noise = jnp.broadcast_to(noise, lane_shape).astype(jnp.int32)
    return wmem, qc, qa, rates_fx, mod, noise


def rates_to_fixed(rates):
    """Rate counters (integer-valued float) -> saturated Q8.8 int32."""
    return _sat_j(jnp.round(rates).astype(jnp.int32) << isa.FRAC)


def make_semantics(lane_shape, qc, qa, rates_fx, mod, noise):
    """The per-opcode semantics, storage-agnostic: a list over opcodes of

        fn(a, b, r_rd, wmem, sh, simm) -> (rd_value | None, new_wmem)

    where ``a``/``b`` are the source register values, ``r_rd`` the
    current *destination* value (only SEL reads it) and ``None`` means
    "rd unchanged". All operands must already be broadcast to
    ``lane_shape`` (``mod`` to ``[n_mod, *lane_shape]``) — see
    ``prepare_operands``.

    This is the single source of the JAX-side ISA arithmetic: the scan
    interpreter and the Pallas tile VM wrap it over a stacked register
    file (``make_branches``), the trace-time specializer applies it to a
    Python register list — so the executors cannot fork semantically,
    they only differ in dispatch and register storage.
    """

    def _val(fn):
        return lambda a, b, r_rd, wmem, sh, simm: (fn(a, b, sh, simm), wmem)

    sem = [None] * isa.N_OPS
    sem[isa.NOP] = lambda a, b, r_rd, wmem, sh, simm: (None, wmem)
    sem[isa.SPLAT] = _val(
        lambda a, b, sh, simm: jnp.broadcast_to(
            jnp.int32(simm), lane_shape))
    sem[isa.MOV] = _val(lambda a, b, sh, simm: a)
    sem[isa.ADD] = _val(lambda a, b, sh, simm: _sat_j(a + b))
    sem[isa.SUB] = _val(lambda a, b, sh, simm: _sat_j(a - b))
    # shift clamp 16: registers are Q8.8 halfwords, so larger shifts are
    # meaningless — and 1 << sh must stay well inside int32
    sem[isa.MULF] = _val(
        lambda a, b, sh, simm: _sat_j(
            (a * b + ((1 << jnp.minimum(sh, 16)) >> 1))
            >> jnp.minimum(sh, 16)))
    sem[isa.SHL] = _val(
        lambda a, b, sh, simm: _sat_j(a << jnp.minimum(sh, 15)))
    sem[isa.SHR] = _val(lambda a, b, sh, simm: a >> jnp.minimum(sh, 31))
    sem[isa.CMPGE] = _val(
        lambda a, b, sh, simm: jnp.where(a >= b, isa.ONE, 0))
    sem[isa.SEL] = lambda a, b, r_rd, wmem, sh, simm: (
        jnp.where(r_rd != 0, a, b), wmem)
    sem[isa.MAXS] = _val(lambda a, b, sh, simm: jnp.maximum(a, b))
    sem[isa.MINS] = _val(lambda a, b, sh, simm: jnp.minimum(a, b))
    sem[isa.LDW] = lambda a, b, r_rd, wmem, sh, simm: (
        wmem << isa.FRAC, wmem)
    sem[isa.STW] = lambda a, b, r_rd, wmem, sh, simm: (
        None, jnp.clip((a + (isa.ONE >> 1)) >> isa.FRAC, 0, isa.WMAX))
    sem[isa.LDCAUSAL] = _val(lambda a, b, sh, simm: qc)
    sem[isa.LDACAUSAL] = _val(lambda a, b, sh, simm: qa)
    sem[isa.LDRATE] = _val(lambda a, b, sh, simm: rates_fx)
    sem[isa.LDMOD] = lambda a, b, r_rd, wmem, sh, simm: (
        mod[jnp.clip(simm & 0xFF, 0, mod.shape[0] - 1)], wmem)
    sem[isa.LDNOISE] = _val(lambda a, b, sh, simm: noise)
    return sem


def make_branches(lane_shape, qc, qa, rates_fx, mod, noise):
    """``make_semantics`` wrapped for a stacked [N_REGS, *lane] register
    file — the lax.switch branch table of the scan interpreter and the
    Pallas tile VM (where ``lane_shape`` is one VMEM tile)."""
    sem = make_semantics(lane_shape, qc, qa, rates_fx, mod, noise)

    def wrap(fn):
        def br(regs, wmem, a, b, rd, sh, simm):
            val, wmem = fn(a, b, regs[rd], wmem, sh, simm)
            return (regs if val is None else regs.at[rd].set(val)), wmem
        return br

    return [wrap(fn) for fn in sem]


def step_word(branches, regs, wmem, word):
    """Execute ONE traced instruction word against (regs, wmem). Unknown
    opcodes execute as NOP — identical in every executor, so the
    bit-interchange contract holds for ANY word stream; playback's
    WRITE_PPU_PROGRAM additionally rejects them up front."""
    op = (word >> 26) & 0x3F
    rd = (word >> 21) & 0x1F
    ra = (word >> 16) & 0x1F
    imm = word & 0xFFFF
    simm = imm - ((imm & 0x8000) << 1)
    rb = (imm >> 8) & 0x1F
    sh = imm & 0xFF
    a = regs[ra % isa.N_REGS]
    b = regs[rb % isa.N_REGS]
    return jax.lax.switch(
        jnp.where(op < isa.N_OPS, op, isa.NOP), branches,
        regs, wmem, a, b, rd % isa.N_REGS, sh, simm)


# ---------------------------------------------------------------------------
# "scan" executor: lax.scan over words, lax.switch over opcodes
# ---------------------------------------------------------------------------

def run_program_jax(words, weights, qc, qa, rates, mod=None, noise=None):
    lane_shape = weights.shape
    wmem, qc, qa, rates_fx, mod, noise = prepare_operands(
        weights, qc, qa, rates, mod, noise)
    branches = make_branches(lane_shape, qc, qa, rates_fx, mod, noise)
    regs0 = jnp.zeros((isa.N_REGS, *lane_shape), jnp.int32)

    def step(carry, word):
        regs, wmem = carry
        return step_word(branches, regs, wmem, word), None

    (regs, wmem), _ = jax.lax.scan(step, (regs0, wmem),
                                   jnp.asarray(words, jnp.int32))
    return wmem, regs


def _sat_j(x):
    return jnp.clip(x, isa.I16MIN, isa.I16MAX)


# ---------------------------------------------------------------------------
# Executor registry / front door
# ---------------------------------------------------------------------------

def resolve_executor(executor: str, words) -> str:
    """Resolve ``"auto"``: the specializer needs the word stream concrete
    at trace time (a host array, or a constant closed over by the jitted
    function); a traced word stream falls back to the scan interpreter."""
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r}; one of {EXECUTORS}")
    if executor != "auto":
        return executor
    return "scan" if isinstance(words, jax.core.Tracer) else "specialized"


def run_program(words, weights, qc, qa, rates, mod=None, noise=None, *,
                executor: str = "auto"):
    """Run a PPU-VM program with a selectable executor (the pluggable
    axis): "scan" | "specialized" | "pallas" | "pallas_interpret" |
    "numpy" | "auto". "numpy" requires all-concrete inputs (it is the
    co-sim reference, not a jit path)."""
    ex = resolve_executor(executor, words)
    if ex == "scan":
        return run_program_jax(words, weights, qc, qa, rates, mod, noise)
    if ex == "specialized":
        from repro.ppuvm import specialize
        # route through the jitted-closure cache: one compiled
        # specialization per program image, shared across uploads/calls
        return specialize.specialized_callable(words)(
            weights, qc, qa, rates, mod, noise)
    if ex in ("pallas", "pallas_interpret"):
        from repro.kernels.ppuvm_exec import ops as exec_ops
        return exec_ops.run_program_tiled(
            words, weights, qc, qa, rates, mod, noise,
            interpret=(ex == "pallas_interpret"))
    wmem, regs = run_program_np(np.asarray(words), np.asarray(weights),
                                np.asarray(qc), np.asarray(qa),
                                np.asarray(rates),
                                None if mod is None else np.asarray(mod),
                                None if noise is None else np.asarray(noise))
    return jnp.asarray(wmem), jnp.asarray(regs)


# ---------------------------------------------------------------------------
# NumPy executor (independent reference — keep free of jax)
# ---------------------------------------------------------------------------

def run_program_np(words, weights, qc, qa, rates, mod=None, noise=None):
    lane_shape = np.shape(weights)
    wmem = np.asarray(weights, np.int32).copy()
    qc = np.broadcast_to(np.asarray(qc, np.int32), lane_shape)
    qa = np.broadcast_to(np.asarray(qa, np.int32), lane_shape)
    rates_fx = _sat_n(np.round(np.asarray(rates)).astype(np.int32)
                      << isa.FRAC)
    rates_fx = np.broadcast_to(rates_fx[..., None, :], lane_shape)
    if mod is None:
        mod = np.zeros((1, *lane_shape[:-2], lane_shape[-1]), np.int32)
    mod = np.asarray(mod, np.int32)
    if noise is None:
        noise = np.zeros(lane_shape, np.int32)
    noise = np.broadcast_to(np.asarray(noise, np.int32), lane_shape)

    regs = np.zeros((isa.N_REGS, *lane_shape), np.int32)
    for word in np.asarray(words, np.int64):
        op, rd, ra, rb, sh, simm = isa.decode(int(word))
        rd %= isa.N_REGS
        a = regs[ra % isa.N_REGS]
        b = regs[rb % isa.N_REGS]
        if op == isa.NOP:
            pass
        elif op == isa.SPLAT:
            regs[rd] = simm
        elif op == isa.MOV:
            regs[rd] = a
        elif op == isa.ADD:
            regs[rd] = _sat_n(a + b)
        elif op == isa.SUB:
            regs[rd] = _sat_n(a - b)
        elif op == isa.MULF:
            shc = min(sh, 16)
            regs[rd] = _sat_n((a * b + ((1 << shc) >> 1)) >> shc)
        elif op == isa.SHL:
            regs[rd] = _sat_n(a << min(sh, 15))
        elif op == isa.SHR:
            regs[rd] = a >> min(sh, 31)
        elif op == isa.CMPGE:
            regs[rd] = np.where(a >= b, isa.ONE, 0)
        elif op == isa.SEL:
            regs[rd] = np.where(regs[rd] != 0, a, b)
        elif op == isa.MAXS:
            regs[rd] = np.maximum(a, b)
        elif op == isa.MINS:
            regs[rd] = np.minimum(a, b)
        elif op == isa.LDW:
            regs[rd] = wmem << isa.FRAC
        elif op == isa.STW:
            wmem = np.clip((a + (isa.ONE >> 1)) >> isa.FRAC,
                           0, isa.WMAX).astype(np.int32)
        elif op == isa.LDCAUSAL:
            regs[rd] = qc
        elif op == isa.LDACAUSAL:
            regs[rd] = qa
        elif op == isa.LDRATE:
            regs[rd] = rates_fx
        elif op == isa.LDMOD:
            regs[rd] = np.broadcast_to(
                mod[min(simm & 0xFF, mod.shape[0] - 1)][..., None, :],
                lane_shape)
        elif op == isa.LDNOISE:
            regs[rd] = noise
        # unknown opcodes are NOPs, matching the JAX executor
    return wmem, regs


def _sat_n(x):
    return np.clip(x, isa.I16MIN, isa.I16MAX).astype(np.int32)
