"""PPU-VM: a SIMD fixed-point instruction-set emulator for the plasticity
processing unit — learning rules become uploadable programs (paper §2.2,
§3.1, §5).

  isa       numeric model, opcode table, encoding
  asm       assembler / program builder -> dense int32 words
  interp    jit-able JAX executor + independent NumPy executor
  programs  R-STDP / STDP / homeostasis written in the ISA
"""
from repro.ppuvm import asm, interp, isa, programs  # noqa: F401
