"""PPU-VM: a SIMD fixed-point instruction-set emulator for the plasticity
processing unit — learning rules become uploadable programs (paper §2.2,
§3.1, §5).

  isa        numeric model, opcode table, encoding
  asm        assembler / program builder -> dense int32 words
  interp     scan interpreter + NumPy reference + executor registry
             (``interp.run_program(words, ..., executor=...)``)
  specialize trace-time specializer: concrete word streams unrolled to
             straight-line jnp ops at jit time
  programs   R-STDP / STDP / homeostasis written in the ISA

The Pallas tile-VM executor lives in ``repro.kernels.ppuvm_exec``; all
executors are bit-identical (tests/test_ppuvm_fuzz.py).
"""
from repro.ppuvm import asm, interp, isa, programs, specialize  # noqa: F401
