"""PPU-VM instruction set: SIMD fixed-point vector ops (paper §2.2, §5).

The silicon PPU couples a Power-ISA scalar core to a SIMD vector unit whose
lanes are hard-wired to synapse-array columns; plasticity kernels are
*programs* that loop over synapse rows, computing in saturating fixed point
("fracsat" in the hardware's modified-Power-ISA vector extension) and
writing 6-bit weights back through the full-custom SRAM controller (see
also arXiv:2003.11996 §"plasticity processing unit").

This module defines the VM's numeric model and opcode table; the two
executors (`repro.ppuvm.interp`) and the assembler (`repro.ppuvm.asm`)
share it.

Numeric model
-------------
Registers hold signed 16-bit fixed point in Q8.8 (``FRAC = 8`` fractional
bits, range ±128, resolution 2^-8), stored in int32 lanes; every
arithmetic result saturates to the int16 range — the hardware's halfword
fracsat mode. A program is written for ONE synapse row; the VM executes
all rows in lock-step (the register file is conceptually ``[n_regs, C]``
per row and ``[n_regs, R, C]`` for the whole array), exactly like the
hardware loops its row-parallel vector kernel over the array.

Memory / observable semantics (the hardware-shaped part):

  ``LDW``        weight row as an integer value w (raw = w << FRAC)
  ``STW``        saturating 6-bit store: w = clip(round(val), 0, 63)
  ``LDCAUSAL``/``LDACAUSAL``
                 CADC causal/anti-causal codes as *fractions of full
                 scale*: value = code / 2^8 — exact in Q8.8 (raw = code),
                 like the vector unit's fractional byte loads
  ``LDRATE``     per-column rate counter as an integer value (saturating)
  ``LDMOD``      per-column modulator slot k (scalar-core deposited, e.g.
                 R - <R>), pre-digitized to Q8.8
  ``LDNOISE``    per-synapse noise plane (the PPU's PRNG stream),
                 pre-digitized to Q8.8

Instruction encoding (one int32 word, assembled by ``repro.ppuvm.asm``):

  bits [31:26] opcode   [25:21] rd   [20:16] ra   [15:0] imm16

For 3-register ALU ops ``imm16 = (rb << 8) | shamt``; for ``VSPLAT`` the
imm16 is the sign-extended Q8.8 constant; for ``LDMOD`` it is the
modulator slot index.
"""
from __future__ import annotations

import numpy as np

# --- numeric model ---------------------------------------------------------
FRAC = 8                       # fractional bits (Q8.8)
ONE = 1 << FRAC                # fixed-point 1.0
I16MIN, I16MAX = -(1 << 15), (1 << 15) - 1
WMAX = 63                      # 6-bit saturating weight store

# --- opcodes ---------------------------------------------------------------
NOP = 0
SPLAT = 1      # rd <- imm16 (sign-extended Q8.8 constant)
MOV = 2        # rd <- ra
ADD = 3        # rd <- sat(ra + rb)
SUB = 4        # rd <- sat(ra - rb)
MULF = 5       # rd <- sat((ra * rb + round) >> shamt)   fracsat multiply
SHL = 6        # rd <- sat(ra << shamt)
SHR = 7        # rd <- ra >> shamt (arithmetic)
CMPGE = 8      # rd <- ONE where ra >= rb else 0
SEL = 9        # rd <- ra where rd != 0 else rb (blend by mask in rd)
MAXS = 10      # rd <- max(ra, rb)
MINS = 11      # rd <- min(ra, rb)
LDW = 12       # rd <- weight row (integer value)
STW = 13       # weight row <- clip(round(ra), 0, 63)
LDCAUSAL = 14  # rd <- CADC causal codes / 2^8
LDACAUSAL = 15  # rd <- CADC anti-causal codes / 2^8
LDRATE = 16    # rd <- rate counters (integer value, saturating)
LDMOD = 17     # rd <- modulator slot imm16
LDNOISE = 18   # rd <- noise plane

N_OPS = 19
N_REGS = 8

MNEMONIC = {
    NOP: "nop", SPLAT: "vsplat", MOV: "vmov", ADD: "vadd", SUB: "vsub",
    MULF: "vmulf", SHL: "vshl", SHR: "vshr", CMPGE: "vcmpge", SEL: "vsel",
    MAXS: "vmax", MINS: "vmin", LDW: "ldw", STW: "stw",
    LDCAUSAL: "ldcausal", LDACAUSAL: "ldacausal", LDRATE: "ldrate",
    LDMOD: "ldmod", LDNOISE: "ldnoise",
}


# --- fixed-point conversion (host side) ------------------------------------
def to_fixed(x):
    """Float -> Q8.8 int32, round-half-even (np.round), saturating."""
    return np.clip(np.round(np.asarray(x, np.float64) * ONE),
                   I16MIN, I16MAX).astype(np.int32)


def from_fixed(x):
    """Q8.8 int32 -> float32."""
    return np.asarray(x, np.float32) / ONE


def splat_imm(value: float) -> int:
    """Encode a float constant as the 16-bit Q8.8 immediate of VSPLAT."""
    v = int(np.clip(round(float(value) * ONE), I16MIN, I16MAX))
    return v & 0xFFFF


# --- encoding --------------------------------------------------------------
def encode(op: int, rd: int = 0, ra: int = 0, imm16: int = 0) -> int:
    assert 0 <= op < (1 << 6) and 0 <= rd < (1 << 5) and 0 <= ra < (1 << 5)
    return (op << 26) | (rd << 21) | (ra << 16) | (imm16 & 0xFFFF)


def alu_imm(rb: int = 0, shamt: int = 0) -> int:
    assert 0 <= rb < (1 << 5) and 0 <= shamt < (1 << 8)
    return (rb << 8) | shamt


def decode(word: int):
    """word -> (op, rd, ra, rb, shamt, simm16). Pure-python mirror of the
    in-kernel decoders (used for disassembly)."""
    op = (word >> 26) & 0x3F
    rd = (word >> 21) & 0x1F
    ra = (word >> 16) & 0x1F
    imm = word & 0xFFFF
    simm = imm - ((imm & 0x8000) << 1)
    rb = (imm >> 8) & 0x1F
    sh = imm & 0xFF
    return op, rd, ra, rb, sh, simm


def validate(words) -> None:
    """Reject word streams with unknown opcodes (host-side, at program
    upload). Both executors run unknown ops as NOPs — identically — but a
    program containing one is a bug worth catching at the boundary."""
    ops = (np.asarray(words, np.int64) >> 26) & 0x3F
    bad = ops[ops >= N_OPS]
    if bad.size:
        raise ValueError(f"unknown opcode(s) {sorted(set(bad.tolist()))}")


def disassemble(words) -> str:
    lines = []
    for w in np.asarray(words, np.int64):
        op, rd, ra, rb, sh, simm = decode(int(w))
        m = MNEMONIC.get(op, f"op{op}")
        if op == SPLAT:
            lines.append(f"{m} r{rd}, {simm / ONE:g}")
        elif op in (MOV, LDW, LDCAUSAL, LDACAUSAL, LDRATE, LDNOISE):
            src = f" r{ra}" if op == MOV else ""
            lines.append(f"{m} r{rd}{src}")
        elif op == LDMOD:
            lines.append(f"{m} r{rd}, slot{simm & 0xFF}")
        elif op == STW:
            lines.append(f"{m} r{ra}")
        elif op in (SHL, SHR):
            lines.append(f"{m} r{rd}, r{ra}, {sh}")
        elif op == MULF:
            lines.append(f"{m} r{rd}, r{ra}, r{rb}, >>{sh}")
        elif op == NOP:
            lines.append(m)
        else:
            lines.append(f"{m} r{rd}, r{ra}, r{rb}")
    return "\n".join(lines)
