"""Learning rules written as PPU-VM programs (paper §2.2, §5).

Each builder returns the dense int32 instruction words that implement the
vector (row-parallel) part of a rule from ``repro.core.rules``; the scalar
part — Eq. 2's running mean, PRNG advance — stays on the "scalar core"
(the Python/JAX wrapper, ``VectorUnit.apply_rstdp_program`` or the
playback ``PPU_RUN`` glue), exactly like the silicon splits work between
the Power core and the vector unit.

Scaling notes: CADC codes load as fractions code/2^8 while the float
oracles divide by ``cadc_max`` = 2^8 - 1, so every per-code gain constant
is folded with the ratio 2^8/cadc_max at assembly time; constants are
Q8.8, so programs match their float oracles to ~2^-9 per operation —
within one 6-bit weight LSB after the saturating store (the acceptance
bound; see tests/test_ppuvm.py).
"""
from __future__ import annotations

import numpy as np

from repro.ppuvm import isa
from repro.ppuvm.asm import Asm


def _code_scale(cadc_max: int) -> float:
    """Fold the oracle's /cadc_max against the VM's /2^FRAC fractional
    CADC load."""
    return float(1 << 8) / float(cadc_max)


def rstdp_program(*, eta: float = 0.5, cadc_max: int = 255) -> np.ndarray:
    """R-STDP Eq. 3 vector part (``rules.rstdp`` / ``apply_rstdp`` ref):

        w <- sat6(w + eta * (R - <R>) * (qc - qa)/cadc_max + xi)

    Modulator slot 0 carries R - <R>; the noise plane carries xi.
    """
    a = Asm()
    e, t, k, m = a.reg("e"), a.reg("t"), a.reg("k"), a.reg("m")
    a.ldcausal(e)
    a.ldacausal(t)
    a.sub(e, e, t)                        # e = (qc - qa) / 2^8
    a.splat(k, eta * _code_scale(cadc_max))
    a.ldmod(m, 0)                         # m = R - <R>
    a.mulf(m, k, m)                       # m = eta' * mod
    a.mulf(e, m, e)                       # e = eta' * mod * elig
    a.ldw(t)
    a.add(t, t, e)
    a.ldnoise(m)                          # xi random walk
    a.add(t, t, m)
    a.stw(t)                              # saturating 6-bit write-back
    return a.build()


def stdp_program(*, eta_plus: float = 0.1, eta_minus: float = 0.12,
                 cadc_max: int = 255) -> np.ndarray:
    """Plain additive STDP (``rules.stdp``):

        w <- sat6(w + (eta_plus * qc - eta_minus * qa) / cadc_max)
    """
    a = Asm()
    c, q, k, w = a.reg("c"), a.reg("q"), a.reg("k"), a.reg("w")
    a.ldcausal(c)
    a.splat(k, eta_plus * _code_scale(cadc_max))
    a.mulf(c, k, c)
    a.ldacausal(q)
    a.splat(k, eta_minus * _code_scale(cadc_max))
    a.mulf(q, k, q)
    a.sub(c, c, q)
    a.ldw(w)
    a.add(w, w, c)
    a.stw(w)
    return a.build()


def homeostasis_program(*, target_rate: float, eta: float = 0.2
                        ) -> np.ndarray:
    """Rate homeostasis (``rules.homeostasis``):

        w <- sat6(w + eta * (target_rate - rates))
    """
    a = Asm()
    r, k, w = a.reg("r"), a.reg("k"), a.reg("w")
    a.ldrate(r)
    a.splat(k, target_rate)
    a.sub(r, k, r)                        # target - rates
    a.splat(k, eta)
    a.mulf(r, k, r)
    a.ldw(w)
    a.add(w, w, r)
    a.stw(w)
    return a.build()


def signed_dw_program(*, eta: float, eta_homeo: float, fire_thresh: float,
                      cadc_max: int = 255) -> np.ndarray:
    """The §5 experiment's Dale-signed rule, vector part: per-row weight
    delta (no store — the scalar core applies it to the PPU-resident
    signed float state and rewrites both signed rows, see
    ``repro.core.hybrid``). Register 0 holds the readout:

        dw = eta * mod * (qc - qa)/cadc_max
           + eta_homeo * (1 - R) * (1 - 2 * fired)

    Modulator slot 0 = R - <R>, slot 1 = R; ``fired`` = rates >= thresh.
    """
    a = Asm()
    e, t, k, m = a.reg("e"), a.reg("t"), a.reg("k"), a.reg("m")
    assert e == 0, "readout register is r0"
    a.ldcausal(e)
    a.ldacausal(t)
    a.sub(e, e, t)                        # (qc - qa) / 2^8
    a.splat(k, eta * _code_scale(cadc_max))
    a.ldmod(m, 0)                         # R - <R>
    a.mulf(m, k, m)
    a.mulf(e, m, e)                       # eligibility term
    a.ldrate(m)
    a.splat(t, fire_thresh)
    a.cmpge(t, m, t)                      # fired mask (ONE / 0)
    a.splat(k, 1.0)
    a.shl(m, t, 1)                        # 2 * fired
    a.sub(t, k, m)                        # 1 - 2*fired
    a.ldmod(m, 1)                         # R
    a.sub(k, k, m)                        # 1 - R
    a.mulf(t, k, t)
    a.splat(k, eta_homeo)
    a.mulf(t, k, t)                       # homeostatic escape term
    a.add(e, e, t)                        # r0 = dw
    return a.build()
