"""Trace-time specializer: compile a *concrete* PPU-VM word stream into
straight-line jnp ops.

The scan interpreter (``interp.run_program_jax``) pays a ``lax.switch``
over all opcodes per instruction — ~5.3x rule-only overhead vs the
fixed-function path (``BENCH_pr2_ppuvm.json``). But almost every real use
runs a program that is *static at jit time*: the word stream is a host
array or a constant closed over by the jitted trial. In that case the VM
dispatch can happen at TRACE time — decode each word in Python and call
only the branch that instruction actually takes — and the jitted graph is
exactly what a hand-fused implementation of the same rule would produce
(XLA then fuses the straight-line integer ops and dead-code-eliminates
unread registers). The uploadable-words interface is unchanged: the same
int32 program image feeds every executor.

This is the software analogue of the hardware flow in paper §3.1: the
program is "compiled onto" the substrate ahead of execution, while the
scan interpreter remains the general path for traced word streams, and
the NumPy interpreter the independent reference.

Semantics are NOT re-implemented here: each unrolled instruction invokes
the same ``interp.make_branches`` table the scan interpreter (and the
Pallas tile VM) dispatches through — only the dispatch is erased — so the
specializer cannot fork from the other JAX executors. Bit-exact
equivalence of all executors is additionally enforced by
``tests/test_ppuvm_fuzz.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.ppuvm import isa
from repro.ppuvm.interp import make_semantics, prepare_operands


def run_program_specialized(words, weights, qc, qa, rates, mod=None,
                            noise=None):
    """Unroll a concrete word stream into straight-line jnp ops.

    ``words`` must be concrete (NumPy array, host list, or a non-traced
    device array) — a traced stream cannot be decoded at trace time; use
    the "scan" executor for that (``interp.resolve_executor`` does this
    automatically for ``executor="auto"``).

    Same signature and return convention as ``interp.run_program_jax``:
    ``(weights_out int32 [..., R, C], regs int32 [N_REGS, ..., R, C])``.
    """
    if isinstance(words, jax.core.Tracer):
        raise TypeError(
            "specialized executor needs a concrete word stream (got a "
            "tracer) — pass the program as a closed-over constant, or use "
            'executor="scan"')
    words = np.asarray(words, np.int64)

    lane_shape = weights.shape
    wmem, qc, qa, rates_fx, mod, noise = prepare_operands(
        weights, qc, qa, rates, mod, noise)
    sem = make_semantics(lane_shape, qc, qa, rates_fx, mod, noise)
    # registers as a Python LIST (not a stacked array): every write is a
    # plain rebind, so the emitted graph is pure straight-line dataflow
    # and XLA dead-code-eliminates registers the program never stores
    regs = [jnp.zeros(lane_shape, jnp.int32) for _ in range(isa.N_REGS)]

    for word in words:
        op, rd, ra, rb, sh, simm = isa.decode(int(word))
        if op >= isa.N_OPS:
            continue                  # unknown opcodes are NOPs everywhere
        rd %= isa.N_REGS
        val, wmem = sem[op](regs[ra % isa.N_REGS], regs[rb % isa.N_REGS],
                            regs[rd], wmem, sh, simm)
        if val is not None:
            regs[rd] = val
    return wmem, jnp.stack(regs)


# ---------------------------------------------------------------------------
# Jitted-closure cache: one compiled specialization per program image
# ---------------------------------------------------------------------------
#
# The specializer re-decodes the word stream in Python on every trace. For
# workloads that run many programs repeatedly — a playback suite uploading
# dozens of rules, a sweep re-binding the same rule per configuration —
# that is a retrace per upload. The cache memoizes ONE jitted closure per
# program image, keyed on the raw word bytes: re-running (or re-uploading)
# a program reuses the compiled executable via jax's own shape-keyed jit
# cache underneath, and calling the closure inside an outer trace inlines
# the cached jaxpr instead of unrolling the decode loop again.
#
# LRU-bounded: each entry pins an unrolled jaxpr + compiled executable, so
# an unbounded dict would leak in workloads sweeping many one-off programs
# (e.g. the differential fuzz corpus). 64 entries comfortably covers every
# real suite (playback uploads a handful of rules) while bounding memory.

_CACHE = {}                       # insertion-ordered = LRU via re-insert
_CACHE_MAX = 64
_STATS = dict(hits=0, misses=0, evictions=0)


def specialized_callable(words):
    """The memoized jitted form of ``run_program_specialized`` for a
    concrete program image: ``fn(weights, qc, qa, rates, mod, noise)``.
    Identical word bytes -> the same jitted closure object."""
    if isinstance(words, jax.core.Tracer):
        raise TypeError(
            "specialized executor needs a concrete word stream (got a "
            "tracer) — pass the program as a closed-over constant, or use "
            'executor="scan"')
    words_np = np.asarray(words, np.int64)
    key = words_np.tobytes()
    fn = _CACHE.pop(key, None)
    if fn is None:
        _STATS["misses"] += 1
        fn = jax.jit(functools.partial(run_program_specialized, words_np))
        while len(_CACHE) >= _CACHE_MAX:        # evict least-recently used
            _CACHE.pop(next(iter(_CACHE)))
            _STATS["evictions"] += 1
    else:
        _STATS["hits"] += 1
    _CACHE[key] = fn                            # (re-)insert as most recent
    return fn


def cache_stats():
    """hits/misses/evictions/size/max_size of the specialized-closure
    cache. ``misses > max_size`` over a bounded workload is the
    eviction-storm signature: the working set no longer fits and every
    upload recompiles (see ``repro.obs.timing.eviction_storm``)."""
    return dict(_STATS, size=len(_CACHE), max_size=_CACHE_MAX)


def cache_clear():
    _CACHE.clear()
    _STATS.update(hits=0, misses=0, evictions=0)
