"""Tiny assembler / program builder for the PPU-VM ISA.

``Asm`` accumulates instructions and emits a dense ``int32`` word array —
the artifact that crosses the playback-program boundary (the co-development
story of paper §3.1: the SAME word stream executes on the optimized JAX
interpreter and the independent NumPy one).

    a = Asm()
    w, elig = a.reg("w"), a.reg("elig")
    a.ldw(w)
    a.ldcausal(elig)
    ...
    words = a.build()
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.ppuvm import isa


class Asm:
    def __init__(self):
        self.words: List[int] = []
        self._names: Dict[str, int] = {}

    # -- register allocation ------------------------------------------------
    def reg(self, name: str) -> int:
        """Allocate (or look up) a named register."""
        if name not in self._names:
            if len(self._names) >= isa.N_REGS:
                raise ValueError(f"out of registers (n_regs={isa.N_REGS})")
            self._names[name] = len(self._names)
        return self._names[name]

    # -- emit helpers ---------------------------------------------------------
    def _emit(self, op, rd=0, ra=0, imm16=0) -> "Asm":
        self.words.append(isa.encode(op, rd, ra, imm16))
        return self

    def nop(self):
        return self._emit(isa.NOP)

    def splat(self, rd, value: float):
        """rd <- Q8.8 constant (saturating encode of ``value``)."""
        return self._emit(isa.SPLAT, rd, 0, isa.splat_imm(value))

    def mov(self, rd, ra):
        return self._emit(isa.MOV, rd, ra)

    def add(self, rd, ra, rb):
        return self._emit(isa.ADD, rd, ra, isa.alu_imm(rb))

    def sub(self, rd, ra, rb):
        return self._emit(isa.SUB, rd, ra, isa.alu_imm(rb))

    def mulf(self, rd, ra, rb, shift: int = isa.FRAC):
        """Fracsat multiply: rd <- sat((ra*rb + round) >> shift)."""
        return self._emit(isa.MULF, rd, ra, isa.alu_imm(rb, shift))

    def shl(self, rd, ra, shamt: int):
        return self._emit(isa.SHL, rd, ra, isa.alu_imm(0, shamt))

    def shr(self, rd, ra, shamt: int):
        return self._emit(isa.SHR, rd, ra, isa.alu_imm(0, shamt))

    def cmpge(self, rd, ra, rb):
        return self._emit(isa.CMPGE, rd, ra, isa.alu_imm(rb))

    def sel(self, rd, ra, rb):
        """Blend: rd <- ra where rd != 0 else rb."""
        return self._emit(isa.SEL, rd, ra, isa.alu_imm(rb))

    def vmax(self, rd, ra, rb):
        return self._emit(isa.MAXS, rd, ra, isa.alu_imm(rb))

    def vmin(self, rd, ra, rb):
        return self._emit(isa.MINS, rd, ra, isa.alu_imm(rb))

    def ldw(self, rd):
        return self._emit(isa.LDW, rd)

    def stw(self, ra):
        return self._emit(isa.STW, 0, ra)

    def ldcausal(self, rd):
        return self._emit(isa.LDCAUSAL, rd)

    def ldacausal(self, rd):
        return self._emit(isa.LDACAUSAL, rd)

    def ldrate(self, rd):
        return self._emit(isa.LDRATE, rd)

    def ldmod(self, rd, slot: int = 0):
        return self._emit(isa.LDMOD, rd, 0, slot)

    def ldnoise(self, rd):
        return self._emit(isa.LDNOISE, rd)

    # -- build ----------------------------------------------------------------
    def build(self) -> np.ndarray:
        """Dense int32 instruction words (the uploadable program image)."""
        return np.asarray(self.words, np.int32)

    def disassemble(self) -> str:
        return isa.disassemble(self.build())
