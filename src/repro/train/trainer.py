"""Training loop with fault tolerance.

Features exercised by tests/test_trainer.py and examples/train_lm.py:
  * resume-from-checkpoint (params + optimizer + data cursor), bit-exact;
  * elastic restart: the checkpoint re-places onto a different mesh;
  * simulated node failure (``fail_at_step``) for the restart test;
  * optional int8 gradient compression with error feedback;
  * gradient accumulation (microbatching).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, ShapeConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.transformer import build_model
from repro.parallel import compress as gc
from repro.parallel.sharding import (ShardingCtx, abstract_params,
                                     init_params, tree_pspecs)
from repro.train.optimizer import AdamWConfig, adamw_init_decls, adamw_update


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    accum_steps: int = 1
    grad_compress_bits: int = 0      # 0 = off
    fail_at_step: int = -1           # simulate a crash (before ckpt) at step
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 tcfg: TrainerConfig, ctx: Optional[ShardingCtx] = None):
        self.arch, self.shape, self.tcfg = arch, shape, tcfg
        self.ctx = ctx or ShardingCtx()
        self.bundle = build_model(arch, self.ctx)
        self.pipeline = SyntheticLMPipeline(arch, shape, seed=tcfg.seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=3)
        self._build_step()

    # -- step ----------------------------------------------------------------
    def _build_step(self):
        tcfg = self.tcfg
        grad_fn = jax.value_and_grad(self.bundle.loss)

        def step_fn(params, opt_state, err, batch):
            if tcfg.accum_steps == 1:
                loss, grads = grad_fn(params, batch)
            else:
                n = tcfg.accum_steps
                loss = 0.0
                grads = None
                for i in range(n):
                    mb = {k: v[i * (v.shape[0] // n):(i + 1) * (v.shape[0] // n)]
                          for k, v in batch.items()}
                    li, gi = grad_fn(params, mb)
                    loss = loss + li / n
                    gi = jax.tree.map(lambda g: g / n, gi)
                    grads = gi if grads is None else jax.tree.map(
                        jnp.add, grads, gi)
            if tcfg.grad_compress_bits:
                grads, err = gc.ef_compress_grads(grads, err,
                                                  tcfg.grad_compress_bits)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 tcfg.opt)
            return params, opt_state, err, dict(loss=loss, **om)

        kwargs = {}
        if self.ctx.mesh is not None:
            p_sh = tree_pspecs(self.bundle.decls, self.ctx)
            o_sh = tree_pspecs(adamw_init_decls(self.bundle.decls), self.ctx)
            e_sh = p_sh if self.tcfg.grad_compress_bits else None
            kwargs = dict(in_shardings=(p_sh, o_sh, e_sh, None),
                          out_shardings=(p_sh, o_sh, e_sh, None))
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1, 2), **kwargs)

    # -- state ----------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = init_params(self.bundle.decls, key, self.ctx)
        opt = init_params(adamw_init_decls(self.bundle.decls),
                          jax.random.PRNGKey(0), self.ctx)
        err = (gc.ef_init(params) if self.tcfg.grad_compress_bits
               else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), {}))
        if not self.tcfg.grad_compress_bits:
            err = {}
        return dict(params=params, opt=opt, err=err, step=0)

    def restore_or_init(self):
        shardings = None
        if self.ctx.mesh is not None:
            shardings = dict(
                params=tree_pspecs(self.bundle.decls, self.ctx),
                opt=tree_pspecs(adamw_init_decls(self.bundle.decls), self.ctx))
        step, state = self.ckpt.restore_latest()
        if state is None:
            return self.init_state()
        data_state = state.pop("data")
        self.pipeline.load_state_dict(data_state)
        if shardings is not None:
            for k in ("params", "opt"):
                flat_s = jax.tree.leaves(shardings[k])
                # re-place elastically onto the current mesh
                state[k] = jax.tree.map(
                    lambda v, s: jax.device_put(jnp.asarray(v), s),
                    state[k], shardings[k])
        else:
            state = jax.tree.map(jnp.asarray, state)
        state["step"] = int(step)
        if "err" not in state:
            state["err"] = {}
        return state

    # -- loop ----------------------------------------------------------------
    def train(self, resume: bool = True) -> Dict[str, Any]:
        st = self.restore_or_init() if resume else self.init_state()
        params, opt, err = st["params"], st["opt"], st["err"]
        start = st["step"]
        history = []
        for step in range(start, self.tcfg.steps):
            if step == self.tcfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.pipeline.next_batch()
            t0 = time.perf_counter()
            params, opt, err, metrics = self.step_fn(params, opt, err, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            history.append(dict(step=step, loss=loss, sec=dt))
            if step % self.tcfg.log_every == 0:
                print(f"step {step}: loss {loss:.4f} ({dt*1e3:.0f} ms)",
                      flush=True)
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, dict(
                    params=params, opt=opt, err=err,
                    data=self.pipeline.state_dict()))
        self.ckpt.wait()
        return dict(params=params, opt=opt, history=history)
