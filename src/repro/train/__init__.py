from repro.train.optimizer import adamw_init_decls, adamw_update, sgd_update  # noqa: F401
from repro.train.steps import make_train_step  # noqa: F401
