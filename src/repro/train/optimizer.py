"""Pure-JAX optimizers (no external deps): AdamW + SGD-momentum.

Optimizer state is declared with the *same logical axes* as the parameters,
so first/second moments shard identically to their weights (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamDecl


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100


def _is_decl(x):
    return isinstance(x, ParamDecl)


def adamw_init_decls(param_decls) -> dict:
    """Moment declarations mirroring the param tree (zeros, same axes)."""
    zero = lambda d: ParamDecl(d.shape, d.axes, init="zeros", dtype=d.dtype)
    return dict(
        m=jax.tree.map(zero, param_decls, is_leaf=_is_decl),
        v=jax.tree.map(zero, param_decls, is_leaf=_is_decl),
        step=ParamDecl((), (), init="zeros", dtype=jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = _schedule(cfg, opt_state["step"])
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step_dir).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, dict(m=new_m, v=new_v, step=step), dict(grad_norm=gn, lr=lr)


def sgd_update(params, grads, opt_state, lr: float = 1e-2, momentum: float = 0.9):
    def upd(p, g, m):
        m = momentum * m + g.astype(jnp.float32)
        return (p - lr * m).astype(p.dtype), m
    out = jax.tree.map(upd, params, grads, opt_state["m"])
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, dict(m=new_m, step=opt_state["step"] + 1), {}
