"""Step builders shared by the trainer, the dry-run, and the benchmarks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelBundle
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(bundle: ModelBundle, opt_cfg: Optional[AdamWConfig] = None,
                    accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches accumulated in a python loop (exact HLO cost; overlappable
    by XLA's latency-hiding scheduler).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    grad_fn = jax.value_and_grad(bundle.loss)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // accum_steps
                return x[i * mb:(i + 1) * mb]
            loss = 0.0
            grads = None
            for i in range(accum_steps):
                mb = {k: slice_mb(v, i) for k, v in batch.items()}
                li, gi = grad_fn(params, mb)
                loss = loss + li / accum_steps
                if grads is None:
                    grads = jax.tree.map(lambda g: g / accum_steps, gi)
                else:
                    grads = jax.tree.map(lambda a, g: a + g / accum_steps,
                                         grads, gi)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(loss=loss, **om)
        return new_params, new_opt, metrics

    return train_step
