"""Wafer topologies and multi-chip network plans.

The BrainScaleS line scales the single 512-neuron / 130K-synapse chip to
wafers of interconnected chips; spikes cross chip boundaries as address-
tagged records on the inter-chip event bus. This module is the *static*
side of that picture: which chips exist, which links connect them, and
which (source column -> destination row) routes ride on each link. The
dynamic side — moving the actual event records each window — lives in
``repro.wafer.router``.

Everything here is host-side numpy: plans are built and validated once,
then the router turns them into constant index tables of the jitted
program.

The correctness anchor is ``monolithic_plan``: any K-chip plan maps to an
equivalent 1-chip plan whose synapse matrix is the block-diagonal
embedding of the per-chip matrices and whose routes are the same routes
in global coordinates. Off-block weights are exactly zero, and a zero
6-bit weight contributes an exact-zero term to the per-column FMA chain
(0.0 + x == x for the nonnegative operands involved), so the split and
monolithic emulations are bit-identical — the split-vs-monolithic
contract ``tests/test_wafer.py`` asserts with ``assert_array_equal``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class WaferTopology:
    """K chips and the directed inter-chip links between them.

    ``kind``:
      "ring"     chip k -> chip (k+1) % K (the neighbor topology the
                 router exchanges with ``ppermute``); K == 1 degenerates
                 to the single self-link.
      "all2all"  every ordered pair INCLUDING self-links (the wafer bus
                 loops back on-chip), exchanged with a masked
                 ``all_gather`` — arbitrary fan-in.
    """
    n_chips: int
    kind: str = "ring"

    def __post_init__(self):
        assert self.n_chips >= 1
        if self.kind not in ("ring", "all2all"):
            raise ValueError(f"unknown topology kind {self.kind!r}")

    def links(self) -> Tuple[Tuple[int, int], ...]:
        """Directed (src_chip, dst_chip) links, src-major order — the
        link index order every router table uses."""
        k = self.n_chips
        if self.kind == "ring":
            return tuple((s, (s + 1) % k) for s in range(k))
        return tuple((s, d) for s in range(k) for d in range(k))

    @property
    def n_links(self) -> int:
        return len(self.links())

    @property
    def links_per_chip(self) -> int:
        """Out-links per source chip — uniform for both kinds, which is
        what lets the sharded transport slice its local link block by
        device rank."""
        return self.n_links // self.n_chips


@dataclass(frozen=True)
class WaferPlan:
    """A topology plus the route list riding on it.

    Each route forwards spikes of ``(src_chip, src_col)`` to input row
    ``(dst_chip, dst_row)`` where they arrive as events carrying
    ``addr`` — the ``(t, row, addr, efficacy)`` record of the event bus.
    Routes are arrays (not per-pair tables) so arbitrary fan-out/fan-in
    is just more rows in the list.
    """
    topology: WaferTopology
    n_rows: int                       # synapse rows per chip
    n_cols: int                       # neuron columns per chip
    src_chip: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    src_col: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    dst_chip: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    dst_row: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    addr: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))

    def __post_init__(self):
        k, r, c = self.topology.n_chips, self.n_rows, self.n_cols
        arrs = (self.src_chip, self.src_col, self.dst_chip, self.dst_row,
                self.addr)
        n = len(self.src_chip)
        assert all(len(a) == n for a in arrs), "ragged route arrays"
        if n == 0:
            return
        assert (0 <= self.src_chip).all() and (self.src_chip < k).all()
        assert (0 <= self.dst_chip).all() and (self.dst_chip < k).all()
        assert (0 <= self.src_col).all() and (self.src_col < c).all()
        assert (0 <= self.dst_row).all() and (self.dst_row < r).all()
        assert (0 <= self.addr).all() and (self.addr < 64).all(), \
            "event addresses are 6-bit"
        links = set(self.topology.links())
        used = set(zip(self.src_chip.tolist(), self.dst_chip.tolist()))
        assert used <= links, f"routes use non-links: {sorted(used - links)}"
        # a destination row is one physical driver: every route landing on
        # it must deliver the same event address
        key = self.dst_chip.astype(np.int64) * r + self.dst_row
        for g in np.unique(key):
            a = self.addr[key == g]
            assert (a == a[0]).all(), \
                f"conflicting addresses on dst row {divmod(int(g), r)}"

    @property
    def n_routes(self) -> int:
        return len(self.src_chip)

    def relay_rows(self) -> np.ndarray:
        """[K, R] bool — rows some route delivers into."""
        m = np.zeros((self.topology.n_chips, self.n_rows), bool)
        m[self.dst_chip, self.dst_row] = True
        return m

    def dst_addr_grid(self) -> np.ndarray:
        """[K, R] int8 — the (validated-unique) event address each relay
        row receives; 0 on non-relay rows."""
        g = np.zeros((self.topology.n_chips, self.n_rows), np.int8)
        g[self.dst_chip, self.dst_row] = self.addr.astype(np.int8)
        return g


def make_plan(topology: WaferTopology, n_rows: int, n_cols: int,
              routes: Sequence[Tuple[int, int, int, int, int]]) -> WaferPlan:
    """Plan from a route list of (src_chip, src_col, dst_chip, dst_row,
    addr) tuples."""
    a = np.asarray(list(routes), np.int32).reshape(-1, 5)
    return WaferPlan(topology=topology, n_rows=n_rows, n_cols=n_cols,
                     src_chip=a[:, 0], src_col=a[:, 1], dst_chip=a[:, 2],
                     dst_row=a[:, 3], addr=a[:, 4])


def monolithic_plan(plan: WaferPlan) -> WaferPlan:
    """The K-chip plan as ONE big virtual chip: global row/col coordinates
    (chip-block-contiguous: global row = chip * R + row, global col =
    chip * C + col) and every route on the single self-link. Pair with
    ``monolithic_weights`` to build the block-diagonal synapse matrix."""
    k, r, c = plan.topology.n_chips, plan.n_rows, plan.n_cols
    return WaferPlan(
        topology=WaferTopology(1, plan.topology.kind),
        n_rows=k * r, n_cols=k * c,
        src_chip=np.zeros(plan.n_routes, np.int32),
        src_col=plan.src_chip * c + plan.src_col,
        dst_chip=np.zeros(plan.n_routes, np.int32),
        dst_row=plan.dst_chip * r + plan.dst_row,
        addr=plan.addr.copy())


def monolithic_weights(per_chip: np.ndarray) -> np.ndarray:
    """[K, R, C] per-chip synapse planes -> [K*R, K*C] block-diagonal
    monolithic plane (off-block entries zero — exact-zero FMA terms, see
    module docstring). Works for weights and addresses alike."""
    k, r, c = per_chip.shape
    out = np.zeros((k * r, k * c), per_chip.dtype)
    for i in range(k):
        out[i * r:(i + 1) * r, i * c:(i + 1) * c] = per_chip[i]
    return out


def s5_column_plan(n_chips: int, n_inputs: int, n_neurons: int,
                   relay: bool = True, kind: str = "all2all") -> WaferPlan:
    """Wafer partition of the §5 pattern-discrimination network: the
    neuron columns split over ``n_chips`` contiguous blocks (all 2I input
    rows replicated per chip — every chip sees the full stimulus).

    With ``relay=True`` every global neuron column is also announced to
    every chip over the bus: spikes of global column j arrive one window
    later on row j % 2I carrying address 63. Address 63 matches no §5
    synapse (the experiment wires address 0 throughout), so the relayed
    events add zero synaptic current but exercise the full router path —
    STP and correlation-sensor state on the relay rows evolve with the
    routed traffic, identically on every chip count. Requires
    ``kind="all2all"`` (self-links included) so all chips, including the
    spike's own, receive the same broadcast.
    """
    r = 2 * n_inputs
    assert n_neurons % n_chips == 0
    c_loc = n_neurons // n_chips
    routes = []
    if relay:
        assert kind == "all2all", "the §5 relay broadcast needs all2all"
        for j in range(n_neurons):
            for d in range(n_chips):
                routes.append((j // c_loc, j % c_loc, d, j % r, 63))
    return make_plan(WaferTopology(n_chips, kind), r, c_loc, routes)
