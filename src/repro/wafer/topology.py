"""Wafer topologies and multi-chip network plans.

The BrainScaleS line scales the single 512-neuron / 130K-synapse chip to
wafers of interconnected chips; spikes cross chip boundaries as address-
tagged records on the inter-chip event bus. This module is the *static*
side of that picture: which chips exist, which links connect them, and
which (source column -> destination row) routes ride on each link. The
dynamic side — moving the actual event records each window — lives in
``repro.wafer.router``.

Everything here is host-side numpy: plans are built and validated once,
then the router turns them into constant index tables of the jitted
program.

The correctness anchor is ``monolithic_plan``: any K-chip plan maps to an
equivalent 1-chip plan whose synapse matrix is the block-diagonal
embedding of the per-chip matrices and whose routes are the same routes
in global coordinates. Off-block weights are exactly zero, and a zero
6-bit weight contributes an exact-zero term to the per-column FMA chain
(0.0 + x == x for the nonnegative operands involved), so the split and
monolithic emulations are bit-identical — the split-vs-monolithic
contract ``tests/test_wafer.py`` asserts with ``assert_array_equal``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class WaferTopology:
    """K chips and the directed inter-chip links between them.

    ``kind``:
      "ring"     chip k -> chip (k+1) % K (the neighbor topology the
                 router exchanges with ``ppermute``); K == 1 degenerates
                 to the single self-link.
      "all2all"  every ordered pair INCLUDING self-links (the wafer bus
                 loops back on-chip), exchanged with a masked
                 ``all_gather`` — arbitrary fan-in.

    Args:
      n_chips: K >= 1 logical chips.
      kind: "ring" | "all2all" (see above).

    Contract pointers: link-order and transport invariants in
    tests/test_wafer.py; the mapper consumes ``links()`` to decide
    direct-vs-relay routing (tests/test_mapper.py).
    """
    n_chips: int
    kind: str = "ring"

    def __post_init__(self):
        assert self.n_chips >= 1
        if self.kind not in ("ring", "all2all"):
            raise ValueError(f"unknown topology kind {self.kind!r}")

    def links(self) -> Tuple[Tuple[int, int], ...]:
        """Directed (src_chip, dst_chip) links, src-major order — the
        link index order every router table uses."""
        k = self.n_chips
        if self.kind == "ring":
            return tuple((s, (s + 1) % k) for s in range(k))
        return tuple((s, d) for s in range(k) for d in range(k))

    @property
    def n_links(self) -> int:
        return len(self.links())

    @property
    def links_per_chip(self) -> int:
        """Out-links per source chip — uniform for both kinds, which is
        what lets the sharded transport slice its local link block by
        device rank."""
        return self.n_links // self.n_chips


@dataclass(frozen=True)
class WaferPlan:
    """A topology plus the route list riding on it.

    Each route forwards spikes of ``(src_chip, src_col)`` to input row
    ``(dst_chip, dst_row)`` where they arrive as events carrying
    ``addr`` — the ``(t, row, addr, efficacy)`` record of the event bus.
    Routes are arrays (not per-pair tables) so arbitrary fan-out/fan-in
    is just more rows in the list.

    FORWARD rules (``fwd_*``, normally empty) are the failover hop
    ``reroute_plan`` emits around a blacklisted link: chip
    ``fwd_src_chip`` re-transmits the events its OWN relay row
    ``fwd_src_row`` received last window over the link to
    ``fwd_dst_chip``, delivering into ``fwd_dst_row`` with ``fwd_addr``.
    Forwarded traffic therefore arrives two windows after the source
    spike (one normal hop + one relay hop) and is counted by the router
    in the ``link_reroutes`` telemetry counter. The network mapper
    (``repro.mapper``) emits the same rules for ring edges with no
    direct link — one transit row + one forward per relayed edge.

    Args:
      topology: the ``WaferTopology`` the routes ride on.
      n_rows / n_cols: per-chip synapse-row / neuron-column geometry.
      src_chip, src_col, dst_chip, dst_row, addr: parallel int32 route
        arrays — spikes of ``(src_chip, src_col)`` become events on
        ``(dst_chip, dst_row)`` carrying ``addr``.
      fwd_*: parallel forward-rule arrays (see above; normally empty).

    Validation (``__post_init__``) rejects out-of-range indices, routes
    over links the topology does not have, duplicate or conflicting
    addresses on one destination row, and forwards reading rows no
    route delivers into — a plan that constructs is executable.

    Contract pointers: tests/test_wafer.py (split == monolithic,
    failover), tests/test_mapper.py (mapper-emitted plans validate and
    round-trip).
    """
    topology: WaferTopology
    n_rows: int                       # synapse rows per chip
    n_cols: int                       # neuron columns per chip
    src_chip: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    src_col: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    dst_chip: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    dst_row: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    addr: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    fwd_src_chip: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    fwd_src_row: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    fwd_dst_chip: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    fwd_dst_row: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    fwd_addr: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))

    def __post_init__(self):
        k, r, c = self.topology.n_chips, self.n_rows, self.n_cols
        arrs = (self.src_chip, self.src_col, self.dst_chip, self.dst_row,
                self.addr)
        n = len(self.src_chip)
        assert all(len(a) == n for a in arrs), "ragged route arrays"
        farrs = (self.fwd_src_chip, self.fwd_src_row, self.fwd_dst_chip,
                 self.fwd_dst_row, self.fwd_addr)
        nf = len(self.fwd_src_chip)
        assert all(len(a) == nf for a in farrs), "ragged forward arrays"
        links = set(self.topology.links())
        if n:
            assert (0 <= self.src_chip).all() and (self.src_chip < k).all()
            assert (0 <= self.dst_chip).all() and (self.dst_chip < k).all()
            assert (0 <= self.src_col).all() and (self.src_col < c).all()
            assert (0 <= self.dst_row).all() and (self.dst_row < r).all()
            assert (0 <= self.addr).all() and (self.addr < 64).all(), \
                "event addresses are 6-bit"
            used = set(zip(self.src_chip.tolist(), self.dst_chip.tolist()))
            assert used <= links, \
                f"routes use non-links: {sorted(used - links)}"
        if nf:
            assert (0 <= self.fwd_src_chip).all() \
                and (self.fwd_src_chip < k).all()
            assert (0 <= self.fwd_dst_chip).all() \
                and (self.fwd_dst_chip < k).all()
            assert (0 <= self.fwd_src_row).all() \
                and (self.fwd_src_row < r).all()
            assert (0 <= self.fwd_dst_row).all() \
                and (self.fwd_dst_row < r).all()
            assert (0 <= self.fwd_addr).all() and (self.fwd_addr < 64).all()
            fused = set(zip(self.fwd_src_chip.tolist(),
                            self.fwd_dst_chip.tolist()))
            assert fused <= links, \
                f"forwards use non-links: {sorted(fused - links)}"
            # forwards re-transmit received traffic: the read row must be
            # a route delivery target on the forwarding chip
            rr = np.zeros((k, r), bool)
            if n:
                rr[self.dst_chip, self.dst_row] = True
            assert rr[self.fwd_src_chip, self.fwd_src_row].all(), \
                "forward reads a row no route delivers into"
        if n + nf == 0:
            return
        # a destination row is one physical driver: every delivery landing
        # on it (route or forward) must carry the same event address
        dst_c = np.concatenate([self.dst_chip, self.fwd_dst_chip])
        dst_r = np.concatenate([self.dst_row, self.fwd_dst_row])
        dst_a = np.concatenate([self.addr, self.fwd_addr])
        key = dst_c.astype(np.int64) * r + dst_r
        for g in np.unique(key):
            a = dst_a[key == g]
            assert (a == a[0]).all(), \
                f"conflicting addresses on dst row {divmod(int(g), r)}"

    @property
    def n_routes(self) -> int:
        return len(self.src_chip)

    @property
    def n_forwards(self) -> int:
        return len(self.fwd_src_chip)

    @property
    def n_deliveries(self) -> int:
        return self.n_routes + self.n_forwards

    def relay_rows(self) -> np.ndarray:
        """[K, R] bool — rows some delivery (route or forward) lands in."""
        m = np.zeros((self.topology.n_chips, self.n_rows), bool)
        m[self.dst_chip, self.dst_row] = True
        m[self.fwd_dst_chip, self.fwd_dst_row] = True
        return m

    def dst_addr_grid(self) -> np.ndarray:
        """[K, R] int8 — the (validated-unique) event address each relay
        row receives; 0 on non-relay rows."""
        g = np.zeros((self.topology.n_chips, self.n_rows), np.int8)
        g[self.dst_chip, self.dst_row] = self.addr.astype(np.int8)
        g[self.fwd_dst_chip, self.fwd_dst_row] = self.fwd_addr.astype(np.int8)
        return g


def make_plan(topology: WaferTopology, n_rows: int, n_cols: int,
              routes: Sequence[Tuple[int, int, int, int, int]]) -> WaferPlan:
    """Plan from a route list of (src_chip, src_col, dst_chip, dst_row,
    addr) tuples."""
    a = np.asarray(list(routes), np.int32).reshape(-1, 5)
    return WaferPlan(topology=topology, n_rows=n_rows, n_cols=n_cols,
                     src_chip=a[:, 0], src_col=a[:, 1], dst_chip=a[:, 2],
                     dst_row=a[:, 3], addr=a[:, 4])


def monolithic_plan(plan: WaferPlan) -> WaferPlan:
    """The K-chip plan as ONE big virtual chip: global row/col coordinates
    (chip-block-contiguous: global row = chip * R + row, global col =
    chip * C + col) and every route on the single self-link. Pair with
    ``monolithic_weights`` to build the block-diagonal synapse matrix."""
    assert plan.n_forwards == 0, \
        "monolithic embedding of forward rules is not defined (forwards " \
        "deliver one window late by construction)"
    k, r, c = plan.topology.n_chips, plan.n_rows, plan.n_cols
    return WaferPlan(
        topology=WaferTopology(1, plan.topology.kind),
        n_rows=k * r, n_cols=k * c,
        src_chip=np.zeros(plan.n_routes, np.int32),
        src_col=plan.src_chip * c + plan.src_col,
        dst_chip=np.zeros(plan.n_routes, np.int32),
        dst_row=plan.dst_chip * r + plan.dst_row,
        addr=plan.addr.copy())


def monolithic_weights(per_chip: np.ndarray) -> np.ndarray:
    """[K, R, C] per-chip synapse planes -> [K*R, K*C] block-diagonal
    monolithic plane (off-block entries zero — exact-zero FMA terms, see
    module docstring). Works for weights and addresses alike."""
    k, r, c = per_chip.shape
    out = np.zeros((k * r, k * c), per_chip.dtype)
    for i in range(k):
        out[i * r:(i + 1) * r, i * c:(i + 1) * c] = per_chip[i]
    return out


def s5_column_plan(n_chips: int, n_inputs: int, n_neurons: int,
                   relay: bool = True, kind: str = "all2all") -> WaferPlan:
    """Wafer partition of the §5 pattern-discrimination network: the
    neuron columns split over ``n_chips`` contiguous blocks (all 2I input
    rows replicated per chip — every chip sees the full stimulus).

    With ``relay=True`` every global neuron column is also announced to
    every chip over the bus: spikes of global column j arrive one window
    later on row j % 2I carrying address 63. Address 63 matches no §5
    synapse (the experiment wires address 0 throughout), so the relayed
    events add zero synaptic current but exercise the full router path —
    STP and correlation-sensor state on the relay rows evolve with the
    routed traffic, identically on every chip count. Requires
    ``kind="all2all"`` (self-links included) so all chips, including the
    spike's own, receive the same broadcast.
    """
    r = 2 * n_inputs
    assert n_neurons % n_chips == 0
    c_loc = n_neurons // n_chips
    routes = []
    if relay:
        assert kind == "all2all", "the §5 relay broadcast needs all2all"
        for j in range(n_neurons):
            for d in range(n_chips):
                routes.append((j // c_loc, j % c_loc, d, j % r, 63))
    return make_plan(WaferTopology(n_chips, kind), r, c_loc, routes)


def reroute_plan(plan: WaferPlan, dead_links,
                 relay_addr: int = 63) -> Tuple[WaferPlan, int]:
    """Host-side failover around blacklisted links: every route riding a
    dead ``(src_chip, dst_chip)`` pair is re-established over an
    intermediate chip ``m`` with alive links, preferring REUSE of bus
    traffic ``m`` already receives — if exactly one alive route delivers
    this very ``(src_chip, src_col)`` spike train into relay row ``rho``
    on ``m``, failover is just the forward rule ``(m, rho) -> (dst_chip,
    dst_row)``; otherwise a fresh relay row is allocated on ``m`` (a row
    no delivery touches — external drive on it is the caller's concern)
    and both hops are added. A ring topology with no usable intermediate
    is PROMOTED to all2all (the physical bus connects any pair; the ring
    is a schedule, not a wire list) — the dead pair itself of course
    stays dead. Forwarded events arrive one window later than the direct
    route would have delivered them.

    Returns ``(new_plan, n_rerouted)`` and raises ``ValueError`` when no
    failover exists (K == 2, saturated relay rows, dead detours) —
    degradation is never silent.
    """
    dead = {(int(s), int(d)) for s, d in dead_links}
    if not dead:
        return plan, 0
    assert plan.n_forwards == 0, "reroute_plan expects an unrerouted plan"
    K, R = plan.topology.n_chips, plan.n_rows
    all_routes = list(zip(plan.src_chip.tolist(), plan.src_col.tolist(),
                          plan.dst_chip.tolist(), plan.dst_row.tolist(),
                          plan.addr.tolist()))
    keep = [x for x in all_routes if (x[0], x[2]) not in dead]
    bad = [x for x in all_routes if (x[0], x[2]) in dead]
    if not bad:
        return plan, 0

    def attempt(kind):
        topo = WaferTopology(K, kind)
        alive = set(topo.links()) - dead
        # delivery census over the surviving routes (dead-pair routes are
        # dropped: they deliver nothing)
        n_deliv = np.zeros((K, R), np.int64)
        src_of = {}
        for (s, c, d, row, a) in keep:
            n_deliv[d, row] += 1
            src_of[(d, row)] = (s, c)
        # rows any delivery will touch: kept targets, the bad routes'
        # targets (they become forward targets), plus fresh allocations
        occupied = n_deliv > 0
        for (_, _, d, row, _) in bad:
            occupied[d, row] = True
        bad_targets = {(d, row) for (_, _, d, row, _) in bad}
        new_routes, fwd = list(keep), []
        for (s, c, d, row, a) in bad:
            hit = None
            for (m, rho), sc in src_of.items():
                if (sc == (s, c) and (m, d) in alive
                        and n_deliv[m, rho] == 1
                        and (m, rho) not in bad_targets):
                    hit = (m, rho)
                    break
            if hit is None:
                for m in range(K):
                    if (m in (s, d) or (s, m) not in alive
                            or (m, d) not in alive):
                        continue
                    free = np.nonzero(~occupied[m])[0]
                    if free.size == 0:
                        continue
                    rho = int(free[0])
                    occupied[m, rho] = True
                    n_deliv[m, rho] += 1
                    src_of[(m, rho)] = (s, c)
                    new_routes.append((s, c, m, rho, relay_addr))
                    hit = (m, rho)
                    break
            if hit is None:
                return None
            fwd.append((*hit, d, row, a))
        rt = np.asarray(new_routes, np.int64).reshape(-1, 5)
        fw = np.asarray(fwd, np.int64).reshape(-1, 5)
        return WaferPlan(
            topology=topo, n_rows=R, n_cols=plan.n_cols,
            src_chip=rt[:, 0].astype(np.int32),
            src_col=rt[:, 1].astype(np.int32),
            dst_chip=rt[:, 2].astype(np.int32),
            dst_row=rt[:, 3].astype(np.int32),
            addr=rt[:, 4].astype(np.int32),
            fwd_src_chip=fw[:, 0].astype(np.int32),
            fwd_src_row=fw[:, 1].astype(np.int32),
            fwd_dst_chip=fw[:, 2].astype(np.int32),
            fwd_dst_row=fw[:, 3].astype(np.int32),
            fwd_addr=fw[:, 4].astype(np.int32))

    out = attempt(plan.topology.kind)
    if out is None and plan.topology.kind == "ring":
        out = attempt("all2all")
    if out is None:
        raise ValueError(
            f"no failover for dead links {sorted(dead)}: "
            f"{len(bad)} routes cannot be re-established")
    return out, len(bad)
