"""Wafer-scale multi-chip emulation: topologies, route plans, and the
inter-chip event router (see ``repro.wafer.topology`` /
``repro.wafer.router``)."""
from repro.wafer.router import InterChipRouter, run_windows
from repro.wafer.topology import (WaferPlan, WaferTopology, make_plan,
                                  monolithic_plan, monolithic_weights,
                                  reroute_plan, s5_column_plan)

__all__ = ["InterChipRouter", "run_windows", "WaferPlan", "WaferTopology",
           "make_plan", "monolithic_plan", "monolithic_weights",
           "reroute_plan", "s5_column_plan"]
