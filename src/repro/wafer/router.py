"""The inter-chip event router: one window of bus traffic per call.

Spikes produced on one chip in window ``t`` become input row events on
connected chips in window ``t+1`` — a one-window routing-latency budget,
matching the hardware's inter-chip bus delay. Per window the router

  1. projects each chip's output spikes onto its out-links' route tables
     (per-link [T, R] delivery grids; several routes landing on the same
     ``(t, dst_row)`` slot merge by ``max`` — one physical event per
     driver slot, order-independent and exact);
  2. censuses each link against the per-link event budget (the shared
     ``events.census_fits`` predicate — the same gate as the sparse
     synaptic path, including the per-step bandwidth axis);
  3. exchanges the grids between chips: as compact ``(t, row, addr,
     efficacy)`` ``EventStream`` records (``link_mode="compact"``), as
     the dense grids (``"dense"``), or census-gated between the two
     (``"auto"`` — compact while every link fits, whole-exchange dense
     fallback otherwise, the PR 6 fallback idiom). Overflow is counted in
     telemetry (``count_links``), never silent: compact over budget
     DROPS tail records (visible divergence + counter), auto falls back
     (bit-exact + counter).

Transports: with no mesh (or an instance rule the link collectives
cannot run over) everything is local jnp — the math core. With a mesh
whose single instance axis evenly divides the chip count, the exchange
runs under ``shard_map``: ``ppermute`` moves the one boundary-crossing
link of each device for the ring topology, a masked ``all_gather``
realises arbitrary fan-in for all2all. Both transports are bit-identical
to the local one (asserted in ``tests/test_wafer.py`` on the forced
multi-device CPU).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events
from repro.faults import inject as finject
from repro.obs import trace as obs_trace
from repro.wafer.topology import WaferPlan

_check_kw = None   # shard_map replication-check kwarg, probed on first use


def _shard_map():
    try:
        from jax import shard_map as sm
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map as sm
    global _check_kw
    if _check_kw is None:
        import inspect
        _check_kw = ({"check_vma": False} if "check_vma"
                     in inspect.signature(sm).parameters
                     else {"check_rep": False})
    return sm, _check_kw


class InterChipRouter:
    """Constant route tables + the per-window routing step.

    ``link_budget`` / ``link_step_budget``: static per-link stream
    capacity and per-step bandwidth (defaults: the density-derived
    ``events.default_max_events`` and the no-constraint ``R``).
    ``link_mode``: "auto" (default) | "compact" | "dense".
    ``ctx``: optional ``ShardingCtx`` — enables the shard_map transport
    when its instance rule is a single mesh axis that evenly divides the
    chip count; anything else degrades to the local transport (the same
    graceful degradation as ``ShardingCtx._pspec``).
    ``faults``: a ``repro.faults`` overlay — dead links deliver nothing,
    flaky links drop a deterministic hash-selected fraction of events
    (identical under the local and shard_map transports). ``None`` is
    the identity (no extra ops).

    When the plan carries FORWARD rules (``reroute_plan`` failover),
    pass last window's delivered grid as ``route(..., routed_in=...)``:
    each forwarding chip re-transmits the events its relay row received,
    so rerouted traffic lands one window after the direct route would
    have — and is counted in the ``link_reroutes`` telemetry counter.

    Args:
      plan: a validated ``WaferPlan`` (route + forward tables become
        constant index arrays at construction).
      ctx: optional ``ShardingCtx`` (see above).
      link_budget / link_step_budget: compact-transport capacities
        (see above).
      link_mode: "auto" | "compact" | "dense" (see above).
      faults: ``FaultPlan`` link overlay, or ``None`` (see above).

    Per window, ``route(out_spikes_t, telemetry=, routed_in=)`` turns
    [T, K, C] spikes into the next window's [T, K, R] delivery grid and
    ``merge(routed_ev, ext_ev, ext_addr)`` folds a delivery grid into
    the external inputs (scatter-max — order-independent because routed
    and external events on one row carry the same address).

    Contract pointers: tests/test_wafer.py (split == monolithic,
    overflow counted never silent, transports interchangeable),
    tests/test_faults.py (link faults), tests/test_mapper.py (mapper
    round trips run every window through this router).
    """

    def __init__(self, plan: WaferPlan, ctx=None,
                 link_budget: Optional[int] = None,
                 link_step_budget: Optional[int] = None,
                 link_mode: str = "auto", faults=None):
        if link_mode not in ("auto", "compact", "dense"):
            raise ValueError(f"unknown link_mode {link_mode!r}")
        self.plan = plan
        self.link_mode = link_mode
        self.link_budget = link_budget
        self.link_step_budget = link_step_budget
        self.faults = faults
        topo = plan.topology
        self.K, self.R, self.C = topo.n_chips, plan.n_rows, plan.n_cols
        links = topo.links()
        self.L = len(links)

        # ragged per-link route tables, padded to the max route count per
        # link; padded slots gather column 0 and scatter into the dropped
        # R slot, so they contribute nothing
        per_link = {l: [] for l in range(self.L)}
        link_id = {sd: l for l, sd in enumerate(links)}
        for i in range(plan.n_routes):
            l = link_id[(int(plan.src_chip[i]), int(plan.dst_chip[i]))]
            per_link[l].append((int(plan.src_col[i]), int(plan.dst_row[i])))
        m = max((len(v) for v in per_link.values()), default=0)
        self.M = max(m, 1)
        src = np.zeros((self.L, self.M), np.int32)
        dst = np.full((self.L, self.M), self.R, np.int32)
        for l, v in per_link.items():
            for j, (sc, dr) in enumerate(v):
                src[l, j], dst[l, j] = sc, dr
        self.link_src = jnp.asarray(src)
        self.link_dst = jnp.asarray(dst)
        self.link_from = jnp.asarray([s for s, _ in links])
        self.link_to = jnp.asarray([d for _, d in links])
        # forward tables (failover hops): same padded-ragged layout, but
        # the gather source is a ROW of the previous window's delivered
        # grid instead of a spike column
        per_fwd = {l: [] for l in range(self.L)}
        for i in range(plan.n_forwards):
            l = link_id[(int(plan.fwd_src_chip[i]),
                         int(plan.fwd_dst_chip[i]))]
            per_fwd[l].append((int(plan.fwd_src_row[i]),
                               int(plan.fwd_dst_row[i])))
        mf = max((len(v) for v in per_fwd.values()), default=0)
        self.MF = max(mf, 1)
        fsrc = np.zeros((self.L, self.MF), np.int32)
        fdst = np.full((self.L, self.MF), self.R, np.int32)
        for l, v in per_fwd.items():
            for j, (sr, dr) in enumerate(v):
                fsrc[l, j], fdst[l, j] = sr, dr
        self.fwd_src = jnp.asarray(fsrc)
        self.fwd_dst = jnp.asarray(fdst)
        # per-link delivery address grid (addresses ride with the records)
        ag = np.zeros((self.L, self.R), np.int8)
        for i in range(plan.n_routes):
            l = link_id[(int(plan.src_chip[i]), int(plan.dst_chip[i]))]
            ag[l, int(plan.dst_row[i])] = np.int8(plan.addr[i])
        for i in range(plan.n_forwards):
            l = link_id[(int(plan.fwd_src_chip[i]),
                         int(plan.fwd_dst_chip[i]))]
            ag[l, int(plan.fwd_dst_row[i])] = np.int8(plan.fwd_addr[i])
        self.link_addr = jnp.asarray(ag)
        # receiver-side planes for merge()
        self.dst_addr = jnp.asarray(plan.dst_addr_grid())      # [K, R] int8

        # sharded transport: a single instance mesh axis that evenly
        # divides the chip count — else local transport
        self._axis = None
        self._dp = 1
        if ctx is not None and ctx.mesh is not None:
            axis = ctx.instance_axis_name()
            dp = ctx.dp_size
            if axis is not None and dp > 1 and self.K % dp == 0:
                self._axis = axis
                self._dp = dp
                self._mesh = ctx.mesh
                self._spec_in, self._spec_rep = ctx.link_specs(1, 3)

    # -- static helpers ------------------------------------------------------
    def _budgets(self, T: int) -> Tuple[int, int]:
        b = self.link_budget
        if b is None:
            b = events.default_max_events(T, self.R, 0.05)
        s = self.link_step_budget
        if s is None:
            s = self.R
        return b, min(s, self.R)

    def init_buffer(self, T: int) -> jnp.ndarray:
        """The routed-event carry: [T, K, R] delivery grid (what last
        window's spikes deposit for this window). Starts silent."""
        return jnp.zeros((T, self.K, self.R), jnp.float32)

    # -- chip-local math core ------------------------------------------------
    def _link_grids(self, out_l, link_src, link_dst):
        """[T, Lx, C] per-link source spikes -> [T, Lx, R] delivery grids
        (scatter-max over routes; duplicate (t, row) targets merge)."""
        T, Lx = out_l.shape[0], out_l.shape[1]
        vals = jnp.take_along_axis(out_l, link_src[None], axis=-1)
        l_idx = jnp.arange(Lx)[:, None]
        return jnp.zeros((T, Lx, self.R + 1), jnp.float32).at[
            :, l_idx, link_dst].max(vals)[..., :self.R]

    @staticmethod
    def _census(grids):
        """[T, Lx, R] -> per-link (event count, worst per-step count)."""
        fired = (grids != 0.0).astype(jnp.int32)
        per_step = jnp.sum(fired, axis=-1)                 # [T, Lx]
        return jnp.sum(per_step, axis=0), jnp.max(per_step, axis=0)

    def _pack(self, grids, link_addr, T, budget, step_budget):
        """[T, Lx, R] grids -> per-link EventStream ([Lx, E] leaves)."""
        g = jnp.moveaxis(grids, 1, 0)                      # [Lx, T, R]
        ad = jnp.broadcast_to(link_addr[:, None, :].astype(jnp.int32),
                              g.shape)
        st = events.pack_events_batch(g, ad, budget)
        if step_budget < self.R:
            st = events.truncate_stream(st, T, step_budget)
        return st

    def _unpack(self, st, T):
        ev, _ = events.unpack_events_batch(st, T, self.R)
        return jnp.moveaxis(ev, 0, 1)                      # [T, Lx, R]

    # -- local transport -----------------------------------------------------
    def _route_local(self, out, T, budget, step_budget, routed_in=None):
        grids = self._link_grids(out[:, self.link_from], self.link_src,
                                 self.link_dst)
        n_fwd = None
        if routed_in is not None:
            # failover hops: re-transmit what the relay rows received last
            # window; merged BEFORE census so the bus budget covers the
            # rerouted traffic too
            fgrids = self._link_grids(routed_in[:, self.link_from],
                                      self.fwd_src, self.fwd_dst)
            n_f, _ = self._census(fgrids)
            n_fwd = jnp.sum(n_f)
            grids = jnp.maximum(grids, fgrids)
        grids = finject.links(self.faults, grids, np.arange(self.L))
        n, kmax = self._census(grids)
        fits = events.census_fits(n, kmax, budget, step_budget)

        def compact():
            return self._unpack(
                self._pack(grids, self.link_addr, T, budget, step_budget), T)

        if self.link_mode == "dense":
            delivered = grids
        elif self.link_mode == "compact":
            delivered = compact()
        else:
            delivered = jax.lax.cond(jnp.all(fits), compact, lambda: grids)
        routed = jnp.zeros((T, self.K, self.R), jnp.float32).at[
            :, self.link_to, :].max(delivered)
        return routed, n, fits, n_fwd

    # -- shard_map transports ------------------------------------------------
    def _route_sharded(self, out, T, budget, step_budget, routed_in=None):
        sm, ck = _shard_map()
        axis, dp = self._axis, self._dp
        K_loc = self.K // dp
        L_loc = self.L // dp
        perm = [(d, (d + 1) % dp) for d in range(dp)]
        ring = self.plan.topology.kind == "ring"
        use_fwd = routed_in is not None
        # local link -> local source chip is static (links are src-major
        # with one uniform out-link block per chip)
        lf_loc = (jnp.arange(L_loc) if ring
                  else jnp.arange(L_loc) // self.K)

        def exch_leaf(x):
            # ring: only the last local link crosses the device boundary
            recv = jax.lax.ppermute(x[K_loc - 1:K_loc], axis, perm)
            return jnp.concatenate([recv, x[:K_loc - 1]], axis=0)

        def exch_stream(st):
            st = st._replace(valid=st.valid.astype(jnp.int8))
            st = jax.tree.map(exch_leaf, st)
            return st._replace(valid=st.valid.astype(bool))

        def body(out_loc, *rest):
            rank = jax.lax.axis_index(axis)
            l0 = rank * L_loc
            lsrc = jax.lax.dynamic_slice_in_dim(self.link_src, l0, L_loc)
            ldst = jax.lax.dynamic_slice_in_dim(self.link_dst, l0, L_loc)
            laddr = jax.lax.dynamic_slice_in_dim(self.link_addr, l0, L_loc)
            grids = self._link_grids(out_loc[:, lf_loc], lsrc, ldst)
            n_fwd = None
            if use_fwd:
                fsrc = jax.lax.dynamic_slice_in_dim(self.fwd_src, l0, L_loc)
                fdst = jax.lax.dynamic_slice_in_dim(self.fwd_dst, l0, L_loc)
                fgrids = self._link_grids(rest[0][:, lf_loc], fsrc, fdst)
                n_f, _ = self._census(fgrids)
                n_fwd = jax.lax.psum(jnp.sum(n_f), axis)
                grids = jnp.maximum(grids, fgrids)
            # absolute link ids keep the flaky-drop hash identical to the
            # local transport's
            grids = finject.links(self.faults, grids,
                                  l0 + jnp.arange(L_loc))
            n_loc, k_loc = self._census(grids)
            n = jax.lax.psum(jax.lax.dynamic_update_slice(
                jnp.zeros((self.L,), jnp.int32), n_loc, (l0,)), axis)
            k = jax.lax.psum(jax.lax.dynamic_update_slice(
                jnp.zeros((self.L,), jnp.int32), k_loc, (l0,)), axis)
            fits = events.census_fits(n, k, budget, step_budget)

            if ring:
                def dense():
                    # payload j is the in-link of local chip j after the
                    # rotation; ring fan-in is 1, so it IS the slab
                    return jnp.moveaxis(exch_leaf(
                        jnp.moveaxis(grids, 1, 0)), 0, 1)

                def compact():
                    st = self._pack(grids, laddr, T, budget, step_budget)
                    return self._unpack(exch_stream(st), T)
            else:
                def _deliver(delivered_all):
                    routed = jnp.zeros((T, self.K, self.R),
                                       jnp.float32).at[
                        :, self.link_to, :].max(delivered_all)
                    return jax.lax.dynamic_slice_in_dim(
                        routed, rank * K_loc, K_loc, axis=1)

                def dense():
                    return _deliver(jax.lax.all_gather(
                        grids, axis, axis=1, tiled=True))

                def compact():
                    st = self._pack(grids, laddr, T, budget, step_budget)
                    st = st._replace(valid=st.valid.astype(jnp.int8))
                    st = jax.tree.map(lambda x: jax.lax.all_gather(
                        x, axis, axis=0, tiled=True), st)
                    st = st._replace(valid=st.valid.astype(bool))
                    return _deliver(self._unpack(st, T))

            if self.link_mode == "dense":
                routed_loc = dense()
            elif self.link_mode == "compact":
                routed_loc = compact()
            else:
                routed_loc = jax.lax.cond(jnp.all(fits), compact, dense)
            if use_fwd:
                return routed_loc, n, fits, n_fwd
            return routed_loc, n, fits

        n_out = 4 if use_fwd else 3
        in_specs = (self._spec_in,) * (2 if use_fwd else 1)
        out_specs = (self._spec_in,) + (self._spec_rep,) * (n_out - 1)
        fn = sm(body, mesh=self._mesh, in_specs=in_specs,
                out_specs=out_specs, **ck)
        res = fn(out, routed_in) if use_fwd else fn(out)
        return res if use_fwd else (*res, None)

    # -- public API ----------------------------------------------------------
    def route(self, out_spikes_t, telemetry=None, routed_in=None):
        """[T, K, C] window output spikes -> ([T, K, R] delivery grid for
        the NEXT window, updated telemetry). ``routed_in`` (last window's
        delivered grid) feeds the plan's forward rules — required for
        failover plans, ignored when the plan has none."""
        T = out_spikes_t.shape[0]
        budget, step_budget = self._budgets(T)
        if self.plan.n_forwards == 0:
            routed_in = None
        elif routed_in is None:
            raise ValueError("this plan has forward rules: route() needs "
                             "routed_in (last window's delivered grid)")
        if self._axis is not None:
            routed, n, fits, n_fwd = self._route_sharded(
                out_spikes_t, T, budget, step_budget, routed_in)
        else:
            routed, n, fits, n_fwd = self._route_local(
                out_spikes_t, T, budget, step_budget, routed_in)
        telemetry = obs_trace.count_links(telemetry, n, fits)
        telemetry = obs_trace.count_reroutes(telemetry, n_fwd)
        return routed, obs_trace.count_faults(telemetry, self.faults)

    def link_census(self, out_spikes_t):
        """[L] delivered-event counts per link for one window of spikes —
        the screening probe's observable. Includes the fault hook (what
        the bus ACTUALLY delivers), excludes forward traffic and budget
        gating (raw capacity census)."""
        grids = self._link_grids(out_spikes_t[:, self.link_from],
                                 self.link_src, self.link_dst)
        grids = finject.links(self.faults, grids, np.arange(self.L))
        n, _ = self._census(grids)
        return n

    def merge(self, routed_ev, ext_ev, ext_addr):
        """Deliver last window's routed grid into this window's inputs.

        Events merge by ``max`` (a routed and an external event on the
        same (t, row) slot are one physical driver event); on slots where
        a routed event lands, the row's (validated-unique) route address
        wins over the external address — deterministic and identical on
        every chip count, which is what the split-vs-monolithic contract
        needs."""
        if self.plan.n_deliveries == 0:
            return ext_ev, ext_addr
        ev = jnp.maximum(ext_ev, routed_ev)
        addr = jnp.where(routed_ev > 0.0, self.dst_addr,
                         ext_addr.astype(jnp.int8))
        return ev, addr


def run_windows(core, router: InterChipRouter, state, ev_w, ad_w,
                telemetry=None):
    """Scan W routed windows: ``ev_w``/``ad_w`` are [W, T, K, R] external
    inputs; each window's spikes are routed into the next window's inputs
    (one-window latency). Returns ``(state, dict(spikes=[W, T, K, C],
    routed=last grid, telemetry=...))``."""
    T = ev_w.shape[1]

    def body(carry, xs):
        st, routed, tele = carry
        ev, ad = xs
        st, out = core.run_routed(st, routed, ev, ad, router,
                                  telemetry=tele)
        return ((st, out["routed"], out.get("telemetry")),
                out["spikes"])

    (state, routed, tele), spikes = jax.lax.scan(
        body, (state, router.init_buffer(T), telemetry), (ev_w, ad_w))
    return state, dict(spikes=spikes, routed=routed, telemetry=tele)
