"""Batched serving engine: prefill once, decode step-by-step.

Production layout (matches the dry-run decode cells): the KV cache is
batch-sharded over ``data`` and sequence-sharded over ``model``; decode
steps donate the cache so it updates in place. Greedy or temperature
sampling; per-request stop handling via an active mask.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.models.transformer import build_model, prefix_len
from repro.parallel.sharding import ShardingCtx, init_params, tree_pspecs


class ServeEngine:
    def __init__(self, arch: ArchConfig, ctx: Optional[ShardingCtx] = None,
                 max_len: int = 256):
        assert not arch.is_encoder_only, "encoder archs are not served"
        self.arch = arch
        self.ctx = ctx or ShardingCtx()
        self.max_len = max_len
        self.bundle = build_model(arch, self.ctx)
        decode_kw, prefill_kw = {}, {}
        if self.ctx.mesh is not None:
            # pin the params to their decl shardings; cache/token/position
            # stay unconstrained (the cache keeps whatever layout prefill
            # produced — donation must not force a reshard)
            psh = tree_pspecs(self.bundle.decls, self.ctx)
            unc = jax.sharding.UNSPECIFIED if hasattr(
                jax.sharding, "UNSPECIFIED") else None
            decode_kw["in_shardings"] = (psh, unc, unc, unc)
            prefill_kw["in_shardings"] = (psh, unc)
        self._decode = jax.jit(self.bundle.decode_step, donate_argnums=(1,),
                               **decode_kw)
        self._prefill = jax.jit(self.bundle.prefill, **prefill_kw)
        self._n_calls = 0   # per-call sampling-key derivation (see generate)

    def generate(self, params, prompts: jnp.ndarray, n_new: int,
                 temperature: float = 0.0, key=None,
                 timer=None) -> np.ndarray:
        """prompts: [B, S0] int32. Returns [B, n_new] generated ids.

        ``timer`` optionally takes a ``repro.obs.timing.PhaseTimer``:
        the prefill dispatch and the whole decode loop are recorded as
        ``prefill`` / ``decode`` spans (block_until_ready-bracketed), so
        serving latency splits show up in the same run reports as the
        emulation phases. ``None`` changes nothing.

        Sampling (``temperature > 0``) without an explicit ``key`` derives
        a fresh key per call from an engine-local counter — repeated calls
        draw different samples; pass ``key`` for reproducible draws.
        """
        b, s0 = prompts.shape
        pl_ = prefix_len(self.arch)
        if s0 + pl_ + n_new > self.max_len:
            raise ValueError(
                f"request overruns the KV cache: prompt {s0} + prefix "
                f"{pl_} + {n_new} new tokens > max_len {self.max_len}")
        batch = dict(tokens=prompts)
        if self.arch.vit_dim:
            batch["patch_embeds"] = jnp.zeros(
                (b, self.arch.n_patches, self.arch.vit_dim), jnp.float32)
        if timer is not None:
            with timer.span("prefill") as mark:
                logits, cache = self._prefill(params, batch)
                mark(logits)
        else:
            logits, cache = self._prefill(params, batch)
        total = s0 + pl_

        # grow caches to max_len
        def grow(x):
            if x.ndim == 4 and x.shape[1] == total:
                pad = self.max_len - total
                return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x
        cache = jax.tree.map(grow, cache)

        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if temperature > 0:
            if key is None:
                key = jax.random.fold_in(jax.random.PRNGKey(0),
                                         self._n_calls)
            self._n_calls += 1

        def decode_loop():
            nonlocal tok, cache, logits, key
            for i in range(n_new):
                out.append(np.asarray(tok[:, 0]))
                logits, cache = self._decode(params, cache, tok,
                                             jnp.int32(total + i))
                nxt = logits[:, -1]
                if temperature > 0:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(
                        sub, nxt / temperature,
                        axis=-1).astype(jnp.int32)[:, None]
                else:
                    tok = jnp.argmax(nxt, axis=-1).astype(jnp.int32)[:, None]

        if timer is not None:
            with timer.span("decode") as mark:
                decode_loop()
                mark(tok)
        else:
            decode_loop()
        return np.stack(out, axis=1)
