from repro.plasticity.three_factor import HybridReadoutTrainer  # noqa: F401
