"""C1' — the paper's hybrid-plasticity scheme as an LM-framework feature.

BrainScaleS-2's architectural claim: learning rules are *software* running
on a processor tightly coupled to the substrate, fed by (a) local
correlation observables and (b) a global scalar factor, writing quantized
weights with no host round-trip. Translated to the LM framework:

  * substrate      = the (frozen or co-trained) backbone producing features;
  * correlations   = eligibility e = phi(x) (outer) (onehot(sample) - p),
                     the local pre/post correlation of the readout;
  * global factor  = R - <R> with R = [sampled token == label]
                     (reward-modulated, paper Eqs. 2-3 verbatim);
  * PPU semantics  = the whole update is one jitted on-device step, and the
                     readout weights live QUANTIZED (arch.plasticity_bits,
                     6-bit default like the synapse SRAM) with saturating
                     writes.

This is three-factor / REINFORCE-style learning — exactly the class of
rules the PPU was built to run (paper §5 uses the same structure for the
spiking task). It applies to every assigned architecture because it only
needs backbone features (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig
from repro.models.transformer import build_model, prefix_len
from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx


@dataclasses.dataclass(frozen=True)
class ThreeFactorConfig:
    eta: float = 2.0
    gamma: float = 0.05          # <R> tracking (paper Eq. 2)
    w_scale: float = 0.02        # dequant scale per LSB
    noise: float = 0.0
    temperature: float = 1.0


class PlasticState(NamedTuple):
    w_q: jnp.ndarray            # [d, V] int8 quantized readout
    mean_r: jnp.ndarray         # scalar <R>
    key: jnp.ndarray


class HybridReadoutTrainer:
    """Reward-modulated plasticity on a quantized readout head."""

    def __init__(self, arch: ArchConfig, ctx: Optional[ShardingCtx] = None,
                 pcfg: ThreeFactorConfig = ThreeFactorConfig()):
        self.arch = arch
        self.ctx = ctx or ShardingCtx()
        self.pcfg = pcfg
        self.bundle = build_model(arch, self.ctx)
        self.wmax = 2 ** (arch.plasticity_bits - 1) - 1    # signed 6-bit: 31
        self._step = jax.jit(self._step_impl)

    def init_state(self, key) -> PlasticState:
        d, v = self.arch.d_model, self.arch.vocab_padded
        return PlasticState(
            w_q=jnp.zeros((d, v), jnp.int8),
            mean_r=jnp.zeros(()), key=key)

    def _step_impl(self, params, pstate: PlasticState, batch):
        arch, pcfg = self.arch, self.pcfg
        # substrate forward (backbone frozen — the "analog core")
        feats, _, _, _ = _features_of(self.bundle, params, batch)
        pl_ = prefix_len(arch)
        if pl_:
            feats = feats[:, pl_:]
        labels = batch["labels"]
        b, s, d = feats.shape
        phi = feats.reshape(b * s, d).astype(jnp.float32)
        y = labels.reshape(b * s)

        w = pstate.w_q.astype(jnp.float32) * pcfg.w_scale
        logits = phi @ w                                    # [N, V]
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < arch.vocab, logits, -1e30)
        p = jax.nn.softmax(logits / pcfg.temperature, axis=-1)

        key, k_samp, k_noise = jax.random.split(pstate.key, 3)
        samp = jax.random.categorical(k_samp, logits / pcfg.temperature,
                                      axis=-1)
        r = (samp == y).astype(jnp.float32)                 # [N]
        mean_r = pstate.mean_r + pcfg.gamma * (jnp.mean(r) - pstate.mean_r)
        mod = r - mean_r                                    # Eq. 2/3

        # local eligibility: pre (outer) (post_sampled - expectation)
        post = jax.nn.one_hot(samp, logits.shape[-1]) - p
        dw = pcfg.eta * jnp.einsum("n,nd,nv->dv", mod, phi, post) / phi.shape[0]
        if pcfg.noise:
            dw = dw + pcfg.noise * jax.random.normal(k_noise, dw.shape)

        # PPU write-back: saturating quantized store
        w_new = pstate.w_q.astype(jnp.float32) + dw / pcfg.w_scale
        w_q = jnp.clip(jnp.round(w_new), -self.wmax, self.wmax
                       ).astype(jnp.int8)
        metrics = dict(reward=jnp.mean(r), mean_r=mean_r,
                       acc_greedy=jnp.mean(
                           (jnp.argmax(logits, -1) == y).astype(jnp.float32)))
        return PlasticState(w_q=w_q, mean_r=mean_r, key=key), metrics

    def step(self, params, pstate, batch):
        """One fused on-device hybrid-plasticity step (no host loop)."""
        return self._step(params, pstate, batch)

    def host_loop_step(self, params, pstate, batch):
        """Host-in-the-loop baseline: observables cross the host boundary
        (the pre-BSS2 workflow the paper's architecture eliminates)."""
        import numpy as np
        pstate = jax.tree.map(lambda x: jax.device_put(np.asarray(x)), pstate)
        new, m = self._step(params, pstate, batch)
        m = {k: np.asarray(v) for k, v in m.items()}
        return new, m


def _features_of(bundle, params, batch):
    """Backbone features (bundle._features is attached by build_model)."""
    return bundle._features(params, batch, use_remat=False)
