"""Declarative fault plans for the emulated silicon (commissioning view).

The production reality behind the paper's verification story (Schmidt et
al. 2023, "From Clean Room to Machine Room"): wafers ship with dead
neurons, defective synapse drivers, stuck memory cells and broken
inter-chip links, and the commissioning flow screens them, blacklists
them and keeps running. ``FaultPlan`` is the *declarative, host-built*
description of one such defect realisation:

  ===================  ====================================================
  field                silicon defect modeled
  ===================  ====================================================
  dead_rows            synapse drivers that never forward events
  hot_neurons          output drivers stuck firing every dt
  dead_neurons         neurons whose spike output never asserts
  stuck_w_mask/_val    6-bit synapse SRAM cells stuck at a value — applied
                       at the ANALOG read (the crossbar sees the stuck
                       value; the PPU's digital readback is unaffected)
  cadc_stuck_*         CADC columns returning a stuck code
  cadc_code_offset     CADC columns with an additive code error
  store_flip           bit planes XORed into every PPU-VM weight STORE
  store_zero           store cells forced to zero (the blacklist
                       reduction uses this to pin masked-out synapses)
  dead_links           inter-chip bus links carrying nothing
  flaky_links          links dropping a deterministic pseudo-random
                       fraction of their events per window (``seed``)
  ===================  ====================================================

Every field is an optional host numpy array (``None`` = no such fault).
Plans become *closed-over constants* of the jitted emulation — the hooks
in ``repro.faults.inject`` emit ops only for present fields, and a
``None`` plan is the identity on every hook, so the fault-free program
is the SAME jaxpr as before this subsystem existed (the telemetry OFF
contract of PR 7, applied to fault injection).

Row/neuron/synapse planes follow the core's instance-prefix shapes
(``[.., R]`` / ``[.., C]`` / ``[.., R, C]`` broadcast against the
state); link arrays are indexed by the ``WaferTopology`` link order.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Optional, Sequence, Tuple

import numpy as np

WBITS = 6                      # synapse weight/address width
WMASK = (1 << WBITS) - 1


def _as_bool(x):
    return None if x is None else np.asarray(x, bool)


def _as_int(x, dtype):
    return None if x is None else np.asarray(x, dtype)


@dataclass(frozen=True)
class FaultPlan:
    """One realisation of silicon defects, host-built numpy.

    A plan is closed over as trace-time constants and threads one knob
    through every layer (``AnnCore``, ``VectorUnit``,
    ``InterChipRouter``, ``playback.execute``,
    ``make_experiment``/``run_training`` — see docs/wafer.md). ``None``
    fields are absent defects and compile to the identity: a run with
    ``faults=None`` is the SAME jaxpr as before the subsystem existed.

    Args:
      dead_rows: [.., R] bool — drivers that never forward events.
      hot_neurons / dead_neurons: [.., C] bool — output drivers stuck
        firing / never asserting.
      stuck_w_mask / stuck_w_val: [.., R, C] — 6-bit SRAM cells stuck
        at a value, applied at the ANALOG read only (the PPU's digital
        readback is unaffected).
      cadc_stuck_mask / cadc_stuck_code / cadc_code_offset: [.., C] —
        CADC columns returning a stuck code / an additive code error.
      store_flip / store_zero: [.., R, C] — bit planes XORed into every
        PPU weight store / store cells forced to zero.
      dead_links: [L] bool — bus links carrying nothing.
      flaky_links: [L] float32 — per-link deterministic event-drop
        fraction (hash-selected with ``seed``).
      seed: the flaky-drop hash seed.
      is_blacklist: marks a ``Blacklist.as_faults`` reduction overlay
        (telemetry reports it under ``faults_detected``).

    Contract pointers: tests/test_faults.py (``faults=None`` same
    jaxpr; injection bit-identical across backends and synaptic paths;
    blacklist reduction exact).
    """

    dead_rows: Optional[np.ndarray] = None        # [.., R] bool
    hot_neurons: Optional[np.ndarray] = None      # [.., C] bool
    dead_neurons: Optional[np.ndarray] = None     # [.., C] bool
    stuck_w_mask: Optional[np.ndarray] = None     # [.., R, C] bool
    stuck_w_val: Optional[np.ndarray] = None      # [.., R, C] int8 0..63
    cadc_stuck_mask: Optional[np.ndarray] = None  # [.., C] bool
    cadc_stuck_code: Optional[np.ndarray] = None  # [.., C] int32
    cadc_code_offset: Optional[np.ndarray] = None # [.., C] int32
    store_flip: Optional[np.ndarray] = None       # [.., R, C] int32 0..63
    store_zero: Optional[np.ndarray] = None       # [.., R, C] bool
    dead_links: Optional[np.ndarray] = None       # [L] bool
    flaky_links: Optional[np.ndarray] = None      # [L] float32 in [0, 1]
    seed: int = 0                                 # flaky-drop hash seed
    is_blacklist: bool = False                    # reduction overlay?

    def __post_init__(self):
        s = object.__setattr__
        s(self, "dead_rows", _as_bool(self.dead_rows))
        s(self, "hot_neurons", _as_bool(self.hot_neurons))
        s(self, "dead_neurons", _as_bool(self.dead_neurons))
        s(self, "stuck_w_mask", _as_bool(self.stuck_w_mask))
        s(self, "stuck_w_val", _as_int(self.stuck_w_val, np.int8))
        s(self, "cadc_stuck_mask", _as_bool(self.cadc_stuck_mask))
        s(self, "cadc_stuck_code", _as_int(self.cadc_stuck_code, np.int32))
        s(self, "cadc_code_offset", _as_int(self.cadc_code_offset, np.int32))
        s(self, "store_flip", _as_int(self.store_flip, np.int32))
        s(self, "store_zero", _as_bool(self.store_zero))
        s(self, "dead_links", _as_bool(self.dead_links))
        fl = self.flaky_links
        s(self, "flaky_links",
          None if fl is None else np.asarray(fl, np.float32))
        if (self.stuck_w_mask is None) != (self.stuck_w_val is None):
            raise ValueError("stuck_w_mask and stuck_w_val come together")
        if (self.cadc_stuck_mask is None) != (self.cadc_stuck_code is None):
            raise ValueError("cadc_stuck_mask and cadc_stuck_code "
                             "come together")
        if self.stuck_w_val is not None:
            v = self.stuck_w_val
            assert (0 <= v).all() and (v <= WMASK).all(), \
                "stuck weights are 6-bit"
            assert v.shape == self.stuck_w_mask.shape
        if self.cadc_stuck_code is not None:
            assert (self.cadc_stuck_code >= 0).all(), "CADC codes >= 0"
        if self.store_flip is not None:
            f = self.store_flip
            assert (0 <= f).all() and (f <= WMASK).all(), \
                "store flips stay within the 6-bit weight plane"
        if self.flaky_links is not None:
            f = self.flaky_links
            assert (0.0 <= f).all() and (f <= 1.0).all(), \
                "flaky drop fractions are probabilities"

    # -- host-side census ----------------------------------------------------
    @property
    def n_dead_rows(self) -> int:
        return 0 if self.dead_rows is None else int(self.dead_rows.sum())

    @property
    def core_sites(self) -> int:
        """Active fault sites on the chip itself (not the bus)."""
        n = self.n_dead_rows
        for m in (self.hot_neurons, self.dead_neurons, self.stuck_w_mask,
                  self.cadc_stuck_mask, self.store_zero):
            if m is not None:
                n += int(m.sum())
        if self.cadc_code_offset is not None:
            n += int((self.cadc_code_offset != 0).sum())
        if self.store_flip is not None:
            n += int((self.store_flip != 0).sum())
        return n

    @property
    def link_sites(self) -> int:
        n = 0
        if self.dead_links is not None:
            n += int(self.dead_links.sum())
        if self.flaky_links is not None:
            n += int((self.flaky_links > 0).sum())
        return n

    @property
    def total_sites(self) -> int:
        return self.core_sites + self.link_sites

    def summary(self) -> dict:
        d = {"total_sites": self.total_sites,
             "is_blacklist": self.is_blacklist}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                n = int((v != 0).sum())
                if n:
                    d[f.name] = n
        return d


def as_plans(faults) -> Tuple[FaultPlan, ...]:
    """Normalize a ``faults`` argument (None | FaultPlan | sequence of
    either) to the tuple of plans every hook iterates, in application
    order — injection plans first, the blacklist reduction last, so the
    reduction's masks dominate the faults they cover (the exactness
    contract ``tests/test_faults.py`` asserts)."""
    if faults is None:
        return ()
    if isinstance(faults, FaultPlan):
        return (faults,)
    return tuple(p for p in faults if p is not None)


def chain(*overlays):
    """Compose fault overlays into the form the emulation threads:
    ``None`` when nothing is active (the identity program), else the
    flat tuple of plans in application order."""
    plans = tuple(p for o in overlays for p in as_plans(o))
    return plans if plans else None


def sample_fault_plan(n_rows: int, n_cols: int, rng,
                      p_dead_row: float = 0.0, p_dead_neuron: float = 0.0,
                      p_hot_neuron: float = 0.0, p_stuck_w: float = 0.0,
                      p_cadc: float = 0.0, p_store_flip: float = 0.0,
                      n_links: int = 0, p_dead_link: float = 0.0,
                      p_flaky_link: float = 0.0, flaky_drop: float = 0.5,
                      prefix: Sequence[int] = (), cadc_max: int = 255,
                      seed: int = 0) -> FaultPlan:
    """A random defect realisation at the given per-site rates — the
    knob the fault-rate sweep in ``benchmarks/faults_bench.py`` turns.
    ``rng`` is a ``np.random.Generator``."""
    pr, pc = (*prefix, n_rows), (*prefix, n_cols)
    prc = (*prefix, n_rows, n_cols)

    def mask(shape, p):
        return rng.random(shape) < p if p > 0 else None

    dead_rows = mask(pr, p_dead_row)
    hot = mask(pc, p_hot_neuron)
    dead_n = mask(pc, p_dead_neuron)
    if hot is not None and dead_n is not None:
        dead_n = dead_n & ~hot            # a driver is stuck one way
    sw_mask = mask(prc, p_stuck_w)
    sw_val = (rng.integers(0, WMASK + 1, prc).astype(np.int8)
              if sw_mask is not None else None)
    cm = mask(pc, p_cadc)
    cc = (rng.integers(0, cadc_max + 1, pc).astype(np.int32)
          if cm is not None else None)
    sf_mask = mask(prc, p_store_flip)
    sf = (np.where(sf_mask, 1 << rng.integers(0, WBITS, prc), 0)
          .astype(np.int32) if sf_mask is not None else None)
    dl = mask((n_links,), p_dead_link) if n_links else None
    fl = None
    if n_links and p_flaky_link > 0:
        fl = np.where(rng.random(n_links) < p_flaky_link,
                      np.float32(flaky_drop), np.float32(0.0))
        if dl is not None:
            fl = np.where(dl, np.float32(0.0), fl)
    return FaultPlan(dead_rows=dead_rows, hot_neurons=hot,
                     dead_neurons=dead_n, stuck_w_mask=sw_mask,
                     stuck_w_val=sw_val, cadc_stuck_mask=cm,
                     cadc_stuck_code=cc, cadc_code_offset=None,
                     store_flip=sf, dead_links=dl, flaky_links=fl,
                     seed=seed)


def remap_link_faults(plan: FaultPlan, old_links, new_links) -> FaultPlan:
    """Re-index a plan's link-fault arrays from one topology's link order
    onto another's (pair-identity preserved) — needed when a reroute
    promotes a ring plan to all2all: the dead wire still connects the
    same chip pair, only its link index changed. Pairs absent from the
    new topology drop; new pairs start healthy."""
    if plan.dead_links is None and plan.flaky_links is None:
        return plan
    idx = {sd: l for l, sd in enumerate(old_links)}
    dl = fl = None
    if plan.dead_links is not None:
        dl = np.zeros(len(new_links), bool)
    if plan.flaky_links is not None:
        fl = np.zeros(len(new_links), np.float32)
    for l, sd in enumerate(new_links):
        j = idx.get(sd)
        if j is None:
            continue
        if dl is not None:
            dl[l] = plan.dead_links[j]
        if fl is not None:
            fl[l] = plan.flaky_links[j]
    kw = {f.name: getattr(plan, f.name) for f in fields(plan)}
    kw.update(dead_links=dl, flaky_links=fl)
    return FaultPlan(**kw)
