"""Commissioning-style screening and graceful degradation.

The flow mirrors the BrainScaleS machine-room commissioning loop: run
probe stimuli against the (possibly faulted) chip, census the telemetry
observables the real system has (rate counters, CADC codes, per-link
bus censuses), and derive a ``Blacklist`` of unusable rows / neurons /
links. Degradation is then *exact by construction*:

  * ``Blacklist.as_faults`` turns the blacklist into a REDUCTION
    ``FaultPlan`` (``is_blacklist=True``): blacklisted rows become dead
    rows, blacklisted neurons dead neurons with their CADC columns
    pinned to the code a zero accumulator digitizes to, and every
    blacklisted synapse's PPU-VM store forced to zero. Threading
    ``chain(faults, blacklist.as_faults(...))`` therefore emulates the
    faulted chip *under* its blacklist — and because the reduction masks
    are applied after (and dominate) every fault they cover, the result
    is bit-identical to emulating the clean reduced network
    (``chain(blacklist.as_faults(...))`` alone): the exactness contract
    ``tests/test_faults.py`` asserts with ``assert_array_equal``.
  * Dead links do not reduce — they re-route: ``repro.wafer.topology.
    reroute_plan`` moves the affected routes over an intermediate chip
    (reusing bus traffic the intermediate already receives where
    possible), and the router counts every forwarded event in
    ``link_reroutes`` — degradation on the bus is never silent either.

Screening is host-side orchestration of jitted probe runs; nothing here
is traced into the training program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.model import FaultPlan


def cadc_zero_code(inst, cadc_bits: int = 8) -> np.ndarray:
    """[.., C] code a ZERO correlation accumulator digitizes to under the
    instance's calibration (``cadc.digitize(0) = clip(round(offset))``) —
    the baseline every CADC probe compares against. Calibration precedes
    screening on the real system, so the expected baseline is known."""
    off = np.asarray(inst["cadc_offset"], np.float64)
    return np.clip(np.round(off), 0, 2 ** cadc_bits - 1).astype(np.int32)


@dataclass(frozen=True)
class Blacklist:
    """Per-neuron / per-row / per-link screening verdict.

    ``rows`` [.., R] / ``neurons`` [.., C] bool follow the core's
    instance-prefix shapes; ``links`` are (src_chip, dst_chip) pairs —
    topology-order-independent, so a reroute that re-indexes the link
    space cannot invalidate them.

    Two consumers:
      * run-time reduction — ``as_faults`` masks the blacklisted fabric
        exactly (tests/test_faults.py: faulted-under-blacklist ==
        clean reduced network);
      * compile-time avoidance — ``repro.mapper.map_network(...,
        blacklist=)`` never places onto blacklisted rows/neurons/links,
        so the mapped run equals the CLEAN monolithic run
        (tests/test_mapper.py::TestExactness::test_blacklist_round_trip).
    """
    rows: np.ndarray
    neurons: np.ndarray
    links: Tuple[Tuple[int, int], ...] = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(self, "rows", np.asarray(self.rows, bool))
        object.__setattr__(self, "neurons", np.asarray(self.neurons, bool))
        object.__setattr__(self, "links",
                           tuple((int(s), int(d)) for s, d in self.links))

    @property
    def n_rows(self) -> int:
        return int(self.rows.sum())

    @property
    def n_neurons(self) -> int:
        return int(self.neurons.sum())

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def total(self) -> int:
        return self.n_rows + self.n_neurons + self.n_links

    def union(self, other: "Blacklist") -> "Blacklist":
        return Blacklist(rows=self.rows | other.rows,
                         neurons=self.neurons | other.neurons,
                         links=tuple(sorted(set(self.links)
                                            | set(other.links))))

    def as_faults(self, inst, cadc_bits: int = 8) -> FaultPlan:
        """The graceful-degradation reduction overlay (see module
        docstring). ``store_zero`` covers the union of blacklisted rows
        and columns so VM stores cannot resurrect masked synapses."""
        zero = (self.rows[..., :, None] | self.neurons[..., None, :])
        return FaultPlan(
            dead_rows=self.rows if self.n_rows else None,
            dead_neurons=self.neurons if self.n_neurons else None,
            cadc_stuck_mask=self.neurons if self.n_neurons else None,
            cadc_stuck_code=(cadc_zero_code(inst, cadc_bits)
                             if self.n_neurons else None),
            store_zero=zero if zero.any() else None,
            is_blacklist=True)


# ---------------------------------------------------------------------------
# Probe-based screening
# ---------------------------------------------------------------------------

def screen_chip(core, ppu, probe_steps: int = 64, margin: int = 2,
                drive_weight: int = 63) -> Blacklist:
    """Screen one (possibly faulted) core + vector unit with the two
    commissioning probes:

      silent probe   no stimulus: neurons that still fire are HOT
                     (stuck output drivers); CADC columns whose codes
                     stray more than ``margin`` from the calibrated
                     zero baseline are corrupted readouts.
      drive probe    every row fires every dt with excitatory weights at
                     ``drive_weight``: healthy neurons must spike (DEAD
                     otherwise), and every healthy driver row must show
                     causal CADC signal on the healthy columns — rows
                     stuck at the zero baseline are dead drivers.

    Probes run through the SAME faulted observables the production run
    would see (``core.run`` + ``ppu.read_correlation``), so detection is
    telemetry-census-based, not oracle-based."""
    cfg = core.cfg
    R, C = cfg.n_rows, cfg.n_cols
    base = cadc_zero_code(ppu.inst, cfg.cadc_bits)      # [.., C]
    prefix = base.shape[:-1]
    run = jax.jit(core.run)

    def probe(ev_value, w_plane):
        st = core.init_state(prefix)
        if w_plane is not None:
            w = jnp.broadcast_to(jnp.asarray(w_plane, jnp.int8),
                                 (*prefix, R, C))
            st = st._replace(syn=st.syn._replace(weights=w))
        ev = jnp.full((probe_steps, *prefix, R), ev_value, jnp.float32)
        ad = jnp.zeros((probe_steps, *prefix, R), jnp.int8)
        st, _ = run(st, ev, ad)
        qc, qa = ppu.read_correlation(st.corr)
        return (np.asarray(st.rate_counters), np.asarray(qc),
                np.asarray(qa))

    # silent probe: hot neurons + corrupted CADC columns
    rates0, qc0, qa0 = probe(0.0, None)
    hot = rates0 > 0.0
    dev = np.maximum(np.abs(qc0 - base[..., None, :]),
                     np.abs(qa0 - base[..., None, :])).max(axis=-2)
    cadc_bad = dev > margin

    # drive probe: excitatory rows at full weight (odd/inhibitory rows
    # stay at zero weight but still forward events, so their drivers
    # leave causal traces too)
    w_plane = np.zeros((R, C), np.int8)
    w_plane[0::2, :] = np.int8(drive_weight)
    rates1, qc1, _ = probe(1.0, w_plane)
    dead_n = (rates1 <= 0.0) & ~hot

    neurons = hot | dead_n | cadc_bad
    good = ~neurons                                     # [.., C]
    if not good.any():
        # nothing to measure rows against — refuse to guess
        return Blacklist(rows=np.zeros((*prefix, R), bool),
                         neurons=neurons)
    delta = qc1 - base[..., None, :]                    # [.., R, C]
    dead_rows = np.where(good[..., None, :], delta,
                         0).max(axis=-1) <= margin
    return Blacklist(rows=dead_rows, neurons=neurons)


def screen_links(router, probe_steps: int = 32,
                 min_ratio: float = 0.95) -> Tuple[Tuple[int, int], ...]:
    """Screen the inter-chip bus: every column spiking every dt, then
    compare the faulted router's per-link delivered census against a
    clean router on the same plan. A link delivering less than
    ``min_ratio`` of its expected census is dead or flaky — returned as
    (src_chip, dst_chip) pairs for the blacklist."""
    from repro.wafer.router import InterChipRouter
    out = jnp.ones((probe_steps, router.K, router.C), jnp.float32)
    n_f = np.asarray(router.link_census(out))
    clean = InterChipRouter(router.plan, link_budget=router.link_budget,
                            link_step_budget=router.link_step_budget,
                            link_mode=router.link_mode)
    n_c = np.asarray(clean.link_census(out))
    bad = (n_c > 0) & (n_f < min_ratio * n_c)
    links = router.plan.topology.links()
    return tuple(links[l] for l in np.nonzero(bad)[0])


def screen(core, ppu, router=None, probe_steps: int = 64,
           margin: int = 2, min_ratio: float = 0.95) -> Blacklist:
    """Full screening pass: chip probes plus (when a router is given)
    the link census probe.

    Runs the two commissioning probes (silent: hot neurons + corrupted
    CADC columns; full-drive: dead neurons + dead driver rows) and,
    with a router, a per-link bus census against the clean expectation.

    Args:
      core / ppu: the (possibly faulted) ``AnnCore`` and ``PPU`` to
        probe — typically ``meta["core"]``/``meta["ppu"]`` from a
        degraded ``run_training``.
      router: optional ``InterChipRouter`` for the link census.
      probe_steps: probe window length (links use ``min(., 32)``).
      margin: CADC code tolerance before a column is flagged.
      min_ratio: delivered/expected event ratio below which a link is
        flagged.

    Returns:
      A ``Blacklist`` covering the detected rows/neurons/links.

    Contract pointers: tests/test_faults.py (screening finds the
    injected sites; reduction exactness), docs/wafer.md for the
    end-to-end degraded -> screened -> recovered walkthrough.
    """
    bl = screen_chip(core, ppu, probe_steps=probe_steps, margin=margin)
    if router is not None:
        links = screen_links(router, probe_steps=min(probe_steps, 32),
                             min_ratio=min_ratio)
        bl = Blacklist(rows=bl.rows, neurons=bl.neurons, links=links)
    return bl
