"""Fault-injection hooks threaded through the emulation stack.

Each hook takes the ``faults`` overlay (``None`` | ``FaultPlan`` | tuple
of plans, see ``repro.faults.model.as_plans``) and one value of the
existing dataflow, and returns that value with the plans' defects
applied *in order*. The contract mirrors PR 7's telemetry pattern:

  * ``faults=None`` (or a plan without the relevant field) emits ZERO
    ops — the hook returns its argument object untouched, so the
    disabled program is the SAME jaxpr as before the subsystem existed
    (asserted across oracle/fused/blocked/sparse backends in
    ``tests/test_faults.py``).
  * All plan arrays are host constants closed over at trace time —
    nothing dynamic rides the scan carry, nothing retraces.
  * Hook placement is chosen so every backend sees identical fault
    semantics (the windowed backends apply per-window what the oracle
    applies per dt — see the induction notes at each hook).

Hook sites:

  rows      ``AnnCore.run``/``step`` entry — dead drivers zero their
            events BEFORE STP, the synaptic matmul, the correlation
            pre-traces and the telemetry census (one shared hook works
            for every backend because all phases consume the stream).
  weights   the analog synapse READ (``step`` / ``_window_currents``):
            stuck SRAM cells override the stored value each time the
            crossbar is read — PPU writes still land in the array, the
            read just keeps not seeing them.
  spikes    after the neuron phase, before rate counters, correlation
            update and the router: hot drivers force 1, dead drivers
            force 0. Membrane state keeps integrating unmasked (the
            defect sits on the spike output, not the soma) — identical
            op trees in every backend.
  rates     the windowed backends' rate-counter fixup matching what the
            oracle accumulates per step from hooked spikes:
            ``rc = where(hot, rc_in + T, rc) * alive`` per plan.
  cadc      ``VectorUnit.read_correlation`` — code offsets then stuck
            codes, clipped to the ADC range.
  store     ``VectorUnit.run_program_fixed`` — XOR bit-flips then the
            blacklist zero-mask on every PPU-VM weight store.
  links     the router's per-link delivery grids before census and
            exchange — dead links carry nothing, flaky links drop a
            deterministic hash-selected fraction of (t, row) slots.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.faults.model import as_plans


def rows(faults, row_spikes_t):
    """[T?, .., R] driver events — dead rows forward nothing."""
    for p in as_plans(faults):
        if p.dead_rows is not None:
            alive = jnp.asarray(~p.dead_rows, row_spikes_t.dtype)
            row_spikes_t = row_spikes_t * alive
    return row_spikes_t


def weights(faults, w):
    """[.., R, C] synapse weights at the analog read."""
    for p in as_plans(faults):
        if p.stuck_w_mask is not None:
            w = jnp.where(jnp.asarray(p.stuck_w_mask),
                          jnp.asarray(p.stuck_w_val, w.dtype), w)
    return w


def spikes(faults, out_spikes):
    """[T?, .., C] neuron output spikes — hot forces 1, dead forces 0."""
    for p in as_plans(faults):
        if p.hot_neurons is not None:
            out_spikes = jnp.where(jnp.asarray(p.hot_neurons),
                                   jnp.ones((), out_spikes.dtype),
                                   out_spikes)
        if p.dead_neurons is not None:
            alive = jnp.asarray(~p.dead_neurons, out_spikes.dtype)
            out_spikes = out_spikes * alive
    return out_spikes


def rates(faults, rc, rc_in, n_steps: int):
    """Window-level rate-counter twin of ``spikes``: ``rc`` is the raw
    windowed accumulation ``rc_in + sum(raw spikes)``; a hot column
    accumulated exactly ``n_steps`` hooked spikes, a dead column zero
    (its carry-in is zero by induction — counters start at zero and
    every window ends masked)."""
    for p in as_plans(faults):
        if p.hot_neurons is not None:
            hot = jnp.asarray(p.hot_neurons)
            rc = jnp.where(hot, rc_in + jnp.asarray(n_steps, rc.dtype), rc)
        if p.dead_neurons is not None:
            rc = rc * jnp.asarray(~p.dead_neurons, rc.dtype)
    return rc


def cadc(faults, qc, qa, cadc_max: int):
    """[.., R, C] CADC codes: additive code errors then stuck codes.
    Column planes broadcast over the row axis."""
    for p in as_plans(faults):
        if p.cadc_code_offset is not None:
            off = jnp.asarray(p.cadc_code_offset)[..., None, :]
            qc = jnp.clip(qc + off, 0, cadc_max)
            qa = jnp.clip(qa + off, 0, cadc_max)
        if p.cadc_stuck_mask is not None:
            m = jnp.asarray(p.cadc_stuck_mask)[..., None, :]
            code = jnp.asarray(p.cadc_stuck_code)[..., None, :]
            qc = jnp.where(m, code, qc)
            qa = jnp.where(m, code, qa)
    return qc, qa


def store(faults, w_new):
    """[.., R, C] int32 weights on the PPU-VM store path (before the
    6-bit cast): XOR bit-flips, then the blacklist zero-mask."""
    for p in as_plans(faults):
        if p.store_flip is not None:
            w_new = jnp.bitwise_xor(w_new,
                                    jnp.asarray(p.store_flip, w_new.dtype))
        if p.store_zero is not None:
            w_new = jnp.where(jnp.asarray(p.store_zero),
                              jnp.zeros((), w_new.dtype), w_new)
    return w_new


def _hash_u32(x):
    """Deterministic 32-bit integer mix (splitmix-style finalizer)."""
    x = jnp.asarray(x, jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7feb352d)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846ca68b)
    return x ^ (x >> 16)


def link_keep(p, T: int, R: int, link_ids):
    """[T, Lx, R] keep factor for one plan's link faults: 0.0 on dead
    links; on flaky links a per-(t, link, row) deterministic coin —
    hashed from (t, row, absolute link id, plan seed), NOT a carried
    PRNG, so the drop pattern is identical for the local and shard_map
    transports and independent of window batching."""
    keep = None
    lid = jnp.asarray(link_ids, jnp.uint32)            # [Lx] absolute ids
    if p.flaky_links is not None:
        fl = jnp.asarray(p.flaky_links)[link_ids]      # [Lx]
        tr = (jnp.arange(T, dtype=jnp.uint32)[:, None, None]
              * jnp.uint32(R)
              + jnp.arange(R, dtype=jnp.uint32)[None, None, :])
        h = _hash_u32(tr * jnp.uint32(0x9e3779b1)
                      + (lid[None, :, None] + 1) * jnp.uint32(0x85ebca77)
                      + jnp.uint32(np.uint32(p.seed)))
        u = (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
        keep = (u >= fl[None, :, None]).astype(jnp.float32)
    if p.dead_links is not None:
        alive = jnp.asarray(~p.dead_links,
                            jnp.float32)[link_ids][None, :, None]
        keep = alive if keep is None else keep * alive
    return keep


def links(faults, grids, link_ids):
    """[T, Lx, R] per-link delivery grids; ``link_ids`` are the absolute
    link indices of the Lx slots (the sharded transport passes its local
    block's offsets)."""
    plans = [p for p in as_plans(faults)
             if p.dead_links is not None or p.flaky_links is not None]
    if not plans:
        return grids
    T, R = grids.shape[0], grids.shape[2]
    for p in plans:
        keep = link_keep(p, T, R, link_ids)
        if keep is not None:
            grids = grids * keep
    return grids


def has_link_faults(faults) -> bool:
    return any(p.dead_links is not None or p.flaky_links is not None
               for p in as_plans(faults))
