"""Fault injection + defect tolerance for the emulated silicon:
declarative ``FaultPlan`` overlays (``repro.faults.model``), jit-safe
injection hooks threaded through the emulation (``repro.faults.inject``),
and commissioning-style screening / blacklist reduction
(``repro.faults.blacklist``)."""
from repro.faults.blacklist import (Blacklist, cadc_zero_code, screen,
                                    screen_chip, screen_links)
from repro.faults.model import (FaultPlan, as_plans, chain,
                                remap_link_faults, sample_fault_plan)

__all__ = ["FaultPlan", "as_plans", "chain", "sample_fault_plan",
           "remap_link_faults", "Blacklist", "cadc_zero_code", "screen",
           "screen_chip", "screen_links"]
