"""HLO inspection helpers for the perf hillclimb.

``top_buffers`` ranks result tensors in an optimized HLO module by size —
the fastest way to find what is *actually* replicated/materialized when the
memory term looks wrong.
"""
from __future__ import annotations

import re
from collections import Counter, defaultdict

from repro.analysis.roofline import _SHAPE_RE, _DTYPE_BYTES

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\(")


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def top_buffers(hlo_text: str, n: int = 30):
    """Largest result tensors: (bytes, op_kind, type, count)."""
    agg = defaultdict(lambda: [0, 0])  # (op_kind, type) -> [count, bytes_each]
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        _, type_str, kind = m.groups()
        b = _bytes_of(type_str)
        if b < (1 << 20):
            continue
        key = (kind, type_str[:120])
        agg[key][0] += 1
        agg[key][1] = b
    rows = sorted(((cnt * b, cnt, b, kind, t) for (kind, t), (cnt, b) in agg.items()),
                  reverse=True)
    return rows[:n]


def print_top_buffers(hlo_text: str, n: int = 30):
    for total, cnt, b, kind, t in top_buffers(hlo_text, n):
        print(f"{total/2**30:8.2f} GiB total | {cnt:5d} x {b/2**20:9.1f} MiB | "
              f"{kind:24s} | {t}")


def bytes_by_op(hlo_text: str, n: int = 20):
    agg = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        _, type_str, kind = m.groups()
        agg[kind] += _bytes_of(type_str)
    return agg.most_common(n)
