"""Roofline-term derivation from a compiled dry-run artifact.

Three terms, all in seconds-per-step on the target hardware
(TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute    = HLO_flops_per_device / peak_flops
  memory     = HLO_bytes_per_device / hbm_bw
  collective = collective_bytes_per_device / link_bw

``cost_analysis()`` of the partitioned executable is per-device (verified:
a (16,16)-sharded matmul reports exactly 2MNK/256 flops). Collective bytes
are NOT in cost_analysis — they are parsed from the optimized HLO text by
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (plus a ring-model "effective" variant).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, Optional

from repro.config import HW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_METADATA_OPS = {"bitcast", "parameter", "constant", "tuple",
                 "get-tuple-element", "after-all", "partition-id",
                 "replica-id", "iota"}


def entry_computation(hlo_text: str) -> str:
    """Extract the ENTRY computation body (top-level, post-fusion ops)."""
    lines = hlo_text.splitlines()
    out = []
    depth = 0
    in_entry = False
    for ln in lines:
        if ln.startswith("ENTRY "):
            in_entry = True
        if in_entry:
            out.append(ln)
            depth += ln.count("{") - ln.count("}")
            if depth <= 0 and len(out) > 1:
                break
    return "\n".join(out)


_ENTRY_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\(")


def hbm_bytes_estimate(hlo_text: str) -> Dict[str, float]:
    """Fusion-aware HBM-traffic estimate from the ENTRY computation.

    XLA-CPU's ``cost_analysis()['bytes accessed']`` counts instructions
    *inside* fusions as if each intermediate were materialized, inflating
    the memory term ~10x vs a TPU schedule (measured on smollm/train_4k:
    1.7 TB/dev raw vs ~0.2 TB/dev entry-level). Here each top-level op's
    result is counted as one write + one read (by its consumer); metadata
    ops (bitcast/tuple/...) are free. This is still conservative for TPU
    (CPU fuses less), and is reported as the roofline memory term.
    """
    ent = entry_computation(hlo_text)
    total = 0
    by_kind: Dict[str, float] = defaultdict(float)
    for ln in ent.splitlines():
        m = _ENTRY_OP_RE.match(ln)
        if not m:
            continue
        type_str, kind = m.groups()
        if kind in _METADATA_OPS:
            continue
        b = _shape_bytes(type_str)
        by_kind[kind] += b
        total += b
    return dict(total_write=total, rw=2.0 * total,
                by_kind=dict(sorted(by_kind.items(), key=lambda kv: -kv[1])[:12]))


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} from optimized HLO (per-device sizes).

    Bytes = result-shape bytes of each collective op. For all-reduce and
    collective-permute this equals the operand size; for all-gather it is the
    gathered (received) size; for reduce-scatter the pre-reduce (sent) size
    is the operand — we use the *larger* of result/operand-visible sizes,
    which for RS means parsing the operand type when present.
    """
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: dict(count=0, bytes=0.0))
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "-done(" in line:
            continue  # paired with -start; count once
        type_str = m.group(1) or m.group(2)
        b = _shape_bytes(type_str)
        if kind == "reduce-scatter":
            # operand is n_shards x larger than the result
            ops = _shape_bytes(line.split("(", 1)[1])
            b = max(b, ops)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return dict(out)


def collective_seconds(colls: Dict[str, Dict[str, float]],
                       link_bw: float = HW.ici_bw_per_link,
                       links: int = HW.ici_links) -> Dict[str, float]:
    """Simple + ring-effective time models for the collective term."""
    simple_bytes = sum(v["bytes"] for v in colls.values())
    # ring model: AR moves 2x its buffer; AG/RS/A2A 1x; CP 1x — per device,
    # across `links` usable links.
    eff = 0.0
    for kind, v in colls.items():
        factor = 2.0 if kind == "all-reduce" else 1.0
        eff += factor * v["bytes"]
    return dict(
        bytes_simple=simple_bytes,
        bytes_effective=eff,
        sec_simple=simple_bytes / (link_bw * links),
        sec_effective=eff / (link_bw * links),
    )


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float          # raw cost_analysis (fusion-naive, CPU)
    hbm_bytes_per_dev: float      # entry-level fusion-aware estimate (used)
    hbm_by_kind: Dict[str, float]
    transcendentals: float
    coll: Dict[str, Dict[str, float]]
    coll_sec: Dict[str, float]
    temp_bytes: int
    arg_bytes: int
    out_bytes: int
    model_flops_global: float
    n_devices: int
    step_kind: str

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / HW.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_dev / HW.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_sec["sec_effective"]

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        hlo_global = self.flops_per_dev * self.n_devices
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        return (self.model_flops_global
                / (self.n_devices * HW.peak_flops_bf16 * self.step_time))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 step_time=self.step_time,
                 useful_flops_ratio=self.useful_flops_ratio, mfu=self.mfu)
        return d


def model_flops_for(arch, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D=batch."""
    n = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def build_report(arch, shape, mesh_name: str, n_devices: int, compiled,
                 lowered_text: Optional[str] = None) -> RooflineReport:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    txt = lowered_text if lowered_text is not None else compiled.as_text()
    colls = parse_collectives(txt)
    hbm = hbm_bytes_estimate(txt)
    return RooflineReport(
        arch=arch.name, shape=shape.name, mesh=mesh_name,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        hbm_bytes_per_dev=float(hbm["rw"]),
        hbm_by_kind=hbm["by_kind"],
        transcendentals=float(ca.get("transcendentals", 0.0)),
        coll=colls, coll_sec=collective_seconds(colls),
        temp_bytes=int(ma.temp_size_in_bytes),
        arg_bytes=int(ma.argument_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        model_flops_global=model_flops_for(arch, shape),
        n_devices=n_devices,
        step_kind=shape.kind,
    )
