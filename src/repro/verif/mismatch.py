"""Virtual instances and divergence localisation.

Two verification tools share this module:

* fixed-seed Monte-Carlo mismatch realisations (paper §3.2.2: "by fixing
  the MC seed a set of virtual instances can be obtained, which can be
  individually parameterized and analyzed, similar to an array of actual
  in-silicon instances of the design") — ``sample_instance(cfg, key,
  prefix)`` returns the full mismatch realisation for ``prefix``-many
  chips; the same key always yields the same silicon;
* the **first-divergence locator** for co-simulation traces
  (``first_divergence``): when two playback traces split, a bare
  "mismatch" assert is useless for debugging — the paper's automated
  monitors (§3.1) instead *localize*: which phase of the machine, which
  record, which timestep, which array element first went wrong.
  ``repro.verif.playback.compare_traces`` routes its mismatch messages
  through this locator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2Config
from repro.core import capmem

# per-parameter mismatch kind: (sigma attribute, additive?)
_NEURON_SIGMA = {
    "g_leak": ("sigma_g_leak", False),
    "tau_syn_exc": ("sigma_tau_syn", False),
    "tau_syn_inh": ("sigma_tau_syn", False),
    "v_thres": ("sigma_v_thres", True),
}


def sample_instance(cfg: BSS2Config, key, prefix: Tuple[int, ...] = ()
                    ) -> Dict:
    """Mismatch realisation for a (batch of) virtual chip instance(s)."""
    mm = cfg.mismatch
    r, c = cfg.n_rows, cfg.n_cols
    nominal = capmem.nominal(cfg)

    keys = jax.random.split(key, len(capmem.NEURON_PARAMS) + 5)
    neuron_params = {}
    for i, name in enumerate(capmem.NEURON_PARAMS):
        v = jnp.broadcast_to(nominal[name], (*prefix, c))
        if name in _NEURON_SIGMA:
            attr, additive = _NEURON_SIGMA[name]
            sig = getattr(mm, attr)
            n = jax.random.normal(keys[i], (*prefix, c))
            v = v + sig * n if additive else v * (1.0 + sig * n)
        else:
            n = jax.random.normal(keys[i], (*prefix, c))
            v = v * (1.0 + mm.sigma_capmem * n)
        neuron_params[name] = v

    k_wg, k_so, k_co, k_cg, _ = keys[-5:]
    return dict(
        neuron_params=neuron_params,
        weight_gain=1.0 + mm.sigma_weight_gain
        * jax.random.normal(k_wg, (*prefix, c)),
        stp_offset=mm.sigma_stp_offset
        * jax.random.normal(k_so, (*prefix, r)),
        stp_calib=jnp.full((*prefix, r), 2 ** (cfg.calib_bits - 1),
                           jnp.int32),           # mid-code before calibration
        cadc_offset=mm.sigma_cadc_offset
        * jax.random.normal(k_co, (*prefix, c)),
        cadc_gain=1.0 + mm.sigma_cadc_gain
        * jax.random.normal(k_cg, (*prefix, c)),
    )


# ---------------------------------------------------------------------------
# First-divergence locator for co-simulation traces
# ---------------------------------------------------------------------------

# which emulation phase produced a given trace-record kind — the coarse
# "where in the machine" attribution of a divergence
PHASE_OF_KIND = {
    "SPIKES": "neuron-scan",
    "V": "neuron-scan",
    "RATES": "neuron-scan",
    "CORR": "corr",
    "WEIGHTS": "ppu",
    "PPU_W": "ppu-vm",
}


@dataclass
class Divergence:
    """Where two experiment traces first split.

    ``record`` is the index into the trace list; ``kind``/``t`` the
    record header; ``phase`` the emulation phase that produced the
    record (``PHASE_OF_KIND``). For array-value divergences ``where`` is
    the index of the first differing element, ``step`` its absolute
    timestep when the leading axis is time (SPIKES/V records: the
    record's end time minus the window length plus the row index), and
    ``a``/``b`` the two values there. Header/shape/length mismatches set
    ``structural=True`` and leave the element fields at None.
    """
    record: int
    kind: str
    t: int
    phase: str = "?"
    step: Optional[int] = None
    where: Optional[Tuple[int, ...]] = None
    a: Optional[float] = None
    b: Optional[float] = None
    n_mismatch: int = 0
    max_abs: float = 0.0
    structural: bool = False
    detail: str = ""

    def describe(self) -> str:
        if self.structural:
            return (f"trace diverges structurally at record {self.record} "
                    f"({self.kind}@{self.t}): {self.detail}")
        at_step = "" if self.step is None else f" step {self.step},"
        return (f"first divergence at record {self.record} "
                f"({self.kind}@{self.t}, phase {self.phase}):{at_step} "
                f"index {self.where} — {self.a:g} vs {self.b:g} "
                f"({self.n_mismatch} element(s) differ, "
                f"max|diff|={self.max_abs:.3e})")


def first_divergence(trace_a, trace_b, atol: float = 1e-3,
                     rtol: float = 1e-4) -> Optional[Divergence]:
    """Locate the FIRST point two playback traces split (None == match).

    Traces are lists of ``(t, kind, array)`` records as produced by
    ``repro.verif.playback`` backends. Records are compared in order;
    the first mismatching one is localized down to the first differing
    element (first in C order: earliest timestep for time-leading
    records). Tolerances match ``compare_traces``.
    """
    for i, ((ta, ka, va), (tb, kb, vb)) in enumerate(zip(trace_a, trace_b)):
        if ta != tb or ka != kb:
            return Divergence(record=i, kind=str(ka), t=int(ta),
                              structural=True,
                              detail=f"header ({ta},{ka}) != ({tb},{kb})")
        va = np.asarray(va, np.float64)
        vb = np.asarray(vb, np.float64)
        if va.shape != vb.shape:
            return Divergence(record=i, kind=str(ka), t=int(ta),
                              phase=PHASE_OF_KIND.get(ka, "?"),
                              structural=True,
                              detail=f"shape {va.shape} != {vb.shape}")
        bad = ~np.isclose(va, vb, atol=atol, rtol=rtol)
        if bad.any():
            idx = tuple(int(j) for j in np.argwhere(bad)[0])
            step = None
            if ka in ("SPIKES", "V") and va.ndim >= 1:
                # record timestamp is the END of the integrated window
                step = int(ta) - va.shape[0] + idx[0]
            return Divergence(
                record=i, kind=str(ka), t=int(ta),
                phase=PHASE_OF_KIND.get(ka, "?"), step=step, where=idx,
                a=float(va[idx]), b=float(vb[idx]),
                n_mismatch=int(bad.sum()),
                max_abs=float(np.max(np.abs(va - vb))))
    if len(trace_a) != len(trace_b):
        n = min(len(trace_a), len(trace_b))
        longer = trace_a if len(trace_a) > len(trace_b) else trace_b
        t, k = longer[n][0], longer[n][1]
        return Divergence(record=n, kind=str(k), t=int(t), structural=True,
                          detail=f"trace length {len(trace_a)} != "
                                 f"{len(trace_b)}")
    return None


def ideal_instance(cfg: BSS2Config, prefix: Tuple[int, ...] = ()) -> Dict:
    """Mismatch-free instance (the 'schematic' simulation)."""
    r, c = cfg.n_rows, cfg.n_cols
    nominal = capmem.nominal(cfg)
    return dict(
        neuron_params={k: jnp.broadcast_to(v, (*prefix, c))
                       for k, v in nominal.items()},
        weight_gain=jnp.ones((*prefix, c)),
        stp_offset=jnp.zeros((*prefix, r)),
        stp_calib=jnp.full((*prefix, r), 2 ** (cfg.calib_bits - 1), jnp.int32),
        cadc_offset=jnp.zeros((*prefix, c)),
        cadc_gain=jnp.ones((*prefix, c)),
    )
