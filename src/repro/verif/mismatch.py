"""Virtual instances: fixed-seed Monte-Carlo mismatch realisations.

Paper §3.2.2: "by fixing the MC seed a set of virtual instances can be
obtained, which can be individually parameterized and analyzed, similar to
an array of actual in-silicon instances of the design."

``sample_instance(cfg, key, prefix)`` returns the full mismatch realisation
for ``prefix``-many chips; the same key always yields the same silicon.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.bss2 import BSS2Config
from repro.core import capmem

# per-parameter mismatch kind: (sigma attribute, additive?)
_NEURON_SIGMA = {
    "g_leak": ("sigma_g_leak", False),
    "tau_syn_exc": ("sigma_tau_syn", False),
    "tau_syn_inh": ("sigma_tau_syn", False),
    "v_thres": ("sigma_v_thres", True),
}


def sample_instance(cfg: BSS2Config, key, prefix: Tuple[int, ...] = ()
                    ) -> Dict:
    """Mismatch realisation for a (batch of) virtual chip instance(s)."""
    mm = cfg.mismatch
    r, c = cfg.n_rows, cfg.n_cols
    nominal = capmem.nominal(cfg)

    keys = jax.random.split(key, len(capmem.NEURON_PARAMS) + 5)
    neuron_params = {}
    for i, name in enumerate(capmem.NEURON_PARAMS):
        v = jnp.broadcast_to(nominal[name], (*prefix, c))
        if name in _NEURON_SIGMA:
            attr, additive = _NEURON_SIGMA[name]
            sig = getattr(mm, attr)
            n = jax.random.normal(keys[i], (*prefix, c))
            v = v + sig * n if additive else v * (1.0 + sig * n)
        else:
            n = jax.random.normal(keys[i], (*prefix, c))
            v = v * (1.0 + mm.sigma_capmem * n)
        neuron_params[name] = v

    k_wg, k_so, k_co, k_cg, _ = keys[-5:]
    return dict(
        neuron_params=neuron_params,
        weight_gain=1.0 + mm.sigma_weight_gain
        * jax.random.normal(k_wg, (*prefix, c)),
        stp_offset=mm.sigma_stp_offset
        * jax.random.normal(k_so, (*prefix, r)),
        stp_calib=jnp.full((*prefix, r), 2 ** (cfg.calib_bits - 1),
                           jnp.int32),           # mid-code before calibration
        cadc_offset=mm.sigma_cadc_offset
        * jax.random.normal(k_co, (*prefix, c)),
        cadc_gain=1.0 + mm.sigma_cadc_gain
        * jax.random.normal(k_cg, (*prefix, c)),
    )


def ideal_instance(cfg: BSS2Config, prefix: Tuple[int, ...] = ()) -> Dict:
    """Mismatch-free instance (the 'schematic' simulation)."""
    r, c = cfg.n_rows, cfg.n_cols
    nominal = capmem.nominal(cfg)
    return dict(
        neuron_params={k: jnp.broadcast_to(v, (*prefix, c))
                       for k, v in nominal.items()},
        weight_gain=jnp.ones((*prefix, c)),
        stp_offset=jnp.zeros((*prefix, r)),
        stp_calib=jnp.full((*prefix, r), 2 ** (cfg.calib_bits - 1), jnp.int32),
        cadc_offset=jnp.zeros((*prefix, c)),
        cadc_gain=jnp.ones((*prefix, c)),
    )
