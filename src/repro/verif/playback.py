"""Playback-program co-simulation (paper §2.3 + §3.1, Fig. 2).

On the real system, compiled *playback programs* (timed instruction
streams) are executed by the FPGA against the chip; the same programs run
against the RTL simulation, making hardware and simulation transparently
interchangeable ("it is now possible to transparently execute a playback
program in simulation or on the physical system and compare the results").

Here the two interchangeable backends are:

  * ``fast`` — the optimized JAX machine model (jit + scan), i.e. the
    implementation the framework actually uses;
  * ``ref``  — an independent pure-NumPy re-implementation of the same
    behavioural equations, written as a straight per-timestep loop.

``execute`` runs a program on either backend and returns an *experiment
trace* (timestamped read-back records, like the FPGA's trace memory);
``compare_traces`` diffs two traces — that is the co-simulation check.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2Config
from repro.core.anncore import AnnCore
from repro.core.ppu import VectorUnit
from repro.verif.mismatch import ideal_instance


# ---------------------------------------------------------------------------
# Instruction set
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Instr:
    op: str                      # WRITE_WEIGHTS | WRITE_ADDRESSES | RUN |
    #                              INJECT | READ_RATES | READ_WEIGHTS |
    #                              READ_V | READ_CORR |
    #                              WRITE_PPU_PROGRAM | PPU_RUN
    payload: Any = None


def write_weights(w) -> Instr:
    return Instr("WRITE_WEIGHTS", np.asarray(w, np.int8))


def write_addresses(a) -> Instr:
    return Instr("WRITE_ADDRESSES", np.asarray(a, np.int8))


def inject(events, addrs=None) -> Instr:
    """events: [T, R] floats in {0,1} released over the next T steps."""
    ev = np.asarray(events, np.float32)
    ad = np.zeros(ev.shape, np.int8) if addrs is None else np.asarray(addrs, np.int8)
    return Instr("INJECT", (ev, ad))


def run(steps: int) -> Instr:
    return Instr("RUN", int(steps))


def read_rates() -> Instr:
    return Instr("READ_RATES")


def read_weights() -> Instr:
    return Instr("READ_WEIGHTS")


def read_v() -> Instr:
    return Instr("READ_V")


def read_corr() -> Instr:
    return Instr("READ_CORR")


def write_ppu_program(words) -> Instr:
    """Upload a PPU-VM program (``repro.ppuvm``): dense int32 words."""
    from repro.ppuvm import isa

    words = np.asarray(words, np.int32)
    isa.validate(words)
    return Instr("WRITE_PPU_PROGRAM", words)


def ppu_run(mod=None, noise=None) -> Instr:
    """Execute the uploaded PPU-VM program against the machine state.

    ``mod`` [n_mod, C] / ``noise`` [R, C] floats are digitized to Q8.8
    HERE (host side, once) so both co-sim backends consume identical
    integers — the analog observables (CADC codes) are the only inputs
    each backend digitizes itself. Appends a ("PPU_W") weight record to
    the trace: the co-simulation check for *programs*.
    """
    from repro.ppuvm import isa

    mod_fp = None if mod is None else isa.to_fixed(mod)
    noise_fp = None if noise is None else isa.to_fixed(noise)
    return Instr("PPU_RUN", (mod_fp, noise_fp))


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class FastBackend:
    """The production machine model (jit + lax.scan).

    ``ppu_executor`` selects the PPU-VM implementation used by
    ``PPU_RUN`` (see ``repro.ppuvm.interp.EXECUTORS``): at program upload
    the words are concrete, so each upload binds a jitted closure with
    the program as a compile-time constant — "auto" therefore resolves to
    the trace-time specializer. All executors are bit-identical (the
    differential fuzz harness), so every choice must produce the same
    trace as the NumPy RefBackend.

    ``telemetry=True`` accumulates the jit-safe counter pytree
    (``repro.obs.trace``) across the whole playback program — emulation
    windows, sparse-gate decisions, VM runs and saturation-rail hits —
    readable via ``telemetry_summary()``. The emitted trace is
    bit-identical either way.

    ``faults``: a ``repro.faults`` overlay injected into core + vector
    unit — the co-simulation contract extends to faulted silicon: both
    backends model the same defect realisation, so their traces still
    match.
    """

    def __init__(self, cfg: BSS2Config, inst=None,
                 ppu_executor: str = "auto", telemetry: bool = False,
                 faults=None):
        from repro.obs import trace as obs_trace

        self.cfg = cfg
        self.inst = inst or ideal_instance(cfg)
        self.core = AnnCore(cfg, self.inst, faults=faults)
        self.state = self.core.init_state()
        self._pending: List[Tuple[np.ndarray, np.ndarray]] = []
        self._ppu = VectorUnit(cfg, self.inst, faults=faults)
        self.ppu_executor = ppu_executor
        self._ppu_prog = None
        self._ppu_run = None
        self._run_cache = {}
        self.tele = obs_trace.init_telemetry() if telemetry else None

    def telemetry_summary(self):
        """Host summary of the accumulated counters (None when off)."""
        from repro.obs import trace as obs_trace
        return obs_trace.summary(self.tele)

    def _bind_program(self, words: np.ndarray):
        """Jit one PPU_RUN closure per uploaded program: the word stream
        is a concrete constant of the traced function, which is what lets
        the specialized executor unroll it at trace time. Closures are
        memoized on the program word bytes, so suites that re-upload the
        same rules (or interleave several) never retrace per upload — and
        the specialized executor additionally shares its unrolled jaxpr
        process-wide via ``repro.ppuvm.specialize``'s closure cache."""
        from repro.ppuvm import interp

        ex = interp.resolve_executor(self.ppu_executor, words)
        prog = jnp.asarray(words)
        key = np.asarray(words).tobytes()
        self._ppu_prog = prog
        cached = self._run_cache.get(key)
        if cached is not None:
            self._ppu_run = cached
            return

        def run(state, mod_fp, noise_fp):
            return self._ppu.run_program_fixed(
                state, prog, mod_fp=mod_fp, noise_fp=noise_fp,
                executor=ex)

        # the numpy executor is host-side by definition — it must see
        # concrete arrays, so it runs eagerly instead of under jit
        self._ppu_run = run if ex == "numpy" else jax.jit(run)
        self._run_cache[key] = self._ppu_run

    def _run_window(self, run_jit, ev, ad):
        """One emulation window with the telemetry pytree threaded (the
        counters ride the jitted call; ``None`` compiles them out)."""
        self.state, out = run_jit(self.state, ev, ad, telemetry=self.tele)
        if self.tele is not None:
            self.tele = out["telemetry"]
        return out

    def execute(self, program: List[Instr]) -> List[Tuple[int, str, np.ndarray]]:
        from repro.obs import trace as obs_trace
        trace = []
        t = 0
        run_jit = jax.jit(self.core.run)
        for ins in program:
            if ins.op == "WRITE_WEIGHTS":
                self.state = self.state._replace(
                    syn=self.state.syn._replace(weights=jnp.asarray(ins.payload)))
            elif ins.op == "WRITE_ADDRESSES":
                self.state = self.state._replace(
                    syn=self.state.syn._replace(addresses=jnp.asarray(ins.payload)))
            elif ins.op == "INJECT":
                ev, ad = ins.payload
                out = self._run_window(run_jit, jnp.asarray(ev),
                                       jnp.asarray(ad))
                t += ev.shape[0]
                trace.append((t, "SPIKES", np.asarray(out["spikes"])))
            elif ins.op == "RUN":
                steps = ins.payload
                ev = jnp.zeros((steps, self.cfg.n_rows))
                ad = jnp.zeros((steps, self.cfg.n_rows), jnp.int8)
                out = self._run_window(run_jit, ev, ad)
                t += steps
                trace.append((t, "SPIKES", np.asarray(out["spikes"])))
            elif ins.op == "READ_RATES":
                trace.append((t, "RATES", np.asarray(self.state.rate_counters)))
            elif ins.op == "READ_WEIGHTS":
                trace.append((t, "WEIGHTS", np.asarray(self.state.syn.weights)))
            elif ins.op == "READ_V":
                trace.append((t, "V", np.asarray(self.state.neuron.v)))
            elif ins.op == "READ_CORR":
                trace.append((t, "CORR", np.asarray(self.state.corr.a_causal)))
            elif ins.op == "WRITE_PPU_PROGRAM":
                self._bind_program(ins.payload)
            elif ins.op == "PPU_RUN":
                if self._ppu_prog is None:
                    raise ValueError("PPU_RUN before WRITE_PPU_PROGRAM")
                mod_fp, noise_fp = ins.payload
                if self.tele is not None:
                    self.tele = obs_trace.count_trial(
                        self.tele, self.state.rate_counters)
                self.state, regs = self._ppu_run(
                    self.state,
                    None if mod_fp is None else jnp.asarray(mod_fp),
                    None if noise_fp is None else jnp.asarray(noise_fp))
                self.tele = obs_trace.count_vm(self.tele, regs)
                trace.append((t, "PPU_W", np.asarray(self.state.syn.weights)))
            else:
                raise ValueError(ins.op)
        return trace


class RefBackend:
    """Independent straight-loop NumPy implementation of the same machine
    (LIF + exp term, STP, address-matched synapses, correlation sensors).

    ``faults`` applies the same ``repro.faults`` overlay as the fast
    backend, re-implemented as straight NumPy at the same hook sites —
    the independence of the reference extends to the fault model."""

    def __init__(self, cfg: BSS2Config, inst=None, faults=None):
        from repro.faults.model import as_plans
        self.faults = as_plans(faults)
        self.cfg = cfg
        inst = inst or ideal_instance(cfg)
        self.p = {k: np.asarray(v) for k, v in inst["neuron_params"].items()}
        self.gain = np.asarray(inst["weight_gain"])
        self.stp_offset = np.asarray(inst["stp_offset"])
        self.stp_calib = np.asarray(inst["stp_calib"])
        self.cadc_offset = np.asarray(inst["cadc_offset"], np.float32)
        self.cadc_gain = np.asarray(inst["cadc_gain"], np.float32)
        self.ppu_prog = None
        r, c = cfg.n_rows, cfg.n_cols
        self.w = np.zeros((r, c), np.int8)
        self.addr = np.zeros((r, c), np.int8)
        # float32 state: the co-sim target is semantic equivalence with the
        # fp32 JAX backend, not extended-precision integration
        f32 = np.float32
        self.p = {k: v.astype(f32) for k, v in self.p.items()}
        self.gain = self.gain.astype(f32)
        self.stp_offset = self.stp_offset.astype(f32)
        self.v = self.p["e_leak"].copy()
        self.wad = np.zeros(c, f32)
        self.i_exc = np.zeros(c, f32)
        self.i_inh = np.zeros(c, f32)
        self.refrac = np.zeros(c, f32)
        self.stp_r = np.ones(r, f32)
        self.tr_pre = np.zeros(r, f32)
        self.tr_post = np.zeros(c, f32)
        self.a_causal = np.zeros((r, c), f32)
        self.a_acausal = np.zeros((r, c), f32)
        self.rates = np.zeros(c, f32)

    def _step(self, ev, ad):
        cfg, p, dt = self.cfg, self.p, self.cfg.dt
        from repro.core.stp import CALIB_STEP, CALIB_BITS
        for fp in self.faults:                 # dead synapse drivers
            if fp.dead_rows is not None:
                ev = ev * (~fp.dead_rows).astype(np.float32)
        trim = ((self.stp_calib.astype(np.float32) - 2 ** (CALIB_BITS - 1))
                * np.float32(CALIB_STEP))
        eff = np.clip(cfg.stp_u * self.stp_r * (1.0 + self.stp_offset - trim),
                      0.0, 1.5) * ev
        self.stp_r = np.clip(
            self.stp_r + (1 - self.stp_r) * (1 - np.exp(-dt / cfg.stp_tau_rec))
            - cfg.stp_u * self.stp_r * ev, 0.0, 1.0)

        w_read = self.w
        for fp in self.faults:                 # stuck cells at the read
            if fp.stuck_w_mask is not None:
                w_read = np.where(fp.stuck_w_mask,
                                  fp.stuck_w_val.astype(w_read.dtype),
                                  w_read)
        i_cols = np.zeros((2, cfg.n_cols))
        for half in (0, 1):
            rows = slice(half, None, 2)
            match = (self.addr[rows] == ad[rows][:, None])
            weff = w_read[rows].astype(np.float32) * match
            i_cols[half] = (weff * eff[rows][:, None]).sum(0) * self.gain

        de = np.exp(-dt / p["tau_syn_exc"])
        di = np.exp(-dt / p["tau_syn_inh"])
        self.i_exc = self.i_exc * de + i_cols[0] * 60.0
        self.i_inh = self.i_inh * di + i_cols[1] * 60.0
        i_total = self.i_exc - self.i_inh - self.wad

        if cfg.neuron.adex:
            arg = np.clip((self.v - p["v_thres"]) / p["delta_t"], -20.0, 3.0)
            i_exp = p["g_leak"] * p["delta_t"] * np.exp(arg)
        else:
            i_exp = 0.0
        tau_m = p["c_mem"] / p["g_leak"]
        v_inf = p["e_leak"] + (i_total + i_exp) / p["g_leak"]
        v = v_inf + (self.v - v_inf) * np.exp(-dt / tau_m)
        w_inf = p["a"] * (self.v - p["e_leak"])
        wad = w_inf + (self.wad - w_inf) * np.exp(-dt / p["tau_w"])

        in_ref = self.refrac > 0
        v = np.where(in_ref, p["e_reset"], v)
        wad = np.where(in_ref, self.wad, wad)
        spike_v = p["v_thres"] + (2.0 * p["delta_t"] if cfg.neuron.adex else 0.0)
        spikes = (v > spike_v) & ~in_ref
        v = np.where(spikes, p["e_reset"], v)
        wad = np.where(spikes, wad + p["b"], wad)
        self.refrac = np.where(spikes, p["tau_refrac"],
                               np.maximum(self.refrac - dt, 0.0))
        self.v, self.wad = v, wad
        sp = spikes.astype(np.float32)
        for fp in self.faults:                 # output-driver faults: the
            if fp.hot_neurons is not None:     # membrane above integrated
                sp = np.where(fp.hot_neurons, np.float32(1.0), sp)
            if fp.dead_neurons is not None:    # unmasked, like AnnCore
                sp = sp * (~fp.dead_neurons).astype(np.float32)

        # correlation sensors (nominal scalar tau, as in AnnCore.step)
        tau = cfg.neuron.tau_syn_exc
        self.tr_pre = self.tr_pre * np.exp(-dt / tau) + ev
        self.tr_post = self.tr_post * np.exp(-dt / tau) + sp
        self.a_causal = np.minimum(
            self.a_causal + self.tr_pre[:, None] * sp[None, :], 1023.0)
        self.a_acausal = np.minimum(
            self.a_acausal + ev[:, None] * self.tr_post[None, :], 1023.0)
        self.rates += sp
        return sp

    def _cadc_digitize(self, a):
        """NumPy twin of cadc.digitize as used by VectorUnit (in_scale=8)."""
        lsb = 2 ** self.cfg.cadc_bits - 1
        code = a * (self.cadc_gain[None, :] * 8.0) + self.cadc_offset[None, :]
        q = np.clip(np.round(code), 0, lsb).astype(np.int32)
        for fp in self.faults:                 # corrupted CADC columns
            if fp.cadc_code_offset is not None:
                q = np.clip(q + fp.cadc_code_offset[None, :], 0, lsb)
            if fp.cadc_stuck_mask is not None:
                q = np.where(fp.cadc_stuck_mask[None, :],
                             fp.cadc_stuck_code[None, :], q)
        return q

    def _ppu_run(self, mod_fp, noise_fp):
        from repro.ppuvm.interp import run_program_np

        if self.ppu_prog is None:
            raise ValueError("PPU_RUN before WRITE_PPU_PROGRAM")
        qc = self._cadc_digitize(self.a_causal)
        qa = self._cadc_digitize(self.a_acausal)
        w_new, _ = run_program_np(self.ppu_prog, self.w.astype(np.int32),
                                  qc, qa, self.rates, mod_fp, noise_fp)
        for fp in self.faults:                 # store-path faults
            if fp.store_flip is not None:
                w_new = w_new ^ fp.store_flip.astype(w_new.dtype)
            if fp.store_zero is not None:
                w_new = np.where(fp.store_zero, 0, w_new)
        self.w = w_new.astype(np.int8)
        # post-read observable reset, like VectorUnit._reset_observables
        self.rates = np.zeros_like(self.rates)
        self.a_causal = np.zeros_like(self.a_causal)
        self.a_acausal = np.zeros_like(self.a_acausal)

    def execute(self, program: List[Instr]) -> List[Tuple[int, str, np.ndarray]]:
        trace = []
        t = 0
        for ins in program:
            if ins.op == "WRITE_WEIGHTS":
                self.w = ins.payload.copy()
            elif ins.op == "WRITE_ADDRESSES":
                self.addr = ins.payload.copy()
            elif ins.op in ("INJECT", "RUN"):
                if ins.op == "INJECT":
                    ev, ad = ins.payload
                else:
                    ev = np.zeros((ins.payload, self.cfg.n_rows), np.float32)
                    ad = np.zeros_like(ev, dtype=np.int8)
                sp = np.stack([self._step(ev[i], ad[i])
                               for i in range(ev.shape[0])])
                t += ev.shape[0]
                trace.append((t, "SPIKES", sp))
            elif ins.op == "READ_RATES":
                trace.append((t, "RATES", self.rates.copy()))
            elif ins.op == "READ_WEIGHTS":
                trace.append((t, "WEIGHTS", self.w.copy()))
            elif ins.op == "READ_V":
                trace.append((t, "V", self.v.copy()))
            elif ins.op == "READ_CORR":
                trace.append((t, "CORR", self.a_causal.copy()))
            elif ins.op == "WRITE_PPU_PROGRAM":
                self.ppu_prog = ins.payload.copy()
            elif ins.op == "PPU_RUN":
                self._ppu_run(*ins.payload)
                trace.append((t, "PPU_W", self.w.copy()))
            else:
                raise ValueError(ins.op)
        return trace


def execute(program: List[Instr], backend: str, cfg: BSS2Config, inst=None,
            ppu_executor: str = "auto", telemetry: bool = False,
            faults=None):
    """Run a playback program. ``backend`` is "fast" (jitted machine
    model) or "ref" (independent NumPy loop); ``ppu_executor`` picks the
    fast backend's PPU-VM executor (ignored by "ref", which always runs
    the independent NumPy interpreter). ``telemetry`` threads the
    fast backend's counter pytree (ignored by "ref" — the independent
    reference stays uninstrumented by design). ``faults`` injects the
    same ``repro.faults`` overlay into either backend — co-simulation of
    the defect realisation itself."""
    be = (FastBackend(cfg, inst, ppu_executor=ppu_executor,
                      telemetry=telemetry, faults=faults)
          if backend == "fast" else RefBackend(cfg, inst, faults=faults))
    return be.execute(program)


def compare_traces(a, b, atol=1e-3) -> List[str]:
    """Diff two experiment traces; returns a list of mismatch descriptions
    (empty == co-simulation PASS).

    Every value mismatch is LOCALIZED through the first-divergence
    locator (``repro.verif.mismatch.first_divergence``): the message
    names the emulation phase, the absolute timestep (for time-leading
    records), and the first differing array index — "where the traces
    split", not a bare assert. ``first_divergence(a, b)`` gives the same
    information as a structured ``Divergence`` object.
    """
    from repro.verif.mismatch import PHASE_OF_KIND, first_divergence

    errs = []
    if len(a) != len(b):
        errs.append(f"trace length {len(a)} != {len(b)}")
    for i, ((ta, ka, va), (tb, kb, vb)) in enumerate(zip(a, b)):
        if ta != tb or ka != kb:
            errs.append(f"[{i}] header ({ta},{ka}) != ({tb},{kb})")
            continue
        va, vb = np.asarray(va, np.float64), np.asarray(vb, np.float64)
        if va.shape != vb.shape:
            errs.append(f"[{i}] {ka}@{ta}: shape {va.shape} != {vb.shape}")
        elif not np.allclose(va, vb, atol=atol, rtol=1e-4):
            d = first_divergence([(ta, ka, va)], [(tb, kb, vb)], atol=atol)
            at_step = "" if d.step is None else f" step {d.step},"
            errs.append(
                f"[{i}] {ka}@{ta}: max|diff|={d.max_abs:.3e} "
                f"(phase {PHASE_OF_KIND.get(ka, '?')},{at_step} first at "
                f"index {d.where}: {d.a:g} vs {d.b:g}, "
                f"{d.n_mismatch} element(s))")
    return errs
