"""Monte-Carlo calibration (paper §3.2.2, Fig. 4).

The paper's flagship verification example: the synapse-driver STP circuit
has a mismatch-induced efficacy offset per driver; a 4-bit trim code is
found *pre-tapeout* by binary search on simulated virtual instances, and
the same routine later calibrates silicon. Here:

  * ``measure_stp_offset`` is the teststand testbench — drive a driver +
    synapse + ideal integrator with a spike train, extract the efficacy
    offset from the PSP amplitudes;
  * ``binary_search_calibrate`` is the generic vmapped code search;
  * ``calibrate_stp`` reproduces the Fig.-4 before/after histograms.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.bss2 import BSS2Config
from repro.core import stp


def measure_stp_offset(cfg: BSS2Config, stp_offset, calib_code,
                       n_spikes: int = 5, isi: float = 50.0):
    """Testbench: equidistant spike train into the driver; the measured
    first-pulse efficacy, normalized by the nominal u, gives the offset.

    stp_offset/calib_code: [...] arrays (any shape of virtual drivers).
    Returns measured offset, same shape.
    """
    state = stp.init_state(stp_offset.shape)
    spikes = jnp.ones(stp_offset.shape, jnp.float32)
    amps = []
    for _ in range(n_spikes):
        eff = stp.efficacy(state, spikes, u=cfg.stp_u, offset=stp_offset,
                           calib_code=calib_code)
        state = stp.update(state, spikes, u=cfg.stp_u,
                           tau_rec=cfg.stp_tau_rec, dt=isi)
        amps.append(eff)
    first = amps[0]
    return first / cfg.stp_u - 1.0


def binary_search_calibrate(measure: Callable, bits: int, shape,
                            target=0.0, increasing: bool = False):
    """Generic bitwise (per-element) binary search over an integer code.

    measure(code: int32 array of ``shape``) -> value array of ``shape``.
    Finds, per element, the code whose measured value is closest to
    ``target`` from above. ``increasing``: whether the measured value
    increases with the code.
    """
    code = jnp.zeros(shape, jnp.int32)
    for bit in reversed(range(bits)):
        trial = code + (1 << bit)
        val = measure(trial)
        accept = (val < target) if increasing else (val > target)
        code = jnp.where(accept, trial, code)
    return code


def calibrate_stp(cfg: BSS2Config, stp_offset) -> Tuple[jnp.ndarray, Dict]:
    """Find per-driver trim codes; returns (codes, metrics).

    metrics: offsets before/after, std before/after — the Fig. 4 numbers.
    """
    def measure(code):
        return measure_stp_offset(cfg, stp_offset, code)

    codes = binary_search_calibrate(measure, cfg.calib_bits,
                                    jnp.shape(stp_offset), target=0.0,
                                    increasing=False)
    before = measure_stp_offset(
        cfg, stp_offset,
        jnp.full(stp_offset.shape, 2 ** (cfg.calib_bits - 1), jnp.int32))
    after = measure_stp_offset(cfg, stp_offset, codes)
    return codes, dict(
        before=before, after=after,
        std_before=jnp.std(before), std_after=jnp.std(after),
        max_abs_after=jnp.max(jnp.abs(after)),
    )
