"""Verification methodology (paper §3): teststand-style MC simulation,
virtual instances, pre-"tapeout" calibration, playback co-simulation."""
from repro.verif.mismatch import sample_instance  # noqa: F401
