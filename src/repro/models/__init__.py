from repro.models.transformer import build_model, input_specs, ModelBundle  # noqa: F401
