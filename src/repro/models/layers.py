"""Shared layer primitives: norms, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_decl(d: int) -> ParamDecl:
    return ParamDecl((d,), (None,), init="ones")


def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rmsnorm_gated(x, z, w, eps: float = 1e-5):
    """Mamba-2 gated RMSNorm: norm(x * silu(z)) * w."""
    dt = x.dtype
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_decls(d: int, f: int) -> dict:
    return dict(
        wg=ParamDecl((d, f), (Ax.EMBED, Ax.FF)),
        w1=ParamDecl((d, f), (Ax.EMBED, Ax.FF)),
        w2=ParamDecl((f, d), (Ax.FF, Ax.EMBED)),
    )


def mlp(x, p, ctx: ShardingCtx):
    h = jax.nn.silu(x @ ctx.cast(p["wg"])) * (x @ ctx.cast(p["w1"]))
    return h @ ctx.cast(p["w2"])


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab-sharded)
# ---------------------------------------------------------------------------

def embed_decl(vocab: int, d: int) -> ParamDecl:
    return ParamDecl((vocab, d), (Ax.VOCAB, Ax.EMBED), init="embed")


def embed_lookup(tokens, emb, ctx: ShardingCtx):
    x = ctx.cast(emb)[tokens]
    return x


def unembed(x, emb, ctx: ShardingCtx, real_vocab: int = 0):
    """Logits against the (tied) embedding — vocab stays model-sharded.

    The bf16 weight operand is explicitly constrained to (vocab-sharded,
    embed-replicated): without this GSPMD keeps the FSDP shard on the
    contraction dim and lowers the matmul into *logit partial-sum
    all-reduces* — measured ~10 GB-scale fp32 AR per loss chunk vs an
    ~84 MB weight all-gather (EXPERIMENTS.md §Perf P7)."""
    emb_c = ctx.constrain(ctx.cast(emb), Ax.VOCAB_ACT, None)
    logits = x @ emb_c.T
    axes = (Ax.BATCH,) + (Ax.NONE,) * (x.ndim - 2) + (Ax.VOCAB_ACT,)
    logits = ctx.constrain(logits, *axes)
    return mask_vocab_pad(logits, real_vocab)


def mask_vocab_pad(logits, real_vocab: int):
    """-inf the padded vocab columns (vocab_padded > vocab)."""
    if real_vocab and logits.shape[-1] > real_vocab:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < real_vocab, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits


def lm_loss_chunked(x, emb_or_head, labels, ctx: ShardingCtx, *,
                    tied: bool, mask=None, max_chunk_tokens: int = 1 << 18,
                    real_vocab: int = 0):
    """Cross entropy with the unembed fused per batch-chunk.

    Avoids materializing the full [B, S, V] fp32 logits: the python loop over
    batch chunks keeps the peak at chunk_B x S x V (and stays exact in the
    dry-run HLO cost analysis, unlike a scan).
    """
    b, s = labels.shape
    n_chunks = max(1, (b * s) // max_chunk_tokens)
    while b % n_chunks:
        n_chunks -= 1
    cb = b // n_chunks
    total = jnp.zeros((), jnp.float32)
    denom = jnp.zeros((), jnp.float32)
    w = emb_or_head if not tied else None
    for i in range(n_chunks):
        xc = x[i * cb:(i + 1) * cb]
        lc = labels[i * cb:(i + 1) * cb]
        if tied:
            logits = unembed(xc, emb_or_head, ctx, real_vocab=real_vocab)
        else:
            w_c = ctx.constrain(ctx.cast(w), None, Ax.VOCAB_ACT)
            logits = ctx.constrain(xc @ w_c, Ax.BATCH, None, Ax.VOCAB_ACT)
            logits = mask_vocab_pad(logits, real_vocab)
        logits = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            mc = mask[i * cb:(i + 1) * cb]
            total = total + jnp.sum(nll * mc)
            denom = denom + jnp.sum(mc)
        else:
            total = total + jnp.sum(nll)
            denom = denom + nll.size
    return total / jnp.maximum(denom, 1.0)


def softmax_xent(logits, labels, mask=None):
    """Cross entropy stable over a (possibly vocab-sharded) last dim."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
