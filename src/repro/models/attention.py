"""Attention: GQA with context-parallel sharding.

Three execution paths:

  * ``attention_prefill`` — online-softmax over KV blocks. The block loop is
    a *python* loop (unrolled HLO) so the dry-run cost analysis is exact and
    the peak score buffer is one block. Queries stay sequence-sharded over
    the ``model`` axis (context parallelism) — this keeps per-device compute
    exact for head counts (15/24/25) that do not divide the 16-way axis;
    head-sharding was measured to cost ~2x redundant FLOPs (see DESIGN.md).
  * ``attention_swa_blocked`` — exact banded sliding-window attention via the
    two-block trick (each w-sized q block attends to its own and the previous
    KV block). Used when the sequence is long enough to keep every model
    shard busy; short sequences fall back to the masked prefill path.
  * ``attention_decode`` — one query token against a full (seq-sharded) KV
    cache; XLA turns the softmax over the sharded KV dim into a small
    all-reduce of max/sum partials.

Scores and softmax statistics are fp32; the p@v contraction runs in the
compute dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx
from repro.models.layers import apply_rope

NEG_INF = -1e30


def attn_decls(arch: ArchConfig) -> dict:
    d, h, kvh, hd = arch.d_model, arch.n_heads, arch.n_kv_heads, arch.head_dim
    decls = dict(
        wq=ParamDecl((d, h * hd), (Ax.EMBED, Ax.HEADS_OUT)),
        wk=ParamDecl((d, kvh * hd), (Ax.EMBED, Ax.HEADS_OUT)),
        wv=ParamDecl((d, kvh * hd), (Ax.EMBED, Ax.HEADS_OUT)),
        wo=ParamDecl((h * hd, d), (Ax.HEADS_OUT, Ax.EMBED)),
    )
    if arch.qkv_bias:
        decls.update(
            bq=ParamDecl((h * hd,), (None,), init="zeros"),
            bk=ParamDecl((kvh * hd,), (None,), init="zeros"),
            bv=ParamDecl((kvh * hd,), (None,), init="zeros"),
        )
    return decls


def _qkv(x, p, arch: ArchConfig, ctx: ShardingCtx, positions):
    b = x.shape[0]
    s = x.shape[1]
    h, kvh, hd = arch.n_heads, arch.n_kv_heads, arch.head_dim
    q = x @ ctx.cast(p["wq"])
    k = x @ ctx.cast(p["wk"])
    v = x @ ctx.cast(p["wv"])
    if arch.qkv_bias:
        q = q + ctx.cast(p["bq"])
        k = k + ctx.cast(p["bk"])
        v = v + ctx.cast(p["bv"])
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if arch.rope_theta:
        q = apply_rope(q, positions, arch.rope_theta)
        k = apply_rope(k, positions, arch.rope_theta)
    # context-parallel layout: sequence over `model`
    q = ctx.constrain(q, Ax.BATCH, Ax.SEQ, None, None)
    k = ctx.constrain(k, Ax.BATCH, Ax.SEQ, None, None)
    v = ctx.constrain(v, Ax.BATCH, Ax.SEQ, None, None)
    return q, k, v


def attention_prefill(q, k, v, *, causal: bool, window: int, ctx: ShardingCtx,
                      kv_block: int = 8192, q_offset: int = 0):
    """Online-softmax attention; python-unrolled KV-block loop.

    q: [b, sq, h, hd]; k/v: [b, skv, kvh, hd]. Returns [b, sq, h, hd].
    ``q_offset``: global position of q[...,0] relative to k (prefix caches).

    When the whole KV fits in one block the online accumulators are skipped
    entirely (plain softmax): at seq<=kv_block the accumulator update traffic
    (fp32 [b,s,h,hd] read+write per block) dominated the HLO byte count —
    measured 1.7 TB/device on smollm train_4k with kv_block=2048 (see
    EXPERIMENTS.md §Perf iteration log).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / (hd ** 0.5)

    kv_block = min(kv_block, skv)
    n_blocks = (skv + kv_block - 1) // kv_block
    qpos = jnp.arange(sq) + q_offset

    if n_blocks == 1:
        sc = jnp.einsum("bqkgd,btkd->bkgqt", qg, k,
                        preferred_element_type=jnp.float32) * scale
        # keep the q dim context-parallel: without this constraint GSPMD
        # replicates the [sq, skv] score tensor on every model shard
        sc = ctx.constrain(sc, Ax.BATCH, None, None, Ax.SEQ, None)
        kpos = jnp.arange(skv)
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
        out = ctx.constrain(out, Ax.BATCH, Ax.SEQ, None, None, None)
        return out.reshape(b, sq, h, hd).astype(q.dtype)

    m = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)

    for j in range(n_blocks):
        lo = j * kv_block
        hi = min(lo + kv_block, skv)
        kj = k[:, lo:hi]
        vj = v[:, lo:hi]
        kposj = jnp.arange(lo, hi)
        s_ij = jnp.einsum("bqkgd,btkd->bkgqt", qg, kj,
                          preferred_element_type=jnp.float32) * scale
        s_ij = ctx.constrain(s_ij, Ax.BATCH, None, None, Ax.SEQ, None)
        mask = jnp.ones((sq, hi - lo), bool)
        if causal:
            mask &= qpos[:, None] >= kposj[None, :]
        if window:
            mask &= (qpos[:, None] - kposj[None, :]) < window
        s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
        p = jnp.exp(s_ij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(q.dtype), vj,
                        preferred_element_type=jnp.float32)
        pv = ctx.constrain(pv, Ax.BATCH, Ax.SEQ, None, None, None)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        m = m_new

    lt = l.transpose(0, 3, 1, 2)[..., None]
    out = acc / jnp.maximum(lt, 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_swa_blocked(q, k, v, *, window: int, ctx: ShardingCtx):
    """Exact sliding-window attention via the two-block band trick.

    Requires sq == skv == s, s % window == 0. Each w-block of queries attends
    to its own and the previous KV block (covers the full causal window).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    w = window
    assert s % w == 0
    nb = s // w
    scale = 1.0 / (hd ** 0.5)

    qb = q.reshape(b, nb, w, kvh, g, hd)
    kb = k.reshape(b, nb, w, kvh, hd)
    vb = v.reshape(b, nb, w, kvh, hd)
    zpad = jnp.zeros_like(kb[:, :1])
    kcat = jnp.concatenate([jnp.concatenate([zpad, kb[:, :-1]], 1), kb], 2)
    vcat = jnp.concatenate([jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], 1), vb], 2)
    # kcat: [b, nb, 2w, kvh, hd]
    sc = jnp.einsum("bnqkgd,bntkd->bnkgqt", qb, kcat,
                    preferred_element_type=jnp.float32) * scale
    sc = ctx.constrain(sc, Ax.BATCH, Ax.SEQ, None, None, None, None)
    i = jnp.arange(w)[:, None]          # q index within block
    jj = jnp.arange(2 * w)[None, :]     # k index within concat window
    band = (jj <= i + w) & (jj > i)     # causal + window
    n = jnp.arange(nb)[:, None, None]
    valid = ((n - 1) * w + jj[None]) >= 0    # first block has no predecessor
    mask = band[None] & valid
    sc = jnp.where(mask[None, :, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnkgqt,bntkd->bnqkgd", p.astype(q.dtype), vcat,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, s, h, hd).astype(q.dtype)
    return ctx.constrain(out, Ax.BATCH, Ax.SEQ, None, None)


def attention_decode(q, cache_k, cache_v, t, *, window: int, ctx: ShardingCtx):
    """Single-token attention over a (seq-sharded) KV cache.

    q: [b, 1, h, hd]; cache_k/v: [b, S, kvh, hd]; t: current position
    (scalar, the new token's index). Attends to positions <= t.
    """
    b, _, h, hd = q.shape
    S, kvh = cache_k.shape[1], cache_k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scale = 1.0 / (hd ** 0.5)
    sc = jnp.einsum("bqkgd,btkd->bkgqt", qg, cache_k,
                    preferred_element_type=jnp.float32) * scale
    sc = ctx.constrain(sc, Ax.BATCH, None, None, None, Ax.KV_SEQ)
    kpos = jnp.arange(S)
    mask = kpos[None, :] <= t
    if window:
        mask &= kpos[None, :] > (t - window)
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(q.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def attn_layer(x, p, arch: ArchConfig, layer_idx: int, ctx: ShardingCtx, *,
               positions, kv_block: int = 2048,
               cache: Optional[dict] = None, t=None, collect_kv: bool = False):
    """Full attention sublayer. Returns (out, new_cache_entry_or_None)."""
    window = 0
    if arch.swa_window and layer_idx not in arch.global_attn_layers:
        window = arch.swa_window
    q, k, v = _qkv(x, p, arch, ctx,
                   positions=positions)
    new_cache = None
    if cache is not None:
        # decode: write k/v at position t, then attend over the cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, t, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, t, axis=1)
        ck = ctx.constrain(ck, Ax.BATCH, Ax.KV_SEQ, None, None)
        cv = ctx.constrain(cv, Ax.BATCH, Ax.KV_SEQ, None, None)
        o = attention_decode(q, ck, cv, t, window=window, ctx=ctx)
        new_cache = dict(k=ck, v=cv)
    else:
        s = x.shape[1]
        use_blocked = (window and s % window == 0
                       and (s // window) >= max(ctx.model_size, 2))
        if use_blocked:
            o = attention_swa_blocked(q, k, v, window=window, ctx=ctx)
        else:
            o = attention_prefill(q, k, v, causal=arch.causal, window=window,
                                  ctx=ctx, kv_block=kv_block)
        if collect_kv:
            new_cache = dict(k=k, v=v)
    b, sq = o.shape[0], o.shape[1]
    o = o.reshape(b, sq, arch.n_heads * arch.head_dim)
    o = ctx.constrain(o, Ax.BATCH, Ax.SEQ, None)
    return o @ ctx.cast(p["wo"]), new_cache


def cache_decls(arch: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    """KV-cache declarations per layer (batch over data, seq over model)."""
    kvh, hd = arch.n_kv_heads, arch.head_dim
    return dict(
        k=ParamDecl((batch, max_len, kvh, hd),
                    (Ax.BATCH, Ax.KV_SEQ, None, None), init="zeros", dtype=dtype),
        v=ParamDecl((batch, max_len, kvh, hd),
                    (Ax.BATCH, Ax.KV_SEQ, None, None), init="zeros", dtype=dtype),
    )
