"""Mixture-of-Experts layer: top-k routing, DP-grouped capacity dispatch.

Dispatch strategy (designed for the (data, model) mesh, see DESIGN.md §4):

  * tokens are reshaped to [dp_groups, T, d] so that each data-parallel group
    dispatches *its own* tokens — no cross-data-axis scatter traffic; the
    only expert-parallel communication is the gather into / out of the
    ``model``-sharded expert buffers (the EP all-to-all).
  * slot assignment is computed with a cumsum over a [g, T*k, E] one-hot
    (no O(T*E*C) dispatch tensor); tokens beyond expert capacity are dropped
    (GShard semantics, capacity_factor configurable).
  * the expert FFN is a single grouped einsum over the expert-sharded weight
    stack — local matmuls on every device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx
from repro.models.layers import mlp, mlp_decls


def moe_decls(arch: ArchConfig) -> dict:
    d = arch.d_model
    m = arch.moe
    fe = m.d_ff_expert
    decls = dict(
        router=ParamDecl((d, m.n_experts), (Ax.EMBED, None), scale=0.02),
        we_gate=ParamDecl((m.n_experts, d, fe), (Ax.EXPERT, Ax.EMBED, None)),
        we_up=ParamDecl((m.n_experts, d, fe), (Ax.EXPERT, Ax.EMBED, None)),
        we_down=ParamDecl((m.n_experts, fe, d), (Ax.EXPERT, None, Ax.EMBED)),
    )
    if m.n_shared_experts:
        decls["shared"] = mlp_decls(d, fe * m.n_shared_experts)
    return decls


def _capacity(tokens_per_group: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(tokens_per_group * top_k / n_experts * cf)
    return max(4, c)


def moe_ffn(x, p, arch: ArchConfig, ctx: ShardingCtx, *, positions=None):
    """x: [b, s, d] (batch over data axes). Returns [b, s, d] + aux loss."""
    b, s, d = x.shape
    m = arch.moe
    E, K = m.n_experts, m.top_k
    dp = ctx.dp_size
    assert b % dp == 0, (b, dp)
    T = (b // dp) * s
    C = _capacity(T, K, E, m.capacity_factor)

    xg = x.reshape(dp, T, d)
    xg = ctx.constrain(xg, Ax.DP_GROUP, None, None)

    # --- routing (fp32) ------------------------------------------------------
    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # [g, T, E]
    gates, eidx = jax.lax.top_k(probs, K)                    # [g, T, K]
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                        # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / K                                     # assignments/tok
    aux = E * jnp.sum(me * ce)                               # ==1 if balanced

    # --- slot assignment ------------------------------------------------------
    eflat = eidx.reshape(dp, T * K)                          # [g, TK]
    oh = jax.nn.one_hot(eflat, E, dtype=jnp.int32)           # [g, TK, E]
    pos_all = jnp.cumsum(oh, axis=1) - 1                     # position per expert
    pos = jnp.take_along_axis(pos_all, eflat[..., None], axis=-1)[..., 0]
    keep = pos < C                                           # dropped beyond capacity

    # slot -> token map: slot_tok[g, e, c] = token index (or T: dummy)
    tok_of_entry = jnp.arange(T * K) // K                    # [TK]
    gi = jnp.broadcast_to(jnp.arange(dp)[:, None], (dp, T * K))
    e_safe = jnp.where(keep, eflat, 0)
    pos_safe = jnp.where(keep, pos, C)                       # C -> dropped row
    slot_tok = jnp.full((dp, E, C + 1), T, jnp.int32)
    slot_tok = slot_tok.at[gi, e_safe, pos_safe].set(
        jnp.where(keep, tok_of_entry[None], T), mode="drop")
    slot_tok = slot_tok[:, :, :C]                            # [g, E, C]

    # --- dispatch gather ------------------------------------------------------
    xg_pad = jnp.concatenate([xg, jnp.zeros((dp, 1, d), xg.dtype)], axis=1)
    xe = jax.vmap(lambda xp, st: xp[st])(xg_pad, slot_tok.reshape(dp, E * C))
    xe = xe.reshape(dp, E, C, d)
    xe = ctx.constrain(xe, Ax.DP_GROUP, Ax.EXPERT_ACT, None, None)

    # --- expert FFN (local matmuls: dp over data, E over model) ---------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, ctx.cast(p["we_gate"]))) \
        * jnp.einsum("gecd,edf->gecf", xe, ctx.cast(p["we_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, ctx.cast(p["we_down"]))
    ye = ctx.constrain(ye, Ax.DP_GROUP, Ax.EXPERT_ACT, None, None)

    # --- combine gather -------------------------------------------------------
    flat_slot = (e_safe * C + jnp.minimum(pos_safe, C - 1))  # [g, TK]
    yflat = jax.vmap(lambda ya, fs: ya[fs])(ye.reshape(dp, E * C, d), flat_slot)
    yflat = yflat * (keep[..., None] * gates.reshape(dp, T * K)[..., None]
                     ).astype(yflat.dtype)
    y = jnp.sum(yflat.reshape(dp, T, K, d), axis=2)
    y = y.reshape(b, s, d)

    if m.n_shared_experts:
        y = y + mlp(x, p["shared"], ctx)
    return y, aux


# ---------------------------------------------------------------------------
# Optimized expert parallelism (shard_map) — the §Perf hillclimb result
# ---------------------------------------------------------------------------

_check_kw = None   # shard_map replication-check kwarg, probed on first use


def moe_ffn_ep(x, p, arch: ArchConfig, ctx: ShardingCtx, *, positions=None):
    """Expert-parallel MoE with *explicit* per-rank dispatch.

    The GSPMD auto-sharded path (``moe_ffn``) lowers the data-dependent
    dispatch/combine gathers into full all-gathers of the [E, C, d] expert
    buffers across the model axis — measured 719 GB/device collective bytes
    on moonshot/train_4k (EXPERIMENTS.md §Perf). This version makes the
    communication explicit with shard_map:

      * activations enter replicated over ``model`` (the Megatron-SP
        all-gather that already exists at the block boundary);
      * every model rank routes all local tokens but *dispatches only to
        its own E/ep experts* — gather, expert FFN, and scatter-combine are
        entirely local;
      * partial outputs are summed with one psum over ``model``
        (2 x activation bytes, vs C-factor-larger buffer all-gathers).

    Numerically identical to ``moe_ffn`` up to summation order (tested in
    tests/test_moe_ep.py on an 8-device mesh).
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map
    global _check_kw
    if _check_kw is None:
        # the replication-check kwarg was renamed check_rep -> check_vma
        # across jax versions; probe once per process
        import inspect
        _check_kw = ({"check_vma": False} if "check_vma"
                     in inspect.signature(shard_map).parameters
                     else {"check_rep": False})

    if ctx.mesh is None:
        return moe_ffn(x, p, arch, ctx, positions=positions)

    m = arch.moe
    E, K = m.n_experts, m.top_k
    ep = ctx.model_size
    assert E % ep == 0, (E, ep)
    e_loc = E // ep
    b, s, d = x.shape
    data_axes = tuple(ctx.mesh_cfg.data_axes)
    dp = ctx.dp_size
    T = (b // dp) * s
    C = _capacity(T, K, E, m.capacity_factor)

    def block(xb, router, wg, wu, wd):
        # xb: [b_loc, s, d] (replicated over model); w*: [e_loc, ...]
        rank = jax.lax.axis_index("model")
        tb, sb, _ = xb.shape
        xt = xb.reshape(tb * sb, d)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, K)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32),
                              axis=1), axis=0) / K
        aux = E * jnp.sum(me * ce)
        # replicate across every mesh axis (tokens differ per data rank)
        aux = jax.lax.pmean(aux, tuple(ctx.mesh.axis_names))

        # global slot positions (every rank computes identically)
        eflat = eidx.reshape(-1)                          # [T*K]
        oh = jax.nn.one_hot(eflat, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - 1,
                                  eflat[:, None], 1)[:, 0]
        keep = pos < C

        # restrict to this rank's experts
        lo = rank * e_loc
        own = (eflat >= lo) & (eflat < lo + e_loc) & keep
        e_rel = jnp.where(own, eflat - lo, 0)
        pos_s = jnp.where(own, pos, C)
        tok = jnp.arange(eflat.shape[0]) // K

        slot_tok = jnp.full((e_loc, C + 1), tb * sb, jnp.int32)
        slot_tok = slot_tok.at[e_rel, pos_s].set(
            jnp.where(own, tok, tb * sb), mode="drop")[:, :C]

        xt_pad = jnp.concatenate(
            [xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        xe = xt_pad[slot_tok.reshape(-1)].reshape(e_loc, C, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))) \
            * jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
        ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))

        # local combine: scatter-add each slot back to its token
        flat_tok = slot_tok.reshape(-1)
        gate_of_entry = gates.reshape(-1)
        # gate per slot: invert via the entry -> slot map
        entry_slot_gate = jnp.where(own, gate_of_entry, 0.0)
        gate_slot = jnp.zeros((e_loc, C + 1), jnp.float32).at[
            e_rel, pos_s].set(entry_slot_gate, mode="drop")[:, :C]
        contrib = ye * gate_slot[..., None].astype(ye.dtype)
        y = jnp.zeros((tb * sb + 1, d), ye.dtype).at[flat_tok].add(
            contrib.reshape(-1, d), mode="drop")[:-1]
        y = jax.lax.psum(y, "model")
        return y.reshape(tb, sb, d), aux

    xspec = P(data_axes, None, None)
    wspec = P("model", None, None)
    y, aux = shard_map(
        block, mesh=ctx.mesh,
        in_specs=(xspec, P(None, None), wspec, wspec, wspec),
        out_specs=(xspec, P()),
        **_check_kw,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])

    if m.n_shared_experts:
        y = y + mlp(x, p["shared"], ctx)
    return y, aux
