"""Mamba-2 SSD (state-space duality) block — matmul-native TPU formulation.

The chunked SSD algorithm is expressed *entirely* as einsums:

  * intra-chunk: (C_i·B_j) ⊙ decay-kernel, a [Q,Q] matmul per chunk — MXU
    friendly;
  * inter-chunk state passing: instead of a sequential scan over chunks (a
    `while` loop hides FLOPs from the dry-run cost analysis and serializes),
    the cumulative states are computed with an O(nc²) *decay-matrix matmul*
    h_c = Σ_{j<c} (Π decay) S_j — nc = seq/chunk is small (16–128), so the
    quadratic term is negligible and the whole layer is dense linear algebra.

This is the hardware-adaptation called out in DESIGN.md: the GPU
implementation of SSD leans on a warp-level scan; on TPU the idiomatic port
turns the scan into a small dense matmul against a masked decay matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx
from repro.models.layers import rmsnorm_gated


def ssm_dims(arch: ArchConfig):
    s = arch.ssm
    di = arch.d_model * s.expand
    nh = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    return di, nh, conv_dim


def ssm_decls(arch: ArchConfig) -> dict:
    d = arch.d_model
    s = arch.ssm
    di, nh, conv_dim = ssm_dims(arch)
    d_in_proj = 2 * di + 2 * s.n_groups * s.d_state + nh
    return dict(
        # w_in's packed output (z ++ xBC ++ dt, width 2*di+2*ng*ns+nh) is not
        # TP-divisible and must not be split mid-field: FSDP-shard the embed
        # dim only; the SSD inner compute is sequence-parallel instead.
        w_in=ParamDecl((d, d_in_proj), (Ax.EMBED, None)),
        conv_w=ParamDecl((s.d_conv, conv_dim), (None, None), scale=0.5),
        conv_b=ParamDecl((conv_dim,), (None,), init="zeros"),
        a_log=ParamDecl((nh,), (None,), init="zeros"),
        dt_bias=ParamDecl((nh,), (None,), init="zeros"),
        d_skip=ParamDecl((nh,), (None,), init="ones"),
        norm_w=ParamDecl((di,), (None,), init="ones"),
        w_out=ParamDecl((di, d), (Ax.FF, Ax.EMBED)),
    )


def _causal_conv(x, w, b):
    """Depthwise causal conv via k shifted adds. x: [b, s, c]; w: [k, c]."""
    k = w.shape[0]
    y = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        y = y + shifted * w[k - 1 - i]
    return y + b


def _split_proj(zxbcdt, arch: ArchConfig):
    s = arch.ssm
    di, nh, _ = ssm_dims(arch)
    gs = s.n_groups * s.d_state
    z = zxbcdt[..., :di]
    xc = zxbcdt[..., di:2 * di + 2 * gs]       # x ++ B ++ C (conv input)
    dt = zxbcdt[..., 2 * di + 2 * gs:]
    return z, xc, dt


def ssd_prefill(x, p, arch: ArchConfig, ctx: ShardingCtx, *, return_state=False):
    """Full-sequence SSD. x: [b, s, d] -> [b, s, d] (+ final ssm state)."""
    b, s_in, d = x.shape
    cfg = arch.ssm
    di, nh, conv_dim = ssm_dims(arch)
    hd, ns, ng = cfg.head_dim, cfg.d_state, cfg.n_groups
    Q = min(cfg.chunk, s_in)
    pad = (-s_in) % Q
    if pad:
        # zero-pad the tail to a chunk multiple (outputs are sliced back;
        # only valid with return_state=False, since the tail would pollute
        # the final state)
        assert not return_state, "padded prefill cannot return a state"
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s_len = s_in + pad
    nc = s_len // Q

    zxbcdt = x @ ctx.cast(p["w_in"])
    z, xconv_raw, dt = _split_proj(zxbcdt, arch)
    xconv = jax.nn.silu(_causal_conv(xconv_raw, ctx.cast(p["conv_w"]),
                                     ctx.cast(p["conv_b"])))
    xs = xconv[..., :di].reshape(b, s_len, nh, hd)
    Bm = xconv[..., di:di + ng * ns].reshape(b, s_len, ng, ns)
    Cm = xconv[..., di + ng * ns:].reshape(b, s_len, ng, ns)
    # broadcast groups over heads
    rep = nh // ng
    Bh = jnp.repeat(Bm, rep, axis=2)           # [b, s, nh, ns]
    Ch = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max * 100)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # [nh], a < 0
    dA = dt * a                                           # [b, s, nh] (log decay)

    # chunked layout
    def chunk(t):
        return t.reshape(b, nc, Q, *t.shape[2:])
    xs_c, Bh_c, Ch_c, dt_c, dA_c = map(chunk, (xs, Bh, Ch, dt, dA))
    xs_c = ctx.constrain(xs_c, Ax.BATCH, Ax.SEQ, None, None, None)
    Bh_c = ctx.constrain(Bh_c, Ax.BATCH, Ax.SEQ, None, None, None)
    Ch_c = ctx.constrain(Ch_c, Ax.BATCH, Ax.SEQ, None, None, None)
    dt_c = ctx.constrain(dt_c, Ax.BATCH, Ax.SEQ, None, None)
    dA_c = ctx.constrain(dA_c, Ax.BATCH, Ax.SEQ, None, None)

    cum = jnp.cumsum(dA_c, axis=2)                        # [b, nc, Q, nh]
    total = cum[:, :, -1]                                 # [b, nc, nh]

    # ---- intra-chunk (masked kernel matmul) ---------------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,Q(i),Q(j),nh]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch_c, Bh_c,
                        preferred_element_type=jnp.float32)
    scores = ctx.constrain(scores, Ax.BATCH, Ax.SEQ, None, None, None)
    M = scores * L * dt_c[:, :, None, :, :]               # [b,nc,Q,Q,nh]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(x.dtype), xs_c,
                        preferred_element_type=jnp.float32)
    y_diag = ctx.constrain(y_diag, Ax.BATCH, Ax.SEQ, None, None, None)

    # ---- chunk states --------------------------------------------------------
    # S_c = Σ_j exp(total_c - cum_j) dt_j B_j ⊗ x_j    [b, nc, nh, ns, hd]
    decay_to_end = jnp.exp(total[:, :, None] - cum) * dt_c      # [b,nc,Q,nh]
    Sc = jnp.einsum("bcjhn,bcjhp->bchnp",
                    (Bh_c * decay_to_end[..., None]).astype(x.dtype), xs_c,
                    preferred_element_type=jnp.float32)

    # ---- inter-chunk state passing as a decay-matrix matmul -----------------
    # H_c (state entering chunk c) = Σ_{j<c} exp(Σ_{m=j+1..c-1} total_m) S_j
    tot_cum = jnp.cumsum(total, axis=1)                   # [b, nc, nh]
    # D[c, j] = exp(tot_cum_{c-1} - tot_cum_j) for j <= c-1 else 0
    dd = tot_cum[:, :, None, :] - tot_cum[:, None, :, :]  # [b, c, j, nh]
    strict = jnp.tril(jnp.ones((nc, nc), bool), k=-1)
    # shift: want exp(tot_cum_{c-1} - tot_cum_j); tot_cum_{c-1} = tot_cum_c - total_c
    dmat = jnp.where(strict[None, :, :, None],
                     jnp.exp(dd - total[:, :, None, :]), 0.0)
    H = jnp.einsum("bcjh,bjhnp->bchnp", dmat.astype(jnp.float32), Sc,
                   preferred_element_type=jnp.float32)    # [b,nc,nh,ns,hd]

    # ---- inter-chunk output contribution -------------------------------------
    in_decay = jnp.exp(cum)                                # decay from chunk start
    y_off = jnp.einsum("bcihn,bchnp->bcihp",
                       (Ch_c * in_decay[..., None]).astype(x.dtype),
                       H.astype(x.dtype), preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, s_len, nh, hd)
    y = y + xs * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s_len, di).astype(x.dtype)
    y = ctx.constrain(y, Ax.BATCH, Ax.SEQ, None)
    y = rmsnorm_gated(y, z, p["norm_w"], arch.norm_eps)
    out = y @ ctx.cast(p["w_out"])
    if pad:
        out = out[:, :s_in]
    if return_state:
        final = H[:, -1] * jnp.exp(total[:, -1])[..., None, None] + Sc[:, -1]
        state = dict(
            conv=xconv_raw[:, -(cfg.d_conv - 1):].astype(jnp.float32),
            ssm=final)                                     # [b, nh, ns, hd]
        return out, state
    return out


def ssd_decode_step(x_t, state, p, arch: ArchConfig, ctx: ShardingCtx):
    """One-token SSD update.

    x_t: [b, 1, d]; state: dict(conv=[b, k-1, conv_dim], ssm=[b, nh, ns, hd]).
    Returns (y_t [b, 1, d], new_state).
    """
    b = x_t.shape[0]
    cfg = arch.ssm
    di, nh, conv_dim = ssm_dims(arch)
    hd, ns, ng = cfg.head_dim, cfg.d_state, cfg.n_groups

    zxbcdt = x_t @ ctx.cast(p["w_in"])
    z, xc_new, dt = _split_proj(zxbcdt, arch)
    # rolling conv state
    conv_in = jnp.concatenate([state["conv"], xc_new], axis=1)  # [b, k, c]
    w = ctx.cast(p["conv_w"])
    xc = jnp.sum(conv_in * w[None], axis=1, keepdims=True) + ctx.cast(p["conv_b"])
    xc = jax.nn.silu(xc)
    new_conv = conv_in[:, 1:]

    xs = xc[..., :di].reshape(b, nh, hd)
    Bm = xc[..., di:di + ng * ns].reshape(b, ng, ns)
    Cm = xc[..., di + ng * ns:].reshape(b, ng, ns)
    rep = nh // ng
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [b, nh]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                    # [b, nh]

    upd = jnp.einsum("bhn,bhp->bhnp", Bh * dt[..., None], xs,
                     preferred_element_type=jnp.float32)
    new_ssm = state["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_ssm.astype(x_t.dtype),
                   preferred_element_type=jnp.float32)
    y = y + xs * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x_t.dtype)
    y = rmsnorm_gated(y, z, p["norm_w"], arch.norm_eps)
    return y @ ctx.cast(p["w_out"]), dict(conv=new_conv, ssm=new_ssm)


def ssm_state_decls(arch: ArchConfig, batch: int) -> dict:
    cfg = arch.ssm
    di, nh, conv_dim = ssm_dims(arch)
    return dict(
        conv=ParamDecl((batch, cfg.d_conv - 1, conv_dim),
                       (Ax.BATCH, None, None), init="zeros", dtype=jnp.float32),
        ssm=ParamDecl((batch, nh, cfg.d_state, cfg.head_dim),
                      (Ax.BATCH, None, None, None), init="zeros",
                      dtype=jnp.float32),
    )
