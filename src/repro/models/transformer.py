"""Model assembly for all assigned architecture families.

``build_model(arch, ctx)`` returns a ``ModelBundle`` of pure functions:

  * ``decls``            — ParamDecl tree (single source of truth for init,
                           abstract lowering, and sharding specs)
  * ``forward``          — logits for train/prefill
  * ``loss``             — scalar LM/masked-unit loss (+ MoE aux)
  * ``cache_decls``      — decode-state declarations
  * ``decode_step``      — one-token step against the cache

Families:
  dense / vlm / audio : pre-norm attention + SwiGLU
  moe                 : pre-norm attention + (shared + routed top-k) MoE
  ssm                 : mamba-2 SSD blocks (no attention, no MLP)
  hybrid (hymba)      : parallel attention ∥ SSD heads, fused by mean of the
                        two normed branch outputs, + SwiGLU MLP; learnable
                        meta tokens prepended; SWA except global layers
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig
from repro.parallel.sharding import Ax, ParamDecl, ShardingCtx, abstract_params
from repro.models import layers as L
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import moe as M

AUX_LOSS_W = 0.01


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------

def _layer_decls(arch: ArchConfig, i: int) -> dict:
    d = arch.d_model
    decls: Dict[str, Any] = dict(ln1=L.rmsnorm_decl(d))
    if arch.n_heads:
        decls["attn"] = A.attn_decls(arch)
    if arch.family == "ssm":
        decls["ssm"] = S.ssm_decls(arch)
        return decls  # mamba block: single norm, no MLP
    if arch.family == "hybrid":
        decls["ssm"] = S.ssm_decls(arch)
        di = arch.d_model * arch.ssm.expand
        decls["attn_branch_norm"] = L.rmsnorm_decl(d)
        decls["ssm_branch_norm"] = L.rmsnorm_decl(d)
    decls["ln2"] = L.rmsnorm_decl(d)
    if arch.moe.n_experts and i >= arch.moe.first_k_dense:
        decls["moe"] = M.moe_decls(arch)
    elif arch.moe.n_experts:
        decls["mlp"] = L.mlp_decls(d, arch.moe.d_ff_dense_first)
    elif arch.d_ff:
        decls["mlp"] = L.mlp_decls(d, arch.d_ff)
    return decls


def model_decls(arch: ArchConfig) -> dict:
    d = arch.d_model
    decls: Dict[str, Any] = dict(
        emb=L.embed_decl(arch.vocab_padded, d),
        ln_f=L.rmsnorm_decl(d),
    )
    if not arch.tie_embeddings:
        decls["head"] = ParamDecl((d, arch.vocab_padded), (Ax.EMBED, Ax.VOCAB))
    if arch.n_meta_tokens:
        decls["meta"] = ParamDecl((arch.n_meta_tokens, d), (None, Ax.EMBED),
                                  init="embed")
    if arch.vit_dim:
        decls["vit_proj"] = dict(
            w1=ParamDecl((arch.vit_dim, d), (None, Ax.EMBED)),
            w2=ParamDecl((d, d), (Ax.EMBED, None)),
        )
    if arch.frame_dim:
        decls["frame_proj"] = ParamDecl((arch.frame_dim, d), (None, Ax.EMBED))
        decls["mask_emb"] = ParamDecl((d,), (None,), init="embed")
    for i in range(arch.n_layers):
        decls[f"layer_{i}"] = _layer_decls(arch, i)
    return decls


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block(x, p, arch: ArchConfig, i: int, ctx: ShardingCtx, *, positions,
           cache=None, t=None, collect_cache=False):
    """One transformer/SSM/hybrid block. Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = L.rmsnorm(x, p["ln1"], arch.norm_eps)

    if arch.family == "ssm":
        if cache is not None:
            y, st = S.ssd_decode_step(h, cache["ssm"], p["ssm"], arch, ctx)
            new_cache["ssm"] = st
        else:
            y = S.ssd_prefill(h, p["ssm"], arch, ctx,
                              return_state=collect_cache)
            if collect_cache:
                y, new_cache["ssm"] = y
        return x + y, aux, new_cache

    if arch.family == "hybrid":
        ao, kv = A.attn_layer(h, p["attn"], arch, i, ctx, positions=positions,
                              cache=cache.get("kv") if cache else None, t=t,
                              collect_kv=collect_cache)
        if cache is not None:
            so, st = S.ssd_decode_step(h, cache["ssm"], p["ssm"], arch, ctx)
            new_cache = dict(kv=kv, ssm=st)
        else:
            so = S.ssd_prefill(h, p["ssm"], arch, ctx,
                               return_state=collect_cache)
            if collect_cache:
                so, st = so
                new_cache = dict(kv=kv, ssm=st)
        ao = L.rmsnorm(ao, p["attn_branch_norm"], arch.norm_eps)
        so = L.rmsnorm(so, p["ssm_branch_norm"], arch.norm_eps)
        x = x + 0.5 * (ao + so)
    else:
        ao, kv = A.attn_layer(h, p["attn"], arch, i, ctx, positions=positions,
                              cache=cache.get("kv") if cache else None, t=t,
                              collect_kv=collect_cache)
        if cache is not None or collect_cache:
            new_cache["kv"] = kv
        x = x + ao

    h2 = L.rmsnorm(x, p["ln2"], arch.norm_eps)
    if "moe" in p:
        # "ep" (shard_map expert parallelism) is the production default —
        # the GSPMD auto-sharded dispatch ("gspmd") is kept as the
        # paper-faithful naive baseline; see EXPERIMENTS.md §Perf for the
        # measured 126x collective-bytes difference on moonshot/train_4k.
        moe_fn = (M.moe_ffn
                  if ctx.overrides.get("moe_impl", "ep") == "gspmd"
                  else M.moe_ffn_ep)
        y, a = moe_fn(h2, p["moe"], arch, ctx)
        aux = aux + a
    else:
        y = L.mlp(h2, p["mlp"], ctx)
    x = x + y
    x = ctx.constrain(x, Ax.BATCH, Ax.SEQ, None)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------

def _frontend(params, batch, arch: ArchConfig, ctx: ShardingCtx):
    """Returns (x [b, s_total, d], label_mask or None)."""
    if arch.family == "audio":
        frames = batch["frames"].astype(ctx.compute_dtype)
        # deterministic ~8% span masking (multiplicative hash)
        s = frames.shape[1]
        pos = jnp.arange(s, dtype=jnp.uint32)
        masked = ((pos * jnp.uint32(2654435761)) % jnp.uint32(100)) < jnp.uint32(8)
        x = frames @ ctx.cast(params["frame_proj"])
        x = jnp.where(masked[None, :, None], ctx.cast(params["mask_emb"]), x)
        # sinusoidal absolute positions (conv-pos stub)
        d = arch.d_model
        inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
        ang = pos.astype(jnp.float32)[:, None] * inv[None, :]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(x.dtype)
        x = x + pe[None]
        return ctx.constrain(x, Ax.BATCH, Ax.SEQ, None), masked

    parts = []
    if arch.n_meta_tokens:
        b = batch["tokens"].shape[0]
        meta = jnp.broadcast_to(ctx.cast(params["meta"])[None],
                                (b, arch.n_meta_tokens, arch.d_model))
        parts.append(meta)
    if arch.vit_dim:
        pe = batch["patch_embeds"].astype(ctx.compute_dtype)
        proj = jax.nn.gelu(pe @ ctx.cast(params["vit_proj"]["w1"]))
        proj = proj @ ctx.cast(params["vit_proj"]["w2"])
        parts.append(proj)
    parts.append(L.embed_lookup(batch["tokens"], params["emb"], ctx))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return ctx.constrain(x, Ax.BATCH, Ax.SEQ, None), None


def prefix_len(arch: ArchConfig) -> int:
    return arch.n_meta_tokens + (arch.n_patches if arch.vit_dim else 0)


# ---------------------------------------------------------------------------
# Bundle
# ---------------------------------------------------------------------------

@dataclass
class ModelBundle:
    arch: ArchConfig
    ctx: ShardingCtx
    decls: dict
    forward: Callable
    prefill: Callable
    loss: Callable
    make_cache_decls: Callable
    decode_step: Callable


def _layer_segments(arch: ArchConfig):
    """Homogeneous layer segments for lax.scan (same params + same block
    computation). Exceptional layers (DeepSeek first-dense, hymba global-
    attention) run as explicit python-loop segments."""
    if arch.family == "hybrid" and arch.global_attn_layers:
        segs = []
        cur = 0
        for g in sorted(arch.global_attn_layers):
            if g > cur:
                segs.append((cur, g, "scan"))
            segs.append((g, g + 1, "loop"))
            cur = g + 1
        if cur < arch.n_layers:
            segs.append((cur, arch.n_layers, "scan"))
        return segs
    if arch.moe.n_experts and arch.moe.first_k_dense:
        return [(0, arch.moe.first_k_dense, "loop"),
                (arch.moe.first_k_dense, arch.n_layers, "scan")]
    return [(0, arch.n_layers, "scan")]


def build_model(arch: ArchConfig, ctx: ShardingCtx) -> ModelBundle:
    decls = model_decls(arch)

    def _remat_wrap(blk, use_remat):
        if arch.remat and use_remat:
            policy = None
            if arch.remat_policy == "dots":
                policy = jax.checkpoint_policies.\
                    dots_with_no_batch_dims_saveable
            return jax.checkpoint(blk, policy=policy)
        return blk

    def features(params, batch, *, collect_cache=False, use_remat=True):
        """Backbone forward -> final-norm features (pre-unembed).

        ``ctx.unroll=True`` (dry-run roofline) python-unrolls every layer so
        XLA's cost analysis is exact; the default path scans homogeneous
        layer segments (compile time ~independent of depth — measured 50x
        faster on 32L)."""
        x, label_mask = _frontend(params, batch, arch, ctx)
        positions = jnp.arange(x.shape[1])
        aux_total = jnp.zeros((), jnp.float32)
        cache = {}
        if collect_cache or ctx.unroll:
            for i in range(arch.n_layers):
                p_i = params[f"layer_{i}"]
                if collect_cache:
                    x, aux, nc = _block(x, p_i, arch, i, ctx,
                                        positions=positions,
                                        collect_cache=True)
                    cache[f"layer_{i}"] = nc
                else:
                    def blk(xx, pp, _i=i):
                        xo, aux, _ = _block(xx, pp, arch, _i, ctx,
                                            positions=positions)
                        return xo, aux
                    x, aux = _remat_wrap(blk, use_remat)(x, p_i)
                aux_total = aux_total + aux
        else:
            for (lo, hi, kind) in _layer_segments(arch):
                def blk(xx, pp, _i=lo):
                    xo, aux, _ = _block(xx, pp, arch, _i, ctx,
                                        positions=positions)
                    return xo, aux
                blk = _remat_wrap(blk, use_remat)
                if kind == "loop" or hi - lo == 1:
                    for i in range(lo, hi):
                        x, aux = blk(x, params[f"layer_{i}"])
                        aux_total = aux_total + aux
                else:
                    stacked = jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[params[f"layer_{i}"] for i in range(lo, hi)])

                    def body(carry, p_i):
                        xx, aa = carry
                        xo, a = blk(xx, p_i)
                        return (xo, aa + a), None

                    (x, aux_total), _ = jax.lax.scan(
                        body, (x, aux_total), stacked)
        x = L.rmsnorm(x, params["ln_f"], arch.norm_eps)
        return x, aux_total, label_mask, cache

    def forward(params, batch):
        x, aux_total, label_mask, _ = features(params, batch, use_remat=False)
        if arch.tie_embeddings:
            logits = L.unembed(x, params["emb"], ctx, real_vocab=arch.vocab)
        else:
            logits = ctx.constrain(x @ ctx.cast(params["head"]),
                                   Ax.BATCH, None, Ax.VOCAB_ACT)
            logits = L.mask_vocab_pad(logits, arch.vocab)
        return logits, aux_total, label_mask

    def prefill(params, batch):
        """Serving prefill: last-token logits + populated decode cache."""
        x, _, _, cache = features(params, batch, collect_cache=True,
                                  use_remat=False)
        last = x[:, -1:]
        if arch.tie_embeddings:
            logits = L.unembed(last, params["emb"], ctx, real_vocab=arch.vocab)
        else:
            logits = L.mask_vocab_pad(last @ ctx.cast(params["head"]),
                                      arch.vocab)
        if arch.is_encoder_only:
            # encoder: the "served" artifact is the full frame logits
            logits = ctx.constrain(x @ ctx.cast(params["head"]),
                                   Ax.BATCH, Ax.SEQ, None)
            logits = L.mask_vocab_pad(logits, arch.vocab)
            return logits, {}
        return logits, cache

    def loss(params, batch):
        x, aux, label_mask, _ = features(params, batch)
        pl = prefix_len(arch)
        if pl:
            x = x[:, pl:]
        labels = batch["labels"]
        mask = None
        if arch.family == "audio":
            mask = label_mask[None].astype(jnp.float32) * jnp.ones(
                labels.shape, jnp.float32)
        emb_or_head = params["emb"] if arch.tie_embeddings else params["head"]
        l = L.lm_loss_chunked(x, emb_or_head, labels, ctx,
                              tied=arch.tie_embeddings, mask=mask,
                              real_vocab=arch.vocab)
        return l + AUX_LOSS_W * aux

    def make_cache_decls(batch_size: int, max_len: int):
        assert not arch.is_encoder_only, "encoder-only arch has no decode"
        cache = {}
        for i in range(arch.n_layers):
            entry = {}
            if arch.n_heads:
                entry["kv"] = A.cache_decls(arch, batch_size, max_len,
                                            jnp.dtype(ctx.compute_dtype))
            if arch.family in ("ssm", "hybrid"):
                entry["ssm"] = S.ssm_state_decls(arch, batch_size)
            cache[f"layer_{i}"] = entry
        return cache

    def decode_step(params, cache, token, t):
        """token: [b, 1] int32; t: scalar position. -> (logits, new_cache)."""
        x = L.embed_lookup(token, params["emb"], ctx)
        x = ctx.constrain(x, Ax.BATCH, None, None)
        positions = jnp.full((1,), t, jnp.int32)
        new_cache = {}
        for i in range(arch.n_layers):
            x, _, nc = _block(x, params[f"layer_{i}"], arch, i, ctx,
                              positions=positions,
                              cache=cache[f"layer_{i}"], t=t)
            new_cache[f"layer_{i}"] = nc
        x = L.rmsnorm(x, params["ln_f"], arch.norm_eps)
        if arch.tie_embeddings:
            logits = L.unembed(x, params["emb"], ctx, real_vocab=arch.vocab)
        else:
            logits = L.mask_vocab_pad(x @ ctx.cast(params["head"]), arch.vocab)
        return logits, new_cache

    bundle = ModelBundle(arch=arch, ctx=ctx, decls=decls, forward=forward,
                         prefill=prefill, loss=loss,
                         make_cache_decls=make_cache_decls,
                         decode_step=decode_step)
    bundle._features = features   # backbone features (used by plasticity)
    return bundle


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx) -> dict:
    """Abstract inputs for every model input of the given shape cell."""
    B, S = shape.global_batch, shape.seq_len
    pl = prefix_len(arch)
    if shape.kind in ("train", "prefill"):
        if arch.family == "audio":
            specs = dict(
                frames=jax.ShapeDtypeStruct((B, S, arch.frame_dim), jnp.float32),
                labels=jax.ShapeDtypeStruct((B, S), jnp.int32),
            )
        elif arch.vit_dim:
            specs = dict(
                tokens=jax.ShapeDtypeStruct((B, S - pl), jnp.int32),
                patch_embeds=jax.ShapeDtypeStruct(
                    (B, arch.n_patches, arch.vit_dim), jnp.float32),
                labels=jax.ShapeDtypeStruct((B, S - pl), jnp.int32),
            )
        else:
            specs = dict(
                tokens=jax.ShapeDtypeStruct((B, S - pl), jnp.int32),
                labels=jax.ShapeDtypeStruct((B, S - pl), jnp.int32),
            )
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs
    # decode
    return dict(token=jax.ShapeDtypeStruct((B, 1), jnp.int32))


def input_shardings(arch: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx) -> dict:
    specs = input_specs(arch, shape, ctx)
    out = {}
    for k, v in specs.items():
        axes = (Ax.BATCH,) + (None,) * (v.ndim - 1)
        out[k] = ctx.act_sharding(axes, v.shape)
    return out
