"""Training driver.

Examples (CPU-sized):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 30
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --trainer hybrid --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch bss2 --steps 300

On a real pod, drop --smoke and pass --shape train_4k: the same driver
builds the production mesh and shards per DESIGN.md §4.
"""
import argparse
import dataclasses

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU)")
    ap.add_argument("--trainer", choices=["adamw", "hybrid"], default="adamw")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-bits", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    args = ap.parse_args()

    from repro.config import SHAPES, MeshConfig, get_arch
    from repro.parallel.sharding import ShardingCtx

    arch = get_arch(args.arch)
    if args.arch == "bss2":
        from repro.core.hybrid import run_training
        out, state, meta = run_training(n_trials=args.steps, seed=args.seed)
        import numpy as np
        mr = out["mean_reward"]
        print(f"final median <R> = {np.median(mr[-1]):.3f}")
        return

    shape = SHAPES[args.shape]
    if args.smoke:
        arch = arch.reduced()
        shape = shape.reduced()

    ctx = ShardingCtx()
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        multi = args.mesh == "multi"
        ctx = ShardingCtx(mesh=make_production_mesh(multi_pod=multi),
                          mesh_cfg=MeshConfig(multi_pod=multi))

    if args.trainer == "hybrid":
        from repro.data.pipeline import SyntheticLMPipeline
        from repro.parallel.sharding import init_params
        from repro.plasticity.three_factor import HybridReadoutTrainer
        tr = HybridReadoutTrainer(arch, ctx)
        params = init_params(tr.bundle.decls, jax.random.PRNGKey(args.seed),
                             ctx)
        pipe = SyntheticLMPipeline(arch, shape, seed=args.seed)
        st = tr.init_state(jax.random.PRNGKey(args.seed + 1))
        for i in range(args.steps):
            st, m = tr.step(params, st, pipe.next_batch())
            if i % 10 == 0:
                print(f"step {i}: reward {float(m['reward']):.4f} "
                      f"<R> {float(m['mean_r']):.4f} "
                      f"acc {float(m['acc_greedy']):.4f}", flush=True)
        return

    from repro.train.trainer import Trainer, TrainerConfig
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, seed=args.seed,
                         accum_steps=args.accum,
                         grad_compress_bits=args.compress_bits)
    trainer = Trainer(arch, shape, tcfg, ctx)
    out = trainer.train()
    print(f"done: final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
