import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements of this module — jax
locks the host device count on first backend initialization.

For every runnable cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. jits the appropriate step (train_step / prefill / decode_step) with
     explicit in/out shardings,
  3. ``.lower(**input_specs).compile()`` — ShapeDtypeStructs only, no
     allocation,
  4. prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()``
     (FLOPs/bytes for the roofline), parses collective bytes from the HLO,
  5. appends the cell record to a JSON results file (incremental, so an
     interrupted sweep resumes where it stopped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config import (ASSIGNED_ARCHS, SHAPES, MeshConfig, cell_applicable,
                          get_arch)
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import ShardingCtx, abstract_params, tree_pspecs
from repro.analysis.roofline import build_report, model_flops_for


def _scalar_sharding(mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def _probe_arch(arch, n_layers: int):
    """Shallow same-structure config for depth-probe cost extrapolation.

    Exceptional layers are preserved: the DeepSeek first-dense layer stays
    layer 0; hymba keeps 3 global-attention layers at proportional
    positions. Per-layer HLO cost is exactly linear in the homogeneous
    layer count, so two probes determine the full-depth cost."""
    import dataclasses as dc
    kw = dict(n_layers=n_layers)
    if arch.global_attn_layers:
        kw["global_attn_layers"] = tuple(sorted(
            {0, n_layers // 2, n_layers - 1}))
    return dc.replace(arch, **kw)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None, compute_dtype=jnp.bfloat16,
               arch_override=None, unroll=None):
    """Lower + compile one cell. Returns (report, compiled)."""
    from repro.models.transformer import (build_model, input_specs,
                                          input_shardings)
    from repro.train.steps import make_train_step
    from repro.train.optimizer import adamw_init_decls

    arch = arch_override or get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # single-pod cells unroll every layer for exact HLO cost analysis (the
    # roofline table is single-pod); the multi-pod pass proves the `pod`
    # axis shards and uses the production scan path (depth-independent
    # compile time).
    if unroll is None:
        unroll = not multi_pod
    ctx = ShardingCtx(mesh=mesh, mesh_cfg=mesh_cfg,
                      compute_dtype=compute_dtype, unroll=unroll,
                      overrides=overrides or {})

    if arch.family == "neuromorphic":
        from repro.core.hybrid import lower_bss2_cell
        return lower_bss2_cell(shape, ctx, mesh_cfg)

    bundle = build_model(arch, ctx)
    p_abs = abstract_params(bundle.decls)
    p_sh = tree_pspecs(bundle.decls, ctx)
    ins = input_specs(arch, shape, ctx)
    in_sh = input_shardings(arch, shape, ctx)

    with mesh:
        if shape.kind == "train":
            accum = int((overrides or {}).get("accum", 1))
            step = make_train_step(bundle, accum_steps=accum)
            opt_decls = adamw_init_decls(bundle.decls)
            o_abs = abstract_params(opt_decls)
            o_sh = tree_pspecs(opt_decls, ctx)
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, in_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_abs, o_abs, ins)
        elif shape.kind == "prefill":
            if ctx.unroll:
                fn = jax.jit(bundle.prefill, in_shardings=(p_sh, in_sh))
            else:
                # scan-path full-depth proof: backbone at real depth + last
                # logits; KV-cache emission costs are measured exactly by
                # the unrolled (shallow) probes and extrapolated linearly.
                def prefill_proof(params, batch):
                    import jax.numpy as _jnp
                    from repro.models import layers as _L
                    x, _, _, _ = bundle._features(params, batch,
                                                  use_remat=False)
                    last = x[:, -1:]
                    if arch.tie_embeddings:
                        return _L.unembed(last, params["emb"], ctx,
                                          real_vocab=arch.vocab)
                    return _L.mask_vocab_pad(
                        last @ ctx.cast(params["head"]), arch.vocab)
                fn = jax.jit(prefill_proof, in_shardings=(p_sh, in_sh))
            lowered = fn.lower(p_abs, ins)
        else:  # decode
            cache_decls = bundle.make_cache_decls(shape.global_batch,
                                                  shape.seq_len)
            c_abs = abstract_params(cache_decls)
            c_sh = tree_pspecs(cache_decls, ctx)
            t_abs = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(bundle.decode_step,
                         in_shardings=(p_sh, c_sh, in_sh["token"],
                                       _scalar_sharding(mesh)),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(p_abs, c_abs, ins["token"], t_abs)
        compiled = lowered.compile()

    mesh_name = "2x16x16" if multi_pod else "16x16"
    report = build_report(arch, shape, mesh_name, mesh_cfg.n_devices, compiled)
    return report, compiled


def lower_cell_probed(arch_name: str, shape_name: str, multi_pod: bool,
                      overrides: dict | None = None, n1: int = 4,
                      n2: int = 8):
    """Depth-probe cost extrapolation for deep models whose fully-unrolled
    HLO is impractical to compile on this 1-core container.

    Per-layer HLO cost is exactly linear in the homogeneous layer count, so
    two shallow *unrolled* probes (n1, n2 layers, exceptional layers
    preserved) determine the full-depth cost:
        val(L) = val(n2) + (L - n2) * (val(n2) - val(n1)) / (n2 - n1).
    The full-depth model is additionally compiled via the production scan
    path, which proves sharding + memory at real depth (memory_analysis of
    that executable is reported).
    """
    import dataclasses as dc
    arch = get_arch(arch_name)
    L = arch.n_layers
    if arch.global_attn_layers:
        n1 = max(n1, len(arch.global_attn_layers) + 2)
        n2 = max(n2, n1 + 4)
    if arch.moe.first_k_dense:
        n1 = max(n1, arch.moe.first_k_dense + 2)
        n2 = max(n2, n1 + 4)

    r1, _ = lower_cell(arch_name, shape_name, multi_pod, overrides,
                       arch_override=_probe_arch(arch, n1), unroll=True)
    r2, _ = lower_cell(arch_name, shape_name, multi_pod, overrides,
                       arch_override=_probe_arch(arch, n2), unroll=True)
    rf, compiled_full = lower_cell(arch_name, shape_name, multi_pod,
                                   overrides, unroll=False)

    def lerp(a, b):
        return b + (L - n2) * (b - a) / (n2 - n1)

    coll = {}
    kinds = set(r1.coll) | set(r2.coll)
    for k in kinds:
        c1 = r1.coll.get(k, dict(count=0, bytes=0.0))
        c2 = r2.coll.get(k, dict(count=0, bytes=0.0))
        coll[k] = dict(count=max(0.0, lerp(c1["count"], c2["count"])),
                       bytes=max(0.0, lerp(c1["bytes"], c2["bytes"])))
    from repro.analysis.roofline import RooflineReport, collective_seconds, \
        model_flops_for
    hbm_kind = {k: max(0.0, lerp(r1.hbm_by_kind.get(k, 0.0),
                                 r2.hbm_by_kind.get(k, 0.0)))
                for k in set(r1.hbm_by_kind) | set(r2.hbm_by_kind)}
    rep = RooflineReport(
        arch=rf.arch, shape=rf.shape, mesh=rf.mesh,
        flops_per_dev=lerp(r1.flops_per_dev, r2.flops_per_dev),
        bytes_per_dev=lerp(r1.bytes_per_dev, r2.bytes_per_dev),
        hbm_bytes_per_dev=lerp(r1.hbm_bytes_per_dev, r2.hbm_bytes_per_dev),
        hbm_by_kind=hbm_kind,
        transcendentals=lerp(r1.transcendentals, r2.transcendentals),
        coll=coll, coll_sec=collective_seconds(coll),
        temp_bytes=rf.temp_bytes, arg_bytes=rf.arg_bytes,
        out_bytes=rf.out_bytes,
        model_flops_global=rf.model_flops_global,
        n_devices=rf.n_devices, step_kind=rf.step_kind)
    rep.depth_probe = (n1, n2)  # type: ignore[attr-defined]
    return rep, compiled_full


def _needs_probe(arch, shape) -> bool:
    """Unrolled-compile budget heuristic (measured: 48L MoE train >17 min,
    phi4 32L prefill_32k 652 s)."""
    if shape.kind not in ("train", "prefill"):
        return False
    if arch.moe.n_experts:
        return True
    if arch.n_layers >= 48:
        return True
    if arch.family in ("hybrid", "ssm"):
        return True
    return False


def run_cell(arch_name, shape_name, multi_pod, out_records, verbose=True,
             overrides=None):
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(arch, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    key = f"{arch_name}/{shape_name}/{mesh_name}"
    if not ok:
        rec = dict(arch=arch_name, shape=shape_name, mesh=mesh_name,
                   status="SKIP", reason=reason,
                   model_flops_global=model_flops_for(arch, shape))
        out_records[key] = rec
        if verbose:
            print(f"[SKIP] {key}: {reason}", flush=True)
        return rec
    t0 = time.time()
    try:
        probed = (not multi_pod) and _needs_probe(arch, shape)
        if probed:
            report, compiled = lower_cell_probed(arch_name, shape_name,
                                                 multi_pod,
                                                 overrides=overrides)
        else:
            report, compiled = lower_cell(arch_name, shape_name, multi_pod,
                                          overrides=overrides)
        ma = compiled.memory_analysis()
        rec = dict(status="OK", compile_s=round(time.time() - t0, 1),
                   depth_probe=getattr(report, "depth_probe", None),
                   **report.to_dict())
        if verbose:
            print(f"[OK]  {key}: compile {rec['compile_s']}s "
                  f"flops/dev {report.flops_per_dev/1e9:.1f}G "
                  f"hbm/dev {report.hbm_bytes_per_dev/1e9:.2f}G "
                  f"(raw {report.bytes_per_dev/1e9:.0f}G) "
                  f"coll {report.coll_sec['bytes_simple']/1e6:.1f}MB "
                  f"temp {ma.temp_size_in_bytes/2**30:.2f}GiB "
                  f"bottleneck={report.bottleneck} "
                  f"MFU@roofline={report.mfu:.2%}", flush=True)
            print(f"      memory_analysis: arg={ma.argument_size_in_bytes/2**30:.2f}GiB "
                  f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
                  f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB", flush=True)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec = dict(arch=arch_name, shape=shape_name, mesh=mesh_name,
                   status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {key}: {rec['error']}", flush=True)
    out_records[key] = rec
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--include-bss2", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="hillclimb knobs, e.g. --override moe_impl=gspmd")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    if args.include_bss2 and "bss2" not in archs:
        archs.append("bss2")
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = {}
    if out_path.exists():
        records = json.loads(out_path.read_text())

    for multi_pod in pods:
        for a in archs:
            for s in shapes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                key = f"{a}/{s}/{mesh_name}"
                if args.skip_existing and records.get(key, {}).get("status") == "OK":
                    print(f"[CACHED] {key}", flush=True)
                    continue
                run_cell(a, s, multi_pod, records, overrides=overrides)
                out_path.write_text(json.dumps(records, indent=1))

    n_ok = sum(1 for r in records.values() if r["status"] == "OK")
    n_skip = sum(1 for r in records.values() if r["status"] == "SKIP")
    n_fail = sum(1 for r in records.values() if r["status"] == "FAIL")
    print(f"\ndry-run complete: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"-> {out_path}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
