"""Serving driver: batched generation against a (random- or checkpoint-
initialized) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch 4 --new 16
"""
import argparse

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.config import get_arch
    from repro.parallel.sharding import ShardingCtx, init_params
    from repro.serve.engine import ServeEngine

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.reduced()
    ctx = ShardingCtx()
    eng = ServeEngine(arch, ctx, max_len=args.prompt_len + args.new + 8)
    if args.ckpt_dir:
        from repro.checkpoint import restore_checkpoint
        _, state = restore_checkpoint(args.ckpt_dir)
        params = jax.tree.map(jnp.asarray, state["params"])
    else:
        params = init_params(eng.bundle.decls, jax.random.PRNGKey(0), ctx)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 max(arch.vocab, 2), jnp.int32)
    import time
    t0 = time.perf_counter()
    out = eng.generate(params, prompts, n_new=args.new,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    print(out)
    print(f"{args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
