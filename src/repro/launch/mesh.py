"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS`` before the first jax device query.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types landed after this container's jax; Auto is the default
    # behaviour there anyway, so omit the kwarg when unavailable
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e-class pod) or 2x16x16 (two pods).

    Axes: ``data`` carries batch + FSDP; ``model`` carries TP/CP/EP/vocab;
    ``pod`` (multi-pod only) carries pure data parallelism so the only
    inter-pod collective is the per-step gradient all-reduce.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for multi-device unit tests (needs host-device override)."""
    return _make_mesh(shape, axes)
