"""One telemetered training run -> a structured run report.

The commissioning loop the paper's verification methods feed ("From Clean
Room to Machine Room") starts from exactly this artifact: a short §5
training with the jit-safe counter pytree enabled, a phase-timing split
of one emulation window, the specializer-cache stats — merged with config
and git provenance into JSON + markdown under ``results/``.

Run:  PYTHONPATH=src python examples/telemetry_report.py \
          [--trials N] [--json PATH] [--md PATH] [--rule vm|python]

The tier-2 CI observability job runs this as its smoke test and uploads
the JSON report as a build artifact.
"""
import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=30)
    ap.add_argument("--rule", default="vm", choices=("vm", "python"),
                    help="plasticity implementation (vm exercises the "
                         "PPU-VM counters and the specializer cache)")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--md", default=None, metavar="PATH")
    args = ap.parse_args()

    import jax
    from repro.core.hybrid import run_training
    from repro.obs import report as obs_report
    from repro.obs.timing import CacheDelta, profile_phases

    # --- the run, counters ON, cache delta captured ----------------------
    with CacheDelta(warn=False) as cd:
        out, state, meta = run_training(n_trials=args.trials, seed=0,
                                        rule_impl=args.rule,
                                        telemetry=True)
    tele = out["telemetry"]
    mr = float(np.median(out["mean_reward"][-1]))

    # --- phase attribution of one emulation window -----------------------
    core = meta["core"]
    ecfg = meta["ecfg"]
    rng = np.random.default_rng(0)
    ev = (rng.random((ecfg.trial_steps, core.cfg.n_rows)) < 0.02
          ).astype(np.float32)
    ad = np.zeros((ecfg.trial_steps, core.cfg.n_rows), np.int8)
    phases = profile_phases(core, core.init_state(), ev, ad, iters=3)

    # --- merge + persist -------------------------------------------------
    rep = obs_report.build_report(
        "telemetry_demo", telemetry=tele, timings=phases,
        cache=dict(cd.delta),
        config=dict(n_trials=args.trials, rule_impl=args.rule,
                    jax_devices=len(jax.devices())),
        extra=dict(median_reward_final=mr))
    out_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "results")
    json_path = args.json or os.path.join(out_dir,
                                          "REPORT_telemetry_demo.json")
    paths = obs_report.write_report(rep, json_path, args.md)
    print(obs_report.to_markdown(rep))
    print(f"wrote {paths['json']} and {paths['md']}")

    # the acceptance invariant, asserted so CI fails loudly: a telemetered
    # run reports real activity
    assert tele["out_spikes"] > 0 and tele["steps"] > 0
    assert tele["trials"] == args.trials
    if args.rule == "vm":
        assert tele["vm_runs"] == args.trials
    return paths


if __name__ == "__main__":
    main()
