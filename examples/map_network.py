"""Map an arbitrary 300x700 network onto 4 chips and train it.

The network is bigger than one native 256x512 chip in both directions,
so it cannot run monolithically on real hardware at all — the mapper
(docs/mapper.md) partitions the 700 neurons over 4 chips, allocates
driver rows per chip, assigns the 6-bit address schedule, and emits a
validated WaferPlan. Training is the paper's hardware-in-the-loop
shape: emulate on the mapped chips, read spikes back, update the
*network description* on the host, re-map, repeat — the placement is
fixed after the first epoch, so re-mapping is a cheap host-side
re-emission of the weight blocks.

Run:  PYTHONPATH=src python examples/map_network.py
"""
import numpy as np

from repro import mapper

N_IN, N_NEURONS, K = 300, 700, 4
EPOCHS, W, T = 6, 2, 48
rng = np.random.default_rng(0)

# --- an arbitrary signed network beyond the native fabric -------------------
# locality-structured feedforward (each input drives a neighborhood) plus
# sparse inhibitory recurrence — the shape the mapper is for
w_in = np.zeros((N_IN, N_NEURONS), np.int32)
for i in range(N_IN):
    w_in[i, (2 * i) % N_NEURONS] = 30
    w_in[i, (2 * i + 1) % N_NEURONS] = 20
w_rec = np.zeros((N_NEURONS, N_NEURONS), np.int32)
for j in range(0, N_NEURONS, 2):
    w_rec[j, (j + 1) % N_NEURONS] = -15

# two input patterns; training goal: pattern A drives the low half of the
# neurons harder than pattern B does (a linear-separation toy objective)
pat_a = rng.permutation(N_IN)[:60]
pat_b = rng.permutation(N_IN)[:60]
low = np.arange(N_NEURONS) < N_NEURONS // 2


def events_for(pattern):
    ev = np.zeros((W, T, N_IN), np.float32)
    ev[:, ::4, :] = 0.0
    ev[:, ::3][:, :, pattern] = 1.0          # drive the pattern rows
    noise = rng.random((W, T, N_IN)) < 0.01  # background
    return np.maximum(ev, noise.astype(np.float32))


def separation(rt):
    """<low-half spikes | A> - <low-half spikes | B> on the mapped run."""
    _, out_a = rt.run(events_for(pat_a))
    _, out_b = rt.run(events_for(pat_b))
    ra = np.asarray(out_a["spikes"])[..., low].sum()
    rb = np.asarray(out_b["spikes"])[..., low].sum()
    return float(ra - rb)


spec = mapper.NetworkSpec(n_in=N_IN, n_neurons=N_NEURONS,
                          w_in=w_in, w_rec=w_rec, name="demo-300x700")
m = mapper.map_network(spec, n_chips=K)      # native 256x512 chips
print(f"mapped {spec.n_sources} sources x {N_NEURONS} neurons onto "
      f"{K} chips: {int((m.row_source >= 0).sum())} driver rows, "
      f"{m.n_relayed_edges} relayed edges, {m.n_transit_rows} transit rows")

net_inst = None
history = []
for epoch in range(EPOCHS):
    rt = mapper.build_runtime(m, net_inst=net_inst)
    net_inst = rt.net_inst                   # sample mismatch once, reuse
    history.append(separation(rt))
    # host update: reward-modulated Hebb — strengthen A-pattern inputs
    # into the low half, weaken B-pattern ones (6-bit saturating, Dale-
    # sign preserving), then re-emit the weight blocks for the SAME
    # placement
    dw = np.zeros_like(w_in)
    dw[np.ix_(pat_a, low)] += 4
    dw[np.ix_(pat_b, low)] -= 4
    w_in = np.clip(w_in + dw, 0, mapper.WMAX)   # input rows are excitatory
    spec = mapper.NetworkSpec(n_in=N_IN, n_neurons=N_NEURONS,
                              w_in=w_in, w_rec=w_rec, name=spec.name)
    m = mapper.map_network(spec, n_chips=K)

print("separation per epoch:", [f"{s:.0f}" for s in history])
assert history[-1] > history[0], \
    "training must improve the separation objective (a silent run proves " \
    "nothing)"
print("map_network OK")
