"""Quickstart: the three layers of the framework in ~60 lines.

  1. the BSS-2 machine model (paper's C1): emulate a spiking network,
  2. the PPU hybrid-plasticity step (R-STDP, Eqs. 2-3),
  3. an assigned LM architecture through the same config system.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# --- 1. emulate the analog core -------------------------------------------
import dataclasses
from repro.configs.bss2 import BSS2
from repro.core.anncore import AnnCore
from repro.verif.mismatch import sample_instance

cfg = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)
inst = sample_instance(cfg, jax.random.PRNGKey(0))   # a virtual chip
core = AnnCore(cfg, inst)
state = core.init_state()
state = state._replace(syn=state.syn._replace(
    weights=jnp.full((16, 16), 45, jnp.int8)))

T = 400
events = (jax.random.uniform(jax.random.PRNGKey(1), (T, 16)) < 0.02
          ).astype(jnp.float32)
addrs = jnp.zeros((T, 16), jnp.int8)
state, out = jax.jit(core.run)(state, events, addrs)
print(f"[1] anncore: {int(out['spikes'].sum())} output spikes from "
      f"{int(events.sum())} input events over {T * cfg.dt:.0f} us model time")

# --- 2. hybrid plasticity (paper §5, fused on device) -----------------------
from repro.core.hybrid import run_training

res, _, meta = run_training(n_trials=300, seed=0)
mr = res["mean_reward"]
print(f"[2] R-STDP: median <R> after {mr.shape[0]} trials = "
      f"{float(np.median(mr[-1])):.2f} (paper Fig. 11: -> ~1)")

# --- 3. an assigned LM arch through the same stack --------------------------
from repro.config import ShapeConfig, get_arch
from repro.models.transformer import build_model
from repro.parallel.sharding import ShardingCtx, init_params
from repro.data.pipeline import SyntheticLMPipeline

arch = get_arch("smollm-360m").reduced()
ctx = ShardingCtx()
bundle = build_model(arch, ctx)
params = init_params(bundle.decls, jax.random.PRNGKey(0))
pipe = SyntheticLMPipeline(arch, ShapeConfig("s", 32, 2, "train"))
loss = jax.jit(bundle.loss)(params, pipe.next_batch())
print(f"[3] {arch.name} (reduced): initial LM loss {float(loss):.3f} "
      f"(ln V = {np.log(arch.vocab):.3f})")
print("quickstart OK")
