"""End-to-end LM training driver example.

Default: a fast CPU-sized run (reduced smollm, 60 steps) demonstrating the
full loop — data pipeline, AdamW, checkpoint/restart, loss decreasing.

--full trains the real smollm-360m config (~360M params) for a few hundred
steps; on the production mesh that is `--mesh single`, on this CPU
container expect ~minutes per step.

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse
import tempfile

from repro.config import ShapeConfig, get_arch
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.full:
        shape = ShapeConfig("train_small", 512, 8, "train")
        steps = args.steps or 300
    else:
        arch = arch.reduced()
        shape = ShapeConfig("smoke", 64, 8, "train")
        steps = args.steps or 60

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    tcfg = TrainerConfig(steps=steps, ckpt_every=max(steps // 4, 10),
                         ckpt_dir=ckpt_dir, log_every=max(steps // 15, 1),
                         opt=AdamWConfig(lr=1e-3, warmup_steps=20))
    print(f"training {arch.name} ({arch.param_count()/1e6:.1f}M params) "
          f"for {steps} steps, batch {shape.global_batch} x {shape.seq_len}")
    tr = Trainer(arch, shape, tcfg)
    out = tr.train()
    losses = [h["loss"] for h in out["history"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({(1 - losses[-1]/losses[0]):.0%} reduction)")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
