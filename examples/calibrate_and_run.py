"""Pre-tapeout calibration workflow (paper §3.2.2): sample virtual chip
instances, calibrate the STP offsets by binary search, then show that the
calibrated machine behaves uniformly across instances.

Run:  PYTHONPATH=src python examples/calibrate_and_run.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2
from repro.core.hybrid import RSTDPConfig, make_experiment
from repro.verif.calibration import calibrate_stp
from repro.verif.mismatch import sample_instance


def main():
    # 1. virtual instances (fixed seed = same "silicon" every run)
    cfg = dataclasses.replace(BSS2.reduced(), n_rows=32, n_cols=16)
    inst = sample_instance(cfg, jax.random.PRNGKey(7))

    # 2. pre-tapeout calibration of the STP efficacy offsets
    codes, metrics = calibrate_stp(cfg, inst["stp_offset"])
    print(f"STP offsets: std {float(metrics['std_before']):.3f} -> "
          f"{float(metrics['std_after']):.3f} after 4-bit binary search")

    # 3. run the hybrid-plasticity experiment on the CALIBRATED instance
    inst_cal = dict(inst, stp_calib=codes)
    ecfg = RSTDPConfig()
    init, trial, meta = make_experiment(cfg=cfg, ecfg=ecfg)
    # (make_experiment samples its own instance; here we just demonstrate
    # the calibrated efficacies feeding the machine)
    from repro.core import stp
    eff_uncal = stp.efficacy(stp.init_state((32,)), jnp.ones(32),
                             u=cfg.stp_u, offset=inst["stp_offset"],
                             calib_code=inst["stp_calib"])
    eff_cal = stp.efficacy(stp.init_state((32,)), jnp.ones(32),
                           u=cfg.stp_u, offset=inst["stp_offset"],
                           calib_code=codes)
    print(f"first-pulse efficacy spread across drivers: "
          f"{float(jnp.std(eff_uncal)):.4f} uncalibrated vs "
          f"{float(jnp.std(eff_cal)):.4f} calibrated")
    assert float(jnp.std(eff_cal)) < float(jnp.std(eff_uncal))
    print("calibrated machine ready — see examples/rstdp_pattern.py for the "
          "learning experiment")


if __name__ == "__main__":
    main()
