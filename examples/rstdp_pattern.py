"""End-to-end reproduction of the paper's §5 experiment (Fig. 10/11).

16 Poisson input channels; patterns A and B on 5 channels each (40%
overlap); even neurons are rewarded for firing on A, odd neurons on B; the
R-STDP rule (Eqs. 2-3) runs on the PPU against the analog correlation
sensors — everything fused in one jitted on-device step.

Run:  PYTHONPATH=src python examples/rstdp_pattern.py [n_trials]
"""
import sys

import numpy as np

from repro.core.hybrid import RSTDPConfig, run_training


def ascii_plot(series, width=64, height=10, lo=0.0, hi=1.0):
    xs = np.linspace(0, len(series) - 1, width).astype(int)
    ys = np.asarray(series)[xs]
    rows = []
    for h in range(height, -1, -1):
        thr = lo + (hi - lo) * h / height
        rows.append("".join("#" if y >= thr else " " for y in ys))
    return "\n".join(f"{lo + (hi-lo)*(height-i)/height:4.2f} |{r}"
                     for i, r in enumerate(rows))


def main():
    n_trials = int(sys.argv[1]) if len(sys.argv) > 1 else 450
    ecfg = RSTDPConfig(overlap=0.4)
    print(f"training {n_trials} trials, overlap={ecfg.overlap:.0%} ...")
    out, state, meta = run_training(n_trials=n_trials, ecfg=ecfg, seed=0)
    even = np.asarray(meta["even"]) > 0
    mr = out["mean_reward"]
    med_all = np.median(mr, axis=1)
    print("\nmedian mean-expected-reward over training (paper Fig. 11 B):")
    print(ascii_plot(med_all))
    print(f"\nfinal: A-pop {np.median(mr[-1, even]):.3f}  "
          f"B-pop {np.median(mr[-1, ~even]):.3f}")

    w = out["w_signed_final"]
    ma = np.asarray(meta["mask_a"]) > 0
    mb = np.asarray(meta["mask_b"]) > 0
    print("\nlearned signed weights (paper Fig. 11 A analogue):")
    print(f"  A-channels -> even neurons: {w[ma][:, even].mean():+6.1f}")
    print(f"  A-channels -> odd  neurons: {w[ma][:, ~even].mean():+6.1f}")
    print(f"  B-channels -> even neurons: {w[mb][:, even].mean():+6.1f}")
    print(f"  B-channels -> odd  neurons: {w[mb][:, ~even].mean():+6.1f}")
    print(f"  background -> any         : "
          f"{w[~(ma | mb)].mean():+6.1f}")


if __name__ == "__main__":
    main()
