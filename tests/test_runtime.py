"""Distributed-runtime substrate tests: optimizer, data pipeline,
checkpointing (incl. failure/restart), gradient compression, serving."""
import dataclasses
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig, get_arch
from repro.data.pipeline import SyntheticLMPipeline
from repro.parallel import compress as gc
from repro.train.optimizer import AdamWConfig, adamw_init_decls, adamw_update
from repro.train.trainer import SimulatedFailure, Trainer, TrainerConfig
from repro.parallel.sharding import abstract_params, init_params

SHAPE = ShapeConfig("smoke", 32, 4, "train")
ARCH = get_arch("smollm-360m").reduced()


class TestOptimizer:
    def test_adamw_minimizes_quadratic(self):
        from repro.parallel.sharding import ParamDecl
        decls = dict(x=ParamDecl((8,), (None,), init="normal"))
        params = init_params(decls, jax.random.PRNGKey(0))
        opt = init_params(adamw_init_decls(decls), jax.random.PRNGKey(1))
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
        target = jnp.arange(8.0)

        @jax.jit
        def step(p, o):
            loss, g = jax.value_and_grad(
                lambda q: jnp.sum((q["x"] - target) ** 2))(p)
            p, o, _ = adamw_update(p, g, o, cfg)
            return p, o, loss

        losses = []
        for _ in range(200):
            params, opt, l = step(params, opt)
            losses.append(float(l))
        assert losses[-1] < 1e-2 * losses[0]

    def test_grad_clip_bounds_update(self):
        from repro.parallel.sharding import ParamDecl
        decls = dict(x=ParamDecl((4,), (None,), init="zeros"))
        params = init_params(decls, jax.random.PRNGKey(0))
        opt = init_params(adamw_init_decls(decls), jax.random.PRNGKey(1))
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=1,
                          weight_decay=0.0)
        g = dict(x=jnp.full((4,), 1e6))
        p2, o2, m = adamw_update(params, g, opt, cfg)
        assert float(m["grad_norm"]) > 1e5
        assert np.all(np.abs(np.asarray(p2["x"])) < 1.5)


class TestPipeline:
    def test_deterministic_and_resumable(self):
        p1 = SyntheticLMPipeline(ARCH, SHAPE, seed=3)
        b1 = [p1.next_batch() for _ in range(3)]
        p2 = SyntheticLMPipeline(ARCH, SHAPE, seed=3)
        p2.load_state_dict(dict(seed=np.int64(3), step=np.int64(2)))
        b2 = p2.next_batch()
        np.testing.assert_array_equal(np.asarray(b1[2]["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_shards_disjoint_cursor_consistent(self):
        a = SyntheticLMPipeline(ARCH, SHAPE, seed=1, shard_index=0,
                                num_shards=2)
        b = SyntheticLMPipeline(ARCH, SHAPE, seed=1, shard_index=1,
                                num_shards=2)
        ba, bb = a.next_batch(), b.next_batch()
        assert ba["tokens"].shape[0] == SHAPE.global_batch // 2
        assert not np.array_equal(np.asarray(ba["tokens"]),
                                  np.asarray(bb["tokens"]))

    def test_learnable_structure(self):
        """Markov structure => bigram MI > 0 (a model can learn it)."""
        p = SyntheticLMPipeline(ARCH, SHAPE, seed=0)
        toks = np.asarray(p.next_batch()["tokens"]).ravel()
        # crude check: adjacent-token distribution is not independent
        from collections import Counter
        pairs = Counter(zip(toks[:-1], toks[1:]))
        uni = Counter(toks)
        n = len(toks) - 1
        mi = 0.0
        for (x, y), c in pairs.items():
            pxy = c / n
            mi += pxy * np.log(pxy / (uni[x] / n * uni[y] / n) + 1e-12)
        assert mi > 0.1, mi


class TestCompression:
    def test_error_feedback_recovers_signal(self):
        """EF quantization: the running SUM of compressed grads tracks the
        running sum of true grads (residual stays bounded)."""
        key = jax.random.PRNGKey(0)
        err = dict(g=jnp.zeros((64,)))
        total_true = np.zeros(64)
        total_comp = np.zeros(64)
        for i in range(50):
            key, sub = jax.random.split(key)
            g = dict(g=jax.random.normal(sub, (64,)) * 0.01)
            comp, err = gc.ef_compress_grads(g, err, bits=8)
            total_true += np.asarray(g["g"])
            total_comp += np.asarray(comp["g"])
        resid = np.abs(total_true - total_comp).max()
        # residual bounded by one quantization step, NOT growing with steps
        assert resid < 0.01, resid

    def test_compress_roundtrip_accuracy(self):
        g = jax.random.normal(jax.random.PRNGKey(1), (1024,))
        q, s = gc.compress(g, bits=8)
        back = gc.decompress(q, s)
        rel = float(jnp.max(jnp.abs(back - g)) / jnp.max(jnp.abs(g)))
        assert rel < 1.0 / 120  # half a quantization step


class TestTrainerFaultTolerance:
    def _cfg(self, d, **kw):
        return TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=d, log_every=100,
                             opt=AdamWConfig(lr=1e-3, warmup_steps=2), **kw)

    def test_loss_decreases(self):
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(ARCH, SHAPE, dataclasses.replace(
                self._cfg(d), steps=30))
            out = tr.train()
            losses = [h["loss"] for h in out["history"]]
            assert losses[-1] < losses[0], (losses[0], losses[-1])

    def test_crash_restart_continues_identically(self):
        """Run A: train 8 steps straight. Run B: crash at step 6, restart
        from the step-4 checkpoint, finish. Final params must match."""
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            tr_a = Trainer(ARCH, SHAPE, self._cfg(d1))
            out_a = tr_a.train()

            tr_b = Trainer(ARCH, SHAPE, self._cfg(d2, fail_at_step=6))
            with pytest.raises(SimulatedFailure):
                tr_b.train()
            tr_b2 = Trainer(ARCH, SHAPE, self._cfg(d2))  # fresh "node"
            out_b = tr_b2.train()

            fa = jax.tree.leaves(out_a["params"])
            fb = jax.tree.leaves(out_b["params"])
            for x, y in zip(fa, fb):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=2e-5, atol=2e-5)

    def test_grad_compression_trains(self):
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(ARCH, SHAPE, dataclasses.replace(
                self._cfg(d), steps=25, grad_compress_bits=8))
            out = tr.train()
            losses = [h["loss"] for h in out["history"]]
            assert losses[-1] < losses[0]

    def test_accum_matches_full_batch(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            cfg1 = dataclasses.replace(self._cfg(d1), steps=3)
            cfg2 = dataclasses.replace(self._cfg(d2), steps=3, accum_steps=2)
            o1 = Trainer(ARCH, SHAPE, cfg1).train(resume=False)
            o2 = Trainer(ARCH, SHAPE, cfg2).train(resume=False)
            l1 = [h["loss"] for h in o1["history"]]
            l2 = [h["loss"] for h in o2["history"]]
            np.testing.assert_allclose(l1, l2, rtol=2e-3)


class TestServe:
    def test_generate_shapes_and_determinism(self):
        from repro.serve.engine import ServeEngine
        eng = ServeEngine(ARCH, max_len=64)
        params = init_params(eng.bundle.decls, jax.random.PRNGKey(0))
        prompts = jnp.ones((2, 8), jnp.int32)
        out1 = eng.generate(params, prompts, n_new=6)
        out2 = eng.generate(params, prompts, n_new=6)
        assert out1.shape == (2, 6)
        np.testing.assert_array_equal(out1, out2)
        assert (out1 < ARCH.vocab_padded).all()

    def test_generate_rejects_kv_cache_overrun(self):
        """prompt + prefix + n_new must fit max_len — past-the-end decode
        positions would silently wrap/drop instead of erroring."""
        from repro.models.transformer import prefix_len
        from repro.serve.engine import ServeEngine
        eng = ServeEngine(ARCH, max_len=16)
        params = init_params(eng.bundle.decls, jax.random.PRNGKey(0))
        prompts = jnp.ones((1, 8), jnp.int32)
        fits = 16 - 8 - prefix_len(ARCH)
        out = eng.generate(params, prompts, n_new=fits)
        assert out.shape == (1, fits)
        with pytest.raises(ValueError, match="overruns the KV cache"):
            eng.generate(params, prompts, n_new=fits + 1)

    def test_sampling_without_key_differs_per_call(self):
        """temperature > 0 with key=None must not silently reuse one
        PRNGKey(0) forever: repeated calls draw fresh samples, while an
        explicit key stays reproducible."""
        from repro.serve.engine import ServeEngine
        eng = ServeEngine(ARCH, max_len=64)
        params = init_params(eng.bundle.decls, jax.random.PRNGKey(0))
        prompts = jnp.ones((4, 8), jnp.int32)
        outs = [eng.generate(params, prompts, n_new=8, temperature=5.0)
                for _ in range(3)]
        assert any(not np.array_equal(outs[0], o) for o in outs[1:]), \
            "key=None sampling repeated identical draws across calls"
        k = jax.random.PRNGKey(7)
        a = eng.generate(params, prompts, n_new=8, temperature=5.0, key=k)
        b = eng.generate(params, prompts, n_new=8, temperature=5.0, key=k)
        np.testing.assert_array_equal(a, b)


def test_elastic_reshard_subprocess():
    """Checkpoint written under one mesh restores under another (8 fake
    devices: (2,2) data x model -> (4,2)). Runs in a subprocess because the
    device count must be set before jax initializes."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, tempfile
import jax, jax.numpy as jnp, numpy as np
import sys
sys.path.insert(0, "src")
from repro.config import ShapeConfig, get_arch, MeshConfig
from repro.parallel.sharding import ShardingCtx, init_params, tree_pspecs
from repro.models.transformer import build_model
from repro.checkpoint import save_checkpoint, restore_checkpoint

arch = get_arch("smollm-360m").reduced()
from repro.launch.mesh import make_smoke_mesh
mesh1 = make_smoke_mesh((2, 2), ("data", "model"))
ctx1 = ShardingCtx(mesh=mesh1)
bundle = build_model(arch, ctx1)
params = init_params(bundle.decls, jax.random.PRNGKey(0), ctx1)
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 1, dict(params=params))
    mesh2 = make_smoke_mesh((4, 2), ("data", "model"))
    ctx2 = ShardingCtx(mesh=mesh2)
    sh2 = tree_pspecs(bundle.decls, ctx2)
    step, state = restore_checkpoint(d, shardings=dict(params=sh2))
    assert step == 1
    a = jax.tree.leaves(params)
    b = jax.tree.leaves(state["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # restored arrays actually live on the new mesh
    leaf = jax.tree.leaves(state["params"])[0]
    assert leaf.sharding.mesh.shape["data"] == 4
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_serve_engine_applies_decl_shardings_subprocess():
    """The engine must actually hand its ``in_shardings`` to ``jax.jit``:
    the compiled prefill/decode params shardings equal the decl pspecs
    (the regression was building the kwargs and dropping them — params
    silently resharded to whatever jit inferred). 8 fake devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.config import get_arch
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import ShardingCtx, init_params, tree_pspecs
from repro.serve.engine import ServeEngine

arch = get_arch("smollm-360m").reduced()
ctx = ShardingCtx(mesh=make_smoke_mesh((2, 2)))
eng = ServeEngine(arch, ctx, max_len=32)
params = init_params(eng.bundle.decls, jax.random.PRNGKey(0), ctx)
batch = dict(tokens=jnp.ones((2, 8), jnp.int32))

compiled = eng._prefill.lower(params, batch).compile()
got = compiled.input_shardings[0][0]          # params subtree
want = tree_pspecs(eng.bundle.decls, ctx)
flat_g, _ = jax.tree.flatten(got)
flat_w, _ = jax.tree.flatten(want)
flat_p, _ = jax.tree.flatten(params)
assert len(flat_g) == len(flat_w) > 0
for g, w, p in zip(flat_g, flat_w, flat_p):
    assert g.is_equivalent_to(w, p.ndim), (g, w, p.shape)

# the served path still runs end to end under the mesh
out = eng.generate(params, jnp.ones((2, 8), jnp.int32), n_new=3)
assert out.shape == (2, 3)
print("SERVE_SHARDINGS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SERVE_SHARDINGS_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
