"""Automatic partitioner / chip mapper contracts (``repro.mapper``).

The correctness anchor is the round-trip contract: mapping an arbitrary
network onto K chips and emulating it routed must equal the K=1
monolithic mapping of the SAME network — ``assert_array_equal``, both
batch backends, ring and all2all, with and without a blacklist. The
supporting invariants (plan validity, per-destination-row address
uniqueness, Dale row parity, ascending-source FMA order, exact spec
reconstruction) are asserted by ``ChipMapping.validate`` over a
hypothesis-generated spec corpus.
"""
import dataclasses

import jax
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro import mapper
from repro.configs.bss2 import BSS2
from repro.faults import Blacklist, FaultPlan
from repro.mapper.partition import CapacityError

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _spec(seed=0, n_in=20, n_neurons=30, fan_out=4, rec_fan_out=3,
          dale=False, rec_mask=None):
    return mapper.random_spec(np.random.default_rng(seed), n_in, n_neurons,
                              fan_out=fan_out, rec_fan_out=rec_fan_out,
                              dale=dale, rec_mask=rec_mask)


def _ring_mask(n_neurons, quarters=(1, 3)):
    """Recurrent edges allowed only from quarter q to quarter (q+1) % 4
    with q in {1, 3} — those cross a chip boundary on BOTH the K=2 and
    the K=4 contiguous partitions, so the net maps onto a ring without
    relays at K in {1, 2, 4}."""
    assert n_neurons % 4 == 0
    q = n_neurons // 4
    mask = np.zeros((n_neurons, n_neurons), bool)
    for src_q in quarters:
        dst_q = (src_q + 1) % 4
        mask[src_q * q:(src_q + 1) * q, dst_q * q:(dst_q + 1) * q] = True
    return mask


def _inputs(spec, rng, W=3, T=24, p=0.25):
    return (rng.random((W, T, spec.n_in)) < p).astype(np.float32)


def _grid_spec(n_in, n_neurons):
    """Locality-structured oversize net (the examples/map_network.py
    shape): input i excites a small neighborhood around 2i, even neurons
    inhibit their successor — per-chip row demand stays within the
    native 256-row fabric on 4 chips."""
    w_in = np.zeros((n_in, n_neurons), np.int32)
    for i in range(n_in):
        w_in[i, (2 * i) % n_neurons] = 30
        w_in[i, (2 * i + 1) % n_neurons] = 20
    w_rec = np.zeros((n_neurons, n_neurons), np.int32)
    for j in range(0, n_neurons, 2):
        w_rec[j, (j + 1) % n_neurons] = -15
    return mapper.NetworkSpec(n_in, n_neurons, w_in, w_rec, name="grid")


def _mono_out(spec, net_inst, ev, backend="fused", chip_cols=None):
    m1 = mapper.map_network(
        spec, 1, chip_rows=mapper.min_chip_rows(spec, 1, chip_cols
                                                or spec.n_neurons),
        chip_cols=chip_cols or spec.n_neurons)
    rt = mapper.build_runtime(m1, net_inst=net_inst, backend=backend)
    _, out = rt.run(ev)
    return np.asarray(out["spikes"])


class TestSpec:
    def test_validation(self):
        with pytest.raises(AssertionError, match="6-bit"):
            mapper.NetworkSpec(1, 2, np.full((1, 2), 64))
        with pytest.raises(AssertionError, match="integer"):
            mapper.NetworkSpec(1, 2, np.ones((1, 2), np.float32))
        with pytest.raises(AssertionError, match="w_rec shape"):
            mapper.NetworkSpec(1, 2, np.ones((1, 2), np.int32),
                               np.ones((1, 2), np.int32))

    def test_canonical_order_and_signs(self):
        spec = mapper.NetworkSpec(
            2, 2, w_in=np.array([[5, 0], [0, -3]]),
            w_rec=np.array([[0, 7], [-2, 4]]))
        w = spec.w_full()
        assert w.shape == (4, 2)
        assert_array_equal(w[:2], spec.w_in)   # inputs first
        assert_array_equal(spec.dale_signs(), [1, -1, 1, 0])
        assert spec.n_edges == 5


class TestPartition:
    def test_balanced_split(self):
        p = mapper.partition_columns(30, 4, 512)
        counts = np.bincount(p.col_chip, minlength=4)
        assert counts.max() - counts.min() <= 1
        # ascending neurons -> ascending (chip, slot)
        assert (np.diff(p.col_chip) >= 0).all()

    def test_blacklist_avoidance_and_shedding(self):
        bad = np.zeros((2, 8), bool)
        bad[0, :6] = True          # chip 0 keeps only 2 usable columns
        p = mapper.partition_columns(10, 2, 8, bad)
        assert not bad[p.col_chip, p.col_slot].any()
        assert (p.col_chip == 0).sum() == 2   # defective chip sheds load

    def test_capacity_error(self):
        with pytest.raises(CapacityError, match="usable columns"):
            mapper.partition_columns(17, 2, 8)


class TestMapping:
    def test_row_capacity_error_names_chip(self):
        spec = _spec(n_in=40, n_neurons=16, fan_out=8, rec_fan_out=0)
        with pytest.raises(CapacityError, match="chip 0"):
            mapper.map_network(spec, 1, chip_rows=16, chip_cols=16)

    def test_address_schedule_is_per_row_unique_per_destination(self):
        m = mapper.map_network(_spec(), 2, chip_rows=128, chip_cols=16)
        used = m.row_source >= 0
        # one 6-bit address per driver row, stored across the whole row
        assert (m.row_addr[used] < 64).all()
        for k, r in zip(*np.nonzero(used)):
            assert (m.addresses[k, r] == m.row_addr[k, r]).all()
        # every route delivers the destination row's schedule address
        # (WaferPlan.__post_init__ separately validates uniqueness)
        assert_array_equal(m.plan.addr,
                           m.row_addr[m.plan.dst_chip, m.plan.dst_row])

    def test_ring_relay_inserts_forward_rules(self):
        # an edge to a non-adjacent chip must go through a transit row +
        # fwd_* rule on the intermediate chip (PR 9 failover machinery)
        n = 16
        w_rec = np.zeros((n, n), np.int32)
        w_rec[0, 12] = 9           # chip 0 -> chip 3 is distance 3 on K=4
        spec = mapper.NetworkSpec(2, n, np.zeros((2, n), np.int32), w_rec)
        with pytest.raises(CapacityError, match="all2all"):
            mapper.map_network(spec, 4, chip_rows=8, chip_cols=4,
                               topology="ring")
        w_rec = np.zeros((n, n), np.int32)
        w_rec[0, 8] = 9            # chip 0 -> chip 2: one relay on chip 1
        spec = mapper.NetworkSpec(2, n, np.zeros((2, n), np.int32), w_rec)
        m = mapper.map_network(spec, 4, chip_rows=8, chip_cols=4,
                               topology="ring")
        assert m.n_relayed_edges == 1 and m.plan.n_forwards == 1
        assert m.n_transit_rows == 1
        # the transit row is pure transit: zero weights, sign 0
        tr = int(m.plan.fwd_src_row[0])
        tc = int(m.plan.fwd_src_chip[0])
        assert tc == 1 and m.row_sign[tc, tr] == 0
        assert (m.weights[tc, tr] == 0).all()

    def test_defect_aware_placement(self):
        K, R, C = 2, 160, 20
        rows = np.zeros((K, R), bool)
        rows[0, :10] = True
        neurons = np.zeros((K, C), bool)
        neurons[1, 5:15] = True
        bl = Blacklist(rows=rows, neurons=neurons)
        m = mapper.map_network(_spec(), K, chip_rows=R, chip_cols=C,
                               blacklist=bl)
        used_rows = m.row_source >= 0
        assert not (used_rows & rows).any()
        assert not m.part.used_mask()[neurons].any()

    if HAVE_HYP:
        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1),
               n_in=st.integers(1, 24), n_neurons=st.integers(4, 40),
               k=st.sampled_from([1, 2, 3, 4]),
               dale=st.booleans())
        def test_mapping_invariants_hypothesis(self, seed, n_in, n_neurons,
                                               k, dale):
            spec = _spec(seed, n_in=n_in, n_neurons=n_neurons, fan_out=3,
                         rec_fan_out=2, dale=dale)
            rows = mapper.min_chip_rows(spec, k, 16) + 8  # transit slack
            try:
                m = mapper.map_network(spec, k, chip_rows=rows,
                                       chip_cols=16)
            except CapacityError:
                return            # undersized fabric: rejected, not mangled
            m.validate()          # plan validity + addr uniqueness +
            #                       Dale parity + FMA order + exact
            #                       reconstruction of the spec
    else:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_mapping_invariants_hypothesis(self):
            pass


class TestExactness:
    """Partitioned-and-routed == monolithic, assert_array_equal."""

    @pytest.mark.parametrize("backend", ["fused", "blocked"])
    @pytest.mark.parametrize("k", [2, 4])
    def test_all2all_round_trip(self, k, backend):
        spec = _spec(rec_fan_out=3)
        rng = np.random.default_rng(1)
        ev = _inputs(spec, rng)
        net_inst = mapper.sample_network_instance(spec, jax.random.PRNGKey(3))
        mono = _mono_out(spec, net_inst, ev, backend=backend)
        cols = 30 // k + 2
        rows = mapper.min_chip_rows(spec, k, cols) + 8
        m = mapper.map_network(spec, k, chip_rows=rows, chip_cols=cols)
        rt = mapper.build_runtime(m, net_inst=net_inst, backend=backend)
        _, out = rt.run(ev)
        assert mono.sum() > 0, "a silent network proves nothing"
        assert_array_equal(np.asarray(out["spikes"]), mono)

    @pytest.mark.parametrize("k", [2, 4])
    def test_ring_round_trip(self, k):
        # ring has no self-links at K >= 2: use a net whose recurrent
        # edges cross a k -> k+1 boundary on every partition under test
        spec = _spec(n_in=16, n_neurons=32, rec_fan_out=3,
                     rec_mask=_ring_mask(32))
        rng = np.random.default_rng(2)
        ev = _inputs(spec, rng)
        net_inst = mapper.sample_network_instance(spec, jax.random.PRNGKey(5))
        mono = _mono_out(spec, net_inst, ev)
        m = mapper.map_network(spec, k, chip_rows=64, chip_cols=32 // k,
                               topology="ring")
        assert m.plan.n_forwards == 0, "ring-realizable: no relays"
        rt = mapper.build_runtime(m, net_inst=net_inst)
        _, out = rt.run(ev)
        assert mono.sum() > 0, "a silent network proves nothing"
        assert_array_equal(np.asarray(out["spikes"]), mono)

    def test_blacklist_round_trip(self):
        # defect-aware mapping: placement avoids the screened-out fabric,
        # so the mapped net still equals the CLEAN monolithic reference —
        # even with the blacklisted resources actually killed by faults
        spec = _spec(rec_fan_out=3)
        rng = np.random.default_rng(3)
        ev = _inputs(spec, rng)
        net_inst = mapper.sample_network_instance(spec, jax.random.PRNGKey(3))
        mono = _mono_out(spec, net_inst, ev)
        K, R, C = 4, 64, 12
        rows = np.zeros((K, R), bool)
        rows[0, :16] = rows[2, 1::4] = True
        neurons = np.zeros((K, C), bool)
        neurons[1, :3] = neurons[3, -2:] = True
        bl = Blacklist(rows=rows, neurons=neurons)
        m = mapper.map_network(spec, K, chip_rows=R, chip_cols=C,
                               blacklist=bl)
        faults = FaultPlan(dead_rows=rows, dead_neurons=neurons)
        rt = mapper.build_runtime(m, net_inst=net_inst, faults=faults)
        _, out = rt.run(ev)
        assert mono.sum() > 0, "a silent network proves nothing"
        assert_array_equal(np.asarray(out["spikes"]), mono)

    def test_oversize_network_beyond_native_fabric(self):
        # sizes beyond one 256x512 chip: 300 inputs x 700 neurons on 4
        # NATIVE chips equals the (virtual) big-chip emulation; the
        # connectivity is locality-structured — unconstrained random
        # graphs at this size exceed the native 256-row budget, which the
        # mapper reports as a CapacityError rather than mangling
        spec = _grid_spec(300, 700)
        rng = np.random.default_rng(4)
        ev = _inputs(spec, rng, W=2, T=16, p=0.05)
        net_inst = mapper.sample_network_instance(spec, jax.random.PRNGKey(9))
        mono = _mono_out(spec, net_inst, ev)
        m = mapper.map_network(spec, 4, chip_rows=256, chip_cols=512)
        rt = mapper.build_runtime(m, net_inst=net_inst)
        _, out = rt.run(ev)
        assert mono.sum() > 0, "a silent network proves nothing"
        assert_array_equal(np.asarray(out["spikes"]), mono)

    if HAVE_HYP:
        @settings(max_examples=8, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([2, 3, 4]))
        def test_round_trip_hypothesis(self, seed, k):
            spec = _spec(seed, n_in=8, n_neurons=12, fan_out=3,
                         rec_fan_out=2, dale=False)
            rng = np.random.default_rng(seed)
            ev = _inputs(spec, rng, W=2, T=16)
            net_inst = mapper.sample_network_instance(
                spec, jax.random.PRNGKey(seed % 997))
            mono = _mono_out(spec, net_inst, ev)
            m = mapper.map_network(spec, k, chip_rows=48, chip_cols=8)
            rt = mapper.build_runtime(m, net_inst=net_inst)
            _, out = rt.run(ev)
            assert_array_equal(np.asarray(out["spikes"]), mono)
    else:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_round_trip_hypothesis(self):
            pass


class TestHybridIntegration:
    """make_experiment(wafer_plan=...) replaces the hard-coded §5 split."""

    def test_explicit_plan_reproduces_default(self):
        from repro.core import hybrid
        from repro.wafer import s5_column_plan

        ecfg = hybrid.RSTDPConfig(trial_steps=128)
        base, _, _ = hybrid.run_training(n_trials=6, ecfg=ecfg, wafer=2)
        plan = s5_column_plan(2, ecfg.n_inputs, ecfg.n_neurons)
        out, _, _ = hybrid.run_training(n_trials=6, ecfg=ecfg, wafer=2,
                                        wafer_plan=plan)
        assert_array_equal(np.asarray(out["w_signed_final"]),
                           np.asarray(base["w_signed_final"]))
        assert_array_equal(np.asarray(out["reward"]),
                           np.asarray(base["reward"]))

    def test_geometry_mismatch_rejected(self):
        from repro.core import hybrid
        from repro.wafer import s5_column_plan

        ecfg = hybrid.RSTDPConfig(trial_steps=128)
        plan = s5_column_plan(2, 4, 8)   # wrong geometry
        with pytest.raises(AssertionError, match="geometry"):
            hybrid.make_experiment(ecfg=ecfg, wafer=2, wafer_plan=plan)

    def test_relayless_plan_runs_closed_loop(self):
        # a minimal mapper-style placement (no relay broadcast at all)
        # runs the closed loop; without the relay traffic the trajectory
        # legitimately differs from the broadcast default
        from repro.core import hybrid
        from repro.wafer import s5_column_plan

        ecfg = hybrid.RSTDPConfig(trial_steps=128)
        plan = s5_column_plan(2, ecfg.n_inputs, ecfg.n_neurons, relay=False)
        out, _, _ = hybrid.run_training(n_trials=6, ecfg=ecfg, wafer=2,
                                        wafer_plan=plan)
        assert np.isfinite(np.asarray(out["reward"])).all()


class TestRelayExecution:
    def test_relayed_edge_delivers_one_window_late(self):
        # the relayed edge reaches its target one window after a direct
        # link would — visible as the transit row's routed events; the
        # run completes and the fwd traffic is counted
        n = 16
        w_rec = np.zeros((n, n), np.int32)
        w_rec[0, 8] = 40
        w_in = np.zeros((2, n), np.int32)
        w_in[0, 0] = 50
        spec = mapper.NetworkSpec(2, n, w_in, w_rec)
        m = mapper.map_network(spec, 4, chip_rows=8, chip_cols=4,
                               topology="ring")
        assert m.plan.n_forwards == 1
        rt = mapper.build_runtime(m, telemetry=True)
        ev = np.zeros((4, 16, 2), np.float32)
        ev[0, :, 0] = 1.0          # drive input 0 hard in window 0
        from repro.obs import trace as obs_trace
        _, out = rt.run(ev, telemetry=obs_trace.init_telemetry())
        tele = out["telemetry"]
        assert int(np.asarray(tele.link_reroutes)) > 0, \
            "forward traffic must be counted, never silent"


class TestRuntimeTelemetry:
    def test_auto_init_and_on_off_identical(self):
        # build_runtime(telemetry=True) must auto-init the counter
        # pytree BEFORE the window scan (a lazy in-body init would
        # change the carry structure), and on/off must stay
        # bit-identical — the house telemetry contract on the mapped
        # runtime
        rng = np.random.default_rng(3)
        spec = mapper.random_spec(rng, 8, 16, fan_out=3, rec_fan_out=2,
                                  dale=True)
        m = mapper.map_network(spec, 2, chip_rows=64, chip_cols=8)
        ev = (rng.random((2, 16, 8)) < 0.2).astype(np.float32)
        rt_on = mapper.build_runtime(m, telemetry=True)
        _, out_on = rt_on.run(ev)
        assert out_on["telemetry"] is not None
        assert int(np.asarray(out_on["telemetry"].in_events)) > 0, \
            "a silent run proves nothing: the counters must have counted"
        rt_off = mapper.build_runtime(m, net_inst=rt_on.net_inst)
        _, out_off = rt_off.run(ev)
        assert out_off["telemetry"] is None
        np.testing.assert_array_equal(np.asarray(out_on["spikes"]),
                                      np.asarray(out_off["spikes"]))
