"""Golden playback-trace regression for the PPU-VM (ISSUE 3 satellite).

Small canonical playback programs — R-STDP, STDP, and homeostasis rules
uploaded via ``WRITE_PPU_PROGRAM`` and executed with ``PPU_RUN`` — have
their full experiment traces checked in under ``tests/golden/``. The
test re-runs both co-sim backends (and the fast backend under EVERY
PPU-VM executor) against the stored traces, so an executor refactor
cannot silently change integer semantics: a 1-LSB weight shift in any
``PPU_W`` record is far outside the float tolerance and fails the diff.

Regenerate after an *intentional* semantics change with:

    PYTHONPATH=src python tests/test_ppuvm_golden.py --regen

(and justify the diff in the PR — the goldens are the contract).
"""
import os
import sys

import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.ppuvm import programs
from repro.verif import playback as pb

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
EXECUTORS = ("scan", "specialized", "pallas_interpret")

import dataclasses as _dc

CFG = _dc.replace(BSS2.reduced(), n_rows=8, n_cols=8)

RULES = {
    "rstdp": lambda: programs.rstdp_program(eta=0.5),
    "stdp": lambda: programs.stdp_program(eta_plus=0.8, eta_minus=0.9),
    "homeostasis": lambda: programs.homeostasis_program(target_rate=4.0),
}


def canonical_program(rule: str):
    """The canonical playback program for one rule: deterministic event
    stream, two PPU_RUNs (one with a noise plane, one without), weight /
    rate read-backs in between."""
    words = RULES[rule]()
    rng = np.random.RandomState(17)
    r, c = CFG.n_rows, CFG.n_cols
    w = np.full((r, c), 50, np.int8)
    addr = np.zeros((r, c), np.int8)
    ev = np.zeros((100, r), np.float32)
    ev[10] = 1.0
    ev[55] = 1.0
    ev[80, ::2] = 1.0
    mod = rng.uniform(-1, 1, (2, c)).astype(np.float32)
    noise = (0.3 * rng.randn(r, c)).astype(np.float32)
    return [
        pb.write_weights(w),
        pb.write_addresses(addr),
        pb.write_ppu_program(words),
        pb.inject(ev),
        pb.ppu_run(mod=mod, noise=noise),
        pb.read_weights(),
        pb.run(40),
        pb.ppu_run(mod=mod),
        pb.read_weights(),
        pb.read_rates(),
    ]


def golden_path(rule: str) -> str:
    return os.path.join(GOLDEN_DIR, f"playback_{rule}.npz")


def save_trace(path: str, trace) -> None:
    payload = {"n": np.int64(len(trace))}
    for i, (t, kind, val) in enumerate(trace):
        payload[f"t_{i}"] = np.int64(t)
        payload[f"kind_{i}"] = np.str_(kind)
        payload[f"val_{i}"] = np.asarray(val)
    np.savez_compressed(path, **payload)


def load_trace(path: str):
    with np.load(path) as z:
        n = int(z["n"])
        return [(int(z[f"t_{i}"]), str(z[f"kind_{i}"]), z[f"val_{i}"])
                for i in range(n)]


@pytest.mark.parametrize("rule", sorted(RULES))
class TestGoldenTraces:
    def test_ref_backend_matches_golden(self, rule):
        """The independent NumPy backend must reproduce the checked-in
        trace — the golden is the frozen integer-semantics contract."""
        golden = load_trace(golden_path(rule))
        tr = pb.execute(canonical_program(rule), "ref", CFG)
        errs = pb.compare_traces(tr, golden, atol=0.05)
        assert not errs, "\n".join(errs)

    def test_fast_backend_all_executors_match_golden(self, rule):
        """Every fast-backend executor must reproduce the golden trace:
        executor refactors cannot silently change what PPU_RUN writes."""
        golden = load_trace(golden_path(rule))
        for ex in EXECUTORS:
            tr = pb.execute(canonical_program(rule), "fast", CFG,
                            ppu_executor=ex)
            errs = pb.compare_traces(tr, golden, atol=0.05)
            assert not errs, f"executor={ex}\n" + "\n".join(errs)

    def test_golden_ppu_weights_are_integer_exact(self, rule):
        """PPU_W records are integers: both backends must match the
        golden BIT-exactly there (the float atol only covers analog
        observables)."""
        golden = load_trace(golden_path(rule))
        for be, kw in (("ref", {}), ("fast", {"ppu_executor": "auto"})):
            tr = pb.execute(canonical_program(rule), be, CFG, **kw)
            for (tg, kg, vg), (t, k, v) in zip(golden, tr):
                if kg in ("PPU_W", "WEIGHTS"):
                    np.testing.assert_array_equal(
                        v.astype(np.int32), vg.astype(np.int32),
                        err_msg=f"{be}: {kg}@{tg} not bit-equal to golden")


def regenerate() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for rule in sorted(RULES):
        trace = pb.execute(canonical_program(rule), "ref", CFG)
        save_trace(golden_path(rule), trace)
        kinds = ",".join(k for _, k, _ in trace)
        print(f"wrote {golden_path(rule)}  ({kinds})")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regenerate()
    else:
        print(__doc__)
