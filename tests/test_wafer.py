"""Wafer-scale multi-chip contracts (``repro.wafer``).

The correctness anchor is split-vs-monolithic bit-equality: a K-chip
wafer run and the single-big-chip run with block-diagonal weights (and
the same routes in global coordinates) must agree with
``assert_array_equal`` — off-block weights are exact-zero FMA terms, and
the router's scatter-max merge is order-independent. The link-budget
contract mirrors the sparse synaptic path: "auto" falls back bit-exactly
and counts, forced "compact" over budget visibly diverges and counts —
overflow is never silent.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import hybrid
from repro.core.anncore import AnnCore
from repro.obs import trace as obs_trace
from repro.verif.mismatch import sample_instance
from repro.wafer import (InterChipRouter, WaferTopology, make_plan,
                         monolithic_plan, monolithic_weights, run_windows,
                         s5_column_plan)

R, C, T, W = 16, 8, 32, 3
ADDR = 7   # every test route delivers address 7; relay synapses match it


def _random_plan(K, kind, rng, per_link=4):
    """Random routes on every link of the topology (addr 7 throughout,
    so dst-row address uniqueness holds trivially)."""
    routes = []
    for s in range(K):
        dsts = [(s + 1) % K] if kind == "ring" else list(range(K))
        for d in dsts:
            for _ in range(per_link):
                routes.append((s, int(rng.integers(C)), d,
                               int(rng.integers(R)), ADDR))
    return make_plan(WaferTopology(K, kind), R, C, routes)


def _chip_arrays(plan, rng):
    """Per-chip weight/address planes; relay rows store address 7 so the
    routed events conduct synaptic current (the route must matter)."""
    K = plan.topology.n_chips
    w = rng.integers(20, 60, (K, R, C)).astype(np.int8)
    a = np.zeros((K, R, C), np.int8)
    relay = plan.relay_rows()
    for k in range(K):
        a[k][relay[k]] = ADDR
    return w, a


def _window_inputs(K, rng, p=0.3):
    ev = (rng.random((W, T, K, R)) < p).astype(np.float32)
    ad = np.zeros((W, T, K, R), np.int8)
    return ev, ad


def _split_core(K, backend):
    cfg = dataclasses.replace(BSS2.reduced(), n_rows=R, n_cols=C)
    inst = sample_instance(cfg, jax.random.PRNGKey(3), (K,))
    return AnnCore(cfg, inst, backend=backend), inst, cfg


def _mono_core(inst, cfg, K, backend):
    """The same sampled instance as ONE chip: chip-block-contiguous
    columns (global col = chip * C + col) and rows broadcast per chip —
    exactly the layout ``monolithic_plan`` uses."""
    minst = dict(
        neuron_params={k: v.reshape(1, -1)
                       for k, v in inst["neuron_params"].items()},
        weight_gain=inst["weight_gain"].reshape(1, -1),
        stp_offset=inst["stp_offset"].reshape(1, -1),
        stp_calib=inst["stp_calib"].reshape(1, -1),
        cadc_offset=inst["cadc_offset"].reshape(1, -1),
        cadc_gain=inst["cadc_gain"].reshape(1, -1))
    mcfg = dataclasses.replace(cfg, n_rows=K * R, n_cols=K * C)
    return AnnCore(mcfg, minst, backend=backend), mcfg


def _run(core, router, prefix, w, a, ev, ad, telemetry=False):
    st = core.init_state(prefix)
    st = st._replace(syn=st.syn._replace(weights=jnp.asarray(w),
                                         addresses=jnp.asarray(a)))
    tele = obs_trace.init_telemetry() if telemetry else None
    _, out = jax.jit(lambda s, e, d: run_windows(
        core, router, s, e, d, telemetry=tele))(
            st, jnp.asarray(ev), jnp.asarray(ad))
    return out


def _counters(out):
    tl = out["telemetry"]
    return {k: int(np.asarray(getattr(tl, k)))
            for k in ("routed_events", "link_overflows", "link_events_max")}


class TestTopology:
    def test_links_and_uniform_out_degree(self):
        ring = WaferTopology(4, "ring")
        assert ring.links() == ((0, 1), (1, 2), (2, 3), (3, 0))
        assert ring.links_per_chip == 1
        a2a = WaferTopology(3, "all2all")
        assert len(a2a.links()) == 9 and (0, 0) in a2a.links()
        assert a2a.links_per_chip == 3
        # K == 1 ring degenerates to the single self-link
        assert WaferTopology(1, "ring").links() == ((0, 0),)

    def test_plan_validation(self):
        topo = WaferTopology(2, "ring")
        with pytest.raises(AssertionError, match="non-links"):
            make_plan(topo, R, C, [(0, 0, 0, 0, 1)])   # self-link not in ring
        with pytest.raises(AssertionError, match="6-bit"):
            make_plan(topo, R, C, [(0, 0, 1, 0, 64)])
        with pytest.raises(AssertionError, match="conflicting"):
            make_plan(topo, R, C, [(0, 0, 1, 3, 1), (0, 1, 1, 3, 2)])

    def test_monolithic_embedding(self):
        rng = np.random.default_rng(0)
        plan = _random_plan(2, "ring", rng)
        mono = monolithic_plan(plan)
        assert mono.topology.n_chips == 1
        assert mono.n_rows == 2 * R and mono.n_cols == 2 * C
        np.testing.assert_array_equal(
            mono.dst_row, plan.dst_chip * R + plan.dst_row)
        w = rng.integers(0, 63, (2, R, C)).astype(np.int8)
        mw = monolithic_weights(w)
        np.testing.assert_array_equal(mw[:R, :C], w[0])
        np.testing.assert_array_equal(mw[R:, C:], w[1])
        assert (mw[:R, C:] == 0).all() and (mw[R:, :C] == 0).all()


class TestSplitVsMonolithic:
    """The tentpole contract: K chips + router == one big chip with
    block-diagonal weights, bit-for-bit, on both batch backends."""

    @pytest.mark.parametrize("kind,K", [("ring", 2), ("all2all", 4)])
    @pytest.mark.parametrize("backend", ["fused", "blocked"])
    def test_split_equals_monolithic(self, kind, K, backend):
        rng = np.random.default_rng(0)
        plan = _random_plan(K, kind, rng)
        w, a = _chip_arrays(plan, rng)
        ev, ad = _window_inputs(K, rng)
        core, inst, cfg = _split_core(K, backend)
        out = _run(core, InterChipRouter(plan), (K,), w, a, ev, ad,
                   telemetry=True)
        spikes = np.asarray(out["spikes"])
        assert spikes.sum() > 0, "a silent run proves nothing"
        assert _counters(out)["routed_events"] > 0, \
            "routes must carry live traffic"

        mcore, _ = _mono_core(inst, cfg, K, backend)
        mrouter = InterChipRouter(monolithic_plan(plan))
        mout = _run(mcore, mrouter, (1,),
                    monolithic_weights(w)[None],
                    monolithic_weights(a)[None],
                    ev.reshape(W, T, 1, K * R), ad.reshape(W, T, 1, K * R))
        np.testing.assert_array_equal(
            spikes, np.asarray(mout["spikes"]).reshape(W, T, K, C))


class TestLinkBudget:
    """The never-silent overflow contract, per link: auto falls back
    bit-exactly AND counts; forced compact over budget visibly diverges
    AND counts."""

    def _runs(self, **router_kw):
        rng = np.random.default_rng(0)
        plan = _random_plan(4, "all2all", rng)
        w, a = _chip_arrays(plan, rng)
        ev, ad = _window_inputs(4, rng)
        core, _, _ = _split_core(4, "fused")
        return _run(core, InterChipRouter(plan, **router_kw), (4,),
                    w, a, ev, ad, telemetry=True)

    def test_modes_agree_within_budget(self):
        dense = self._runs(link_mode="dense")
        for mode in ("auto", "compact"):
            out = self._runs(link_mode=mode)
            np.testing.assert_array_equal(np.asarray(dense["spikes"]),
                                          np.asarray(out["spikes"]))
            assert _counters(out)["link_overflows"] == 0
        assert _counters(dense)["routed_events"] > 0

    def test_auto_fallback_is_bitexact_and_counted(self):
        dense = self._runs(link_mode="dense")
        tiny = self._runs(link_mode="auto", link_budget=4)
        np.testing.assert_array_equal(np.asarray(dense["spikes"]),
                                      np.asarray(tiny["spikes"]))
        c = _counters(tiny)
        assert c["link_overflows"] > 0
        assert c["link_events_max"] > 4

    def test_forced_compact_overflow_diverges_and_counts(self):
        dense = self._runs(link_mode="dense")
        tiny = self._runs(link_mode="compact", link_budget=4)
        assert not np.array_equal(np.asarray(dense["spikes"]),
                                  np.asarray(tiny["spikes"])), \
            "dropped link records must be visible downstream"
        assert _counters(tiny)["link_overflows"] > 0

    def test_step_budget_gates_auto(self):
        """The per-step bandwidth axis of the census: a tight
        ``link_step_budget`` trips the same counted fallback."""
        dense = self._runs(link_mode="dense")
        stepped = self._runs(link_mode="auto", link_step_budget=1)
        np.testing.assert_array_equal(np.asarray(dense["spikes"]),
                                      np.asarray(stepped["spikes"]))
        assert _counters(stepped)["link_overflows"] > 0


class TestClosedLoop:
    """run_training parity on the partitioned §5 network (the wafer mode
    of ``repro.core.hybrid``): mismatch draws, background events and
    exploration noise are drawn monolithically and resharded, so the
    learning trajectory is bit-identical for every chip count."""

    N = 8

    def _train(self, **kw):
        ecfg = hybrid.RSTDPConfig(trial_steps=128)
        out, _, meta = hybrid.run_training(n_trials=self.N, ecfg=ecfg,
                                           seed=0, **kw)
        return out, meta

    @staticmethod
    def _glob_w(w):
        K, I, c = w.shape
        return np.asarray(w).transpose(1, 0, 2).reshape(I, K * c)

    def test_k1_no_relay_matches_plain(self):
        plain, _ = self._train()
        wafer, meta = self._train(wafer=1, wafer_relay=False)
        assert meta["router"] is not None
        np.testing.assert_array_equal(plain["w_signed_final"],
                                      wafer["w_signed_final"][0])
        np.testing.assert_array_equal(plain["reward"].reshape(self.N, -1),
                                      wafer["reward"].reshape(self.N, -1))

    def test_chip_count_parity_with_relay(self):
        outs = {K: self._train(wafer=K, telemetry=True)[0]
                for K in (1, 2, 4)}
        base = self._glob_w(outs[1]["w_signed_final"])
        r1 = int(outs[1]["telemetry"]["routed_events"])
        assert r1 > 0, "the relay broadcast must carry traffic"
        for K in (2, 4):
            np.testing.assert_array_equal(
                base, self._glob_w(outs[K]["w_signed_final"]))
            np.testing.assert_array_equal(
                outs[1]["reward"].reshape(self.N, -1),
                outs[K]["reward"].reshape(self.N, -1))
            # every chip receives its own per-link broadcast copy
            assert int(outs[K]["telemetry"]["routed_events"]) == K * r1
            assert int(outs[K]["telemetry"]["link_overflows"]) == 0


def test_sharded_transport_matches_local_subprocess():
    """ppermute (ring) and masked all_gather (all2all) transports are
    bit-identical to the local one, for every link mode, on 8 fake CPU
    devices (subprocess: device count is fixed at jax init)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.wafer import WaferTopology, make_plan, InterChipRouter, run_windows
from repro.core.anncore import AnnCore
from repro.verif.mismatch import sample_instance
from repro.configs.bss2 import BSS2
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import ShardingCtx
from repro.obs import trace as obs

mesh = make_smoke_mesh((4, 2))
ctx = ShardingCtx(mesh=mesh)
K, R, C, T, W = 4, 16, 8, 32, 3
cfg = dataclasses.replace(BSS2.reduced(), n_rows=R, n_cols=C)
rng = np.random.default_rng(0)
inst = sample_instance(cfg, jax.random.PRNGKey(3), (K,))
core = AnnCore(cfg, inst, backend="fused")
w = rng.integers(20, 60, (K, R, C)).astype(np.int8)
ev = (rng.random((W, T, K, R)) < 0.3).astype(np.float32)
ad = np.zeros((W, T, K, R), np.int8)

for kind in ("ring", "all2all"):
    routes = []
    for s in range(K):
        dsts = [(s + 1) % K] if kind == "ring" else list(range(K))
        for d in dsts:
            for _ in range(4):
                routes.append((s, int(rng.integers(C)), d,
                               int(rng.integers(R)), 7))
    plan = make_plan(WaferTopology(K, kind), R, C, routes)
    a = np.zeros((K, R, C), np.int8)
    relay = plan.relay_rows()
    for k in range(K):
        a[k][relay[k]] = 7

    def run_with(router):
        st = core.init_state((K,))
        st = st._replace(syn=st.syn._replace(weights=jnp.asarray(w),
                                             addresses=jnp.asarray(a)))
        _, out = jax.jit(lambda s, e, d: run_windows(
            core, router, s, e, d, telemetry=obs.init_telemetry()))(
                st, jnp.asarray(ev), jnp.asarray(ad))
        return (np.asarray(out["spikes"]),
                int(np.asarray(out["telemetry"].routed_events)))

    for mode in ("dense", "compact", "auto"):
        s_loc, n_loc = run_with(InterChipRouter(plan, link_mode=mode))
        r_sh = InterChipRouter(plan, ctx=ctx, link_mode=mode)
        assert r_sh._axis == "data", r_sh._axis
        s_sh, n_sh = run_with(r_sh)
        np.testing.assert_array_equal(s_loc, s_sh)
        assert n_loc == n_sh, (kind, mode, n_loc, n_sh)
        assert s_loc.sum() > 0 and n_loc > 0
print("WAFER_SHARDED_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "WAFER_SHARDED_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
