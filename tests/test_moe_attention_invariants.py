"""Integration invariants: MoE dispatch algebra and attention-path
equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig, get_arch
from repro.models import attention as A
from repro.models import moe as M
from repro.parallel.sharding import ShardingCtx, init_params


def _moe_arch(n_experts=8, top_k=2, cf=8.0):
    return dataclasses.replace(
        get_arch("moonshot-v1-16b-a3b").reduced(),
        d_model=32,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                      n_shared_experts=0, capacity_factor=cf))


class TestMoEDispatch:
    def test_matches_naive_per_token_loop(self):
        """With ample capacity, the gather-based dispatch must equal the
        naive 'route every token through its top-k experts' computation."""
        arch = _moe_arch()
        ctx = ShardingCtx()
        decls = M.moe_decls(arch)
        p = init_params(decls, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))

        y, aux = jax.jit(lambda xx, pp: M.moe_ffn(xx, pp, arch, ctx))(x, p)

        # naive reference
        logits = x.astype(jnp.float32) @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        gates, eidx = jax.lax.top_k(probs, arch.moe.top_k)
        gates = gates / gates.sum(-1, keepdims=True)
        y_ref = jnp.zeros_like(x)
        for e in range(arch.moe.n_experts):
            h = jax.nn.silu(x @ p["we_gate"][e]) * (x @ p["we_up"][e])
            ye = h @ p["we_down"][e]
            for k in range(arch.moe.top_k):
                w = jnp.where(eidx[..., k] == e, gates[..., k], 0.0)
                y_ref = y_ref + w[..., None] * ye
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)

    def test_capacity_drops_excess_tokens(self):
        arch = _moe_arch(n_experts=2, top_k=1, cf=0.51)
        ctx = ShardingCtx()
        p = init_params(M.moe_decls(arch), jax.random.PRNGKey(0))
        # force every token to expert 0 via a huge router bias
        p["router"] = p["router"].at[:, 0].set(100.0).at[:, 1].set(-100.0)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        y, _ = M.moe_ffn(x, p, arch, ctx)
        # capacity = max(4, 32*1/2*0.51) = 8 of 32 tokens -> most rows zero
        nz = np.abs(np.asarray(y)).sum(-1) > 1e-6
        assert nz.sum() <= 2 * 8

    def test_aux_loss_uniform_router_is_one(self):
        arch = _moe_arch()
        ctx = ShardingCtx()
        p = init_params(M.moe_decls(arch), jax.random.PRNGKey(0))
        p["router"] = jnp.zeros_like(p["router"])   # uniform routing probs
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
        _, aux = M.moe_ffn(x, p, arch, ctx)
        # balanced: E * sum_e (1/E * 1/E) * ... == ~1 for uniform tie-broken
        assert 0.5 < float(aux) < 2.0


class TestAttentionPaths:
    def test_swa_blocked_equals_masked_prefill(self):
        b, s, h, kvh, hd, w = 2, 64, 4, 2, 16, 16
        ctx = ShardingCtx()
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        blocked = A.attention_swa_blocked(q, k, v, window=w, ctx=ctx)
        masked = A.attention_prefill(q, k, v, causal=True, window=w, ctx=ctx)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(masked),
                                   rtol=2e-3, atol=2e-3)

    def test_online_blocks_equal_single_block(self):
        b, s, h, kvh, hd = 2, 64, 4, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        ctx = ShardingCtx()
        one = A.attention_prefill(q, k, v, causal=True, window=0, ctx=ctx,
                                  kv_block=64)
        many = A.attention_prefill(q, k, v, causal=True, window=0, ctx=ctx,
                                   kv_block=16)
        np.testing.assert_allclose(np.asarray(one), np.asarray(many),
                                   rtol=2e-3, atol=2e-3)

    def test_decode_equals_prefill_last_position(self):
        b, s, h, kvh, hd = 2, 32, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kvh, hd))
        v = jax.random.normal(ks[2], (b, s, kvh, hd))
        ctx = ShardingCtx()
        full = A.attention_prefill(q, k, v, causal=True, window=0, ctx=ctx)
        dec = A.attention_decode(q[:, -1:], k, v, s - 1, window=0, ctx=ctx)
        np.testing.assert_allclose(np.asarray(dec[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-3, atol=2e-3)
