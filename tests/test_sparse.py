"""Event-sparse synaptic path: packing round-trip + BIT-exact equivalence.

The sparse path (``repro.core.events`` + ``repro.kernels.synray_sparse``)
claims bit-identity with the dense matmul and the per-step oracle — not
tolerance-equality — for any window that fits its static capacities. The
claim rests on XLA:CPU's in-order FMA reduction chain (see
synray_sparse/ref.py), so this suite asserts ``assert_array_equal``
across a 0%..100% density sweep, through both the jnp ref and the kernel
in interpret mode, with float STP-like efficacies, multi-address streams,
and instance prefixes.

The flip side of the static capacities is the overflow contract: a FORCED
sparse path with an undersized capacity silently drops events and must
provably diverge from the dense result (the divergence-contract pattern
of test_fused.py's const_addr test), while ``sparse="auto"`` detects the
same overflow at runtime and falls back to dense — never wrong numbers.

``ANNCORE_KERNEL_IMPL`` (default "auto") forces the kernel impl for the
core-level classes — the tier-2 CI job sets "interpret" to run the suite
through the actual Pallas kernels.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import events, synapse
from repro.core.anncore import AnnCore
from repro.kernels.synray_sparse import ops as sparse_ops
from repro.verif.mismatch import sample_instance

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KERNEL_IMPL = os.environ.get("ANNCORE_KERNEL_IMPL", "auto")
DENSITIES = [0.0, 0.001, 0.01, 0.1, 0.5, 1.0]


def _window(T, R, key=0, p=0.1, n_addr=4):
    """[T, R] events with STP-like float efficacies (0 = silent)."""
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    fired = jax.random.uniform(ks[0], (T, R)) < p
    eff = jax.random.uniform(ks[1], (T, R), minval=0.1, maxval=1.5)
    ev = jnp.where(fired, eff, 0.0)
    ad = jax.random.randint(ks[2], (T, R), 0, n_addr, jnp.int8)
    return ev, ad


def _array(R, C, key=1, n_addr=4):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    w = jax.random.randint(ks[0], (R, C), 0, 64, jnp.int8)
    a = jax.random.randint(ks[1], (R, C), 0, n_addr, jnp.int8)
    return w, a


def _round_trip(ev, ad, max_events):
    T, R = ev.shape
    stream = events.pack_events(ev, ad, max_events)
    ev2, ad2 = events.unpack_events(stream, T, R)
    return stream, ev2, ad2


class TestEventStreamRoundTrip:
    @pytest.mark.parametrize("p", DENSITIES)
    def test_round_trip_exact(self, p):
        """pack -> unpack reproduces the window exactly: efficacies
        everywhere, addresses at fired slots (silent slots carry 0 — the
        stream only transports addresses WITH events)."""
        T, R = 40, 24
        ev, ad = _window(T, R, key=3, p=p)
        _, ev2, ad2 = _round_trip(ev, ad, T * R)
        np.testing.assert_array_equal(np.asarray(ev), np.asarray(ev2))
        fired = np.asarray(ev) != 0
        np.testing.assert_array_equal(np.asarray(ad) * fired,
                                      np.asarray(ad2))
        assert (np.asarray(ad2) * ~fired == 0).all()

    def test_t_major_order_and_census(self):
        ev, ad = _window(48, 16, key=4, p=0.2)
        stream = events.pack_events(ev, ad, 48 * 16)
        n = int(stream.n_events)
        assert n == int(np.count_nonzero(np.asarray(ev)))
        assert np.asarray(stream.valid).sum() == n
        t = np.asarray(stream.t)[:n]
        row = np.asarray(stream.row)[:n]
        assert (np.diff(t) >= 0).all(), "records must be t-major"
        same_t = np.diff(t) == 0
        assert (np.diff(row)[same_t] > 0).all(), \
            "rows must ascend within a step"

    def test_overflow_reports_true_count(self):
        """Over-capacity packing keeps the TRUE census (the auto-switch
        predicate) while the stored records stay a valid prefix."""
        ev, ad = _window(32, 32, key=5, p=0.5)
        n_true = int(np.count_nonzero(np.asarray(ev)))
        cap = n_true // 3
        stream = events.pack_events(ev, ad, cap)
        assert int(stream.n_events) == n_true
        assert bool(events.overflowed(stream))
        assert np.asarray(stream.valid).sum() == cap
        full = events.pack_events(ev, ad, 32 * 32)
        np.testing.assert_array_equal(np.asarray(stream.eff),
                                      np.asarray(full.eff)[:cap])

    def test_regroup_matches_stream(self):
        """[T, K] regrouping holds exactly the stream's records, in
        stream (row-ascending) order per step."""
        T, R = 40, 24
        ev, ad = _window(T, R, key=6, p=0.15)
        stream = events.pack_events(ev, ad, T * R)
        rows_tk, addr_tk, eff_tk = events.regroup_events(stream, T, R)
        evn, adn = np.asarray(ev), np.asarray(ad)
        for t in range(T):
            rr = np.nonzero(evn[t])[0]
            k = len(rr)
            np.testing.assert_array_equal(np.asarray(rows_tk)[t, :k], rr)
            np.testing.assert_array_equal(np.asarray(eff_tk)[t, :k],
                                          evn[t, rr])
            np.testing.assert_array_equal(np.asarray(addr_tk)[t, :k],
                                          adn[t, rr])
            assert (np.asarray(eff_tk)[t, k:] == 0).all()

    def test_window_stats(self):
        """The auto-switch census: worst per-instance total and worst
        single-step count, across an instance prefix."""
        ev = jnp.zeros((4, 2, 8)).at[0, 0, :3].set(1.0).at[2, 1, :5].set(
            0.7).at[3, 1, 0].set(0.2)
        n, kmax = events.window_stats(ev)
        assert int(n) == 6 and int(kmax) == 5

    if HAVE_HYPOTHESIS:
        @settings(max_examples=40, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1),
               t_len=st.integers(1, 24), rows=st.integers(1, 24),
               density=st.floats(0.0, 1.0))
        def test_round_trip_hypothesis(self, seed, t_len, rows, density):
            """Property: ANY window round-trips through the stream."""
            rng = np.random.RandomState(seed)
            ev = jnp.asarray(
                np.where(rng.rand(t_len, rows) < density,
                         rng.rand(t_len, rows).astype(np.float32) + 0.1,
                         0.0).astype(np.float32))
            ad = jnp.asarray(rng.randint(0, 64, (t_len, rows)), jnp.int8)
            _, ev2, ad2 = _round_trip(ev, ad, t_len * rows)
            np.testing.assert_array_equal(np.asarray(ev), np.asarray(ev2))
            fired = np.asarray(ev) != 0
            np.testing.assert_array_equal(np.asarray(ad) * fired,
                                          np.asarray(ad2))
    else:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_round_trip_hypothesis(self):
            pass


class TestSparseBitExact:
    """sparse == dense == per-step oracle, EXACT equality, 0%..100%.

    C = 512 keeps T * R * C above ``synapse.SPARSE_MIN_DENSE_WORK`` so
    the sparse="auto" tests exercise the runtime switch rather than the
    static small-window demotion to dense."""

    T, R, C = 64, 64, 512

    def _operands(self, p, key=0, n_addr=4):
        ev, ad = _window(self.T, self.R, key=key, p=p, n_addr=n_addr)
        w, a = _array(self.R, self.C, key=key + 1, n_addr=n_addr)
        gain = jax.random.uniform(jax.random.PRNGKey(key + 2), (self.C,),
                                  minval=0.5, maxval=1.5)
        return w, a, ev, ad, gain

    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    @pytest.mark.parametrize("p", DENSITIES)
    def test_sweep_against_dense_and_oracle(self, p, impl):
        w, a, ev, ad, gain = self._operands(p, key=int(p * 1000))
        dense = synapse.synaptic_current_window(w, a, ev, ad, gain,
                                                sparse="never")
        sparse = synapse.synaptic_current_window(
            w, a, ev, ad, gain, impl=impl, sparse="always",
            max_events=self.T * self.R, k_cap=self.R)
        np.testing.assert_array_equal(np.asarray(sparse),
                                      np.asarray(dense))
        oracle = jnp.stack([synapse.synaptic_current(w, a, ev[t], ad[t],
                                                     gain)
                            for t in range(self.T)])
        np.testing.assert_array_equal(np.asarray(sparse),
                                      np.asarray(oracle))

    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    def test_auto_fits_is_exact(self, impl):
        """Below-threshold window through sparse="auto" (the lax.cond
        picks the sparse branch) — still bit-identical to dense."""
        w, a, ev, ad, gain = self._operands(0.005, key=11)
        assert self.T * self.R * self.C >= synapse.SPARSE_MIN_DENSE_WORK
        dense = synapse.synaptic_current_window(w, a, ev, ad, gain,
                                                sparse="never")
        n, kmax = events.window_stats(ev)
        assert int(n) <= events.default_max_events(
            self.T, self.R, synapse.SPARSE_THRESHOLD)
        auto = jax.jit(lambda *o: synapse.synaptic_current_window(
            *o, impl=impl, sparse="auto"))(w, a, ev, ad, gain)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(dense))

    def test_const_addr_stream(self):
        """Row-constant addresses (the §5 wiring): sparse == the
        const_addr dense fast path, exactly."""
        w, a = _array(self.R, self.C, key=21)
        ev, _ = _window(self.T, self.R, key=20, p=0.02)
        row_addr = jax.random.randint(jax.random.PRNGKey(22), (self.R,),
                                      0, 4, jnp.int8)
        ad = jnp.broadcast_to(row_addr, ev.shape)
        fast = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                               sparse="never",
                                               const_addr=True)
        sparse = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, sparse="always",
            max_events=self.T * self.R, k_cap=self.R)
        np.testing.assert_array_equal(np.asarray(sparse),
                                      np.asarray(fast))

    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    def test_instance_prefix(self, impl):
        """A fleet prefix rides the sparse kernel's instance grid axis —
        still bit-identical per instance."""
        prefix, T, R, C = (3,), 48, 32, 64
        ks = jax.random.split(jax.random.PRNGKey(31), 5)
        fired = jax.random.uniform(ks[0], (T, *prefix, R)) < 0.03
        ev = jnp.where(fired,
                       jax.random.uniform(ks[1], (T, *prefix, R),
                                          minval=0.1, maxval=1.5), 0.0)
        ad = jax.random.randint(ks[2], (T, *prefix, R), 0, 4, jnp.int8)
        w = jax.random.randint(ks[3], (*prefix, R, C), 0, 64, jnp.int8)
        a = jax.random.randint(ks[4], (*prefix, R, C), 0, 4, jnp.int8)
        dense = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                                sparse="never")
        sparse = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, impl=impl, sparse="always",
            max_events=T * R, k_cap=R)
        np.testing.assert_array_equal(np.asarray(sparse),
                                      np.asarray(dense))

    def test_small_window_static_dense_demotion(self):
        """Below the work floor, sparse="auto" compiles to the pure dense
        program — identical to sparse="never" for the same impl (e.g. the
        16 x 16 §5 experiment never pays any switch overhead)."""
        T, R, C = 32, 16, 32
        assert T * R * C < synapse.SPARSE_MIN_DENSE_WORK
        ev, ad = _window(T, R, key=81, p=0.05)
        w, a = _array(R, C, key=82)
        for impl in ("ref", "interpret"):
            auto = synapse.synaptic_current_window(
                w, a, ev, ad, 1.0, impl=impl, sparse="auto")
            never = synapse.synaptic_current_window(
                w, a, ev, ad, 1.0, impl=impl, sparse="never")
            np.testing.assert_array_equal(np.asarray(auto),
                                          np.asarray(never))

    def test_ops_ref_vs_interpret(self):
        """The kernel itself against its jnp ref on the same regrouped
        records — the kernel preserves the reduction chain bit-for-bit."""
        T, R, C = 32, 64, 128
        ev, ad = _window(T, R, key=41, p=0.1)
        w, a = _array(R, C, key=42)
        stream = events.pack_events(ev, ad, T * R)
        recs = events.regroup_events(stream, T, 16)
        outs = [sparse_ops.sparse_window(*recs, w, a, impl=impl)
                for impl in ("ref", "interpret")]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]))


class TestOverflowContract:
    """Undersized capacities must never produce silently wrong numbers.
    (Sized above ``SPARSE_MIN_DENSE_WORK`` so the "auto" cases reach the
    runtime census rather than the static dense demotion.)"""

    T, R, C = 64, 64, 512

    def _operands(self):
        ev, ad = _window(self.T, self.R, key=51, p=0.5)
        w, a = _array(self.R, self.C, key=52)
        return w, a, ev, ad

    def test_forced_sparse_overflow_diverges(self):
        """The divergence contract: forcing sparse with a deliberately
        undersized stream capacity DROPS events, provably diverging from
        dense — the broken promise the auto fallback exists to prevent."""
        w, a, ev, ad = self._operands()
        dense = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                                sparse="never")
        n = int(np.count_nonzero(np.asarray(ev)))
        forced = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, sparse="always", max_events=n // 4,
            k_cap=self.R)
        assert np.abs(np.asarray(forced) - np.asarray(dense)).max() > 0, \
            "undersized capacity without fallback must be detectable"

    def test_auto_overflow_falls_back_dense(self):
        """Same undersized capacity through sparse="auto": the runtime
        census detects the overflow and the window runs dense — exact."""
        w, a, ev, ad = self._operands()
        dense = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                                sparse="never")
        n = int(np.count_nonzero(np.asarray(ev)))
        auto = jax.jit(lambda *o: synapse.synaptic_current_window(
            *o, sparse="auto", max_events=n // 4))(w, a, ev, ad, 1.0)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(dense))

    def test_auto_per_step_overflow_falls_back(self):
        """k_cap (per-step records) undersized: auto must fall back even
        when the TOTAL census fits."""
        w, a, ev, ad = self._operands()
        dense = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                                sparse="never")
        auto = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, sparse="auto",
            max_events=self.T * self.R, k_cap=2)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(dense))
        forced = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, sparse="always",
            max_events=self.T * self.R, k_cap=2)
        assert np.abs(np.asarray(forced) - np.asarray(dense)).max() > 0

    def test_step_overflow_predicate_flags_silent_regime(self):
        """The latent-bug regime: a stream that FITS its total capacity
        (``overflowed() == False``) but holds a step with more than
        ``k_cap`` records — ``regroup_events`` drops that step's tail
        while the total-capacity predicate reports all-clear. The
        per-step predicate ``step_overflowed`` must flag it, and the
        shared ``census_fits`` gate (what sparse="auto" and the wafer
        router's link budget both use) must refuse it."""
        w, a, ev, ad = self._operands()
        dense = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                                sparse="never")
        k_cap = 2
        stream = events.pack_events(ev, ad, self.T * self.R)
        assert not bool(events.overflowed(stream)), \
            "regime needs a stream that fits its total capacity"
        assert bool(events.step_overflowed(stream, self.T, k_cap)), \
            "per-step predicate must flag the regroup drop"
        n, kmax = events.window_stats(ev)
        assert not bool(events.census_fits(n, kmax, self.T * self.R,
                                           k_cap)), \
            "the shared gate must refuse what regroup would drop"
        # and the drop is real: the forced path diverges from dense
        forced = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, sparse="always",
            max_events=self.T * self.R, k_cap=k_cap)
        assert np.abs(np.asarray(forced) - np.asarray(dense)).max() > 0

    def test_step_counts_and_truncate_stream(self):
        """``step_counts`` reports the stored per-step records;
        ``truncate_stream`` cuts each step at the budget while keeping
        ``n_events`` at the TRUE count (drop-detectable)."""
        ev, ad = _window(16, 32, key=53, p=0.4)
        T = 16
        stream = events.pack_events(ev, ad, T * 32)
        counts = np.asarray(events.step_counts(stream, T))
        np.testing.assert_array_equal(
            counts, np.count_nonzero(np.asarray(ev), axis=1))
        cut = events.truncate_stream(stream, T, 3)
        cut_counts = np.asarray(events.step_counts(cut, T))
        np.testing.assert_array_equal(cut_counts,
                                      np.minimum(counts, 3))
        # kept records are exactly each step's first 3 (t-major order)
        ev2, _ = events.unpack_events(cut, T, 32)
        kept = np.asarray(ev).copy()
        for t in range(T):
            nz = np.nonzero(kept[t])[0]
            kept[t, nz[3:]] = 0.0
        np.testing.assert_array_equal(np.asarray(ev2), kept)
        assert int(cut.n_events) == int(stream.n_events)
        assert bool(events.step_overflowed(cut, T, 3)) == bool(
            (counts > 3).any())


class TestAutoGate:
    """The const_addr-aware auto gate (PR 6 follow-on): with const_addr
    the dense side is the once-resolved plain matmul, so the crossover
    drops — "auto" sizes its capacities from the lower
    ``SPARSE_THRESHOLD_CONST_ADDR`` and hands the intermediate-density
    band back to dense. Each route is internally bit-exact; across the
    two dense variants (masked vs once-resolved matmul) the house
    const_addr tolerance applies (see tests/test_fused.py)."""

    T, R, C = 128, 128, 256   # T*R*C = 4M >= SPARSE_MIN_DENSE_WORK

    def _operands(self, p):
        # const_addr-compatible stream: one address per row, constant
        # over the window (the mapper's address-schedule regime)
        ks = jax.random.split(jax.random.PRNGKey(71), 4)
        row_addr = jax.random.randint(ks[0], (self.R,), 0, 64, jnp.int8)
        fired = jax.random.uniform(ks[1], (self.T, self.R)) < p
        eff = jax.random.uniform(ks[2], (self.T, self.R), minval=0.1,
                                 maxval=1.5)
        ev = jnp.where(fired, eff, 0.0)
        ad = jnp.broadcast_to(row_addr, (self.T, self.R))
        w = jax.random.randint(ks[3], (self.R, self.C), 0, 64, jnp.int8)
        a = jnp.broadcast_to(row_addr[:, None], (self.R, self.C))
        return w, a, ev, ad

    def test_const_addr_lowers_crossover(self):
        """At a density between the two thresholds (0.02 < p <= 0.05)
        the generic gate still routes sparse, the const_addr gate picks
        dense — where the once-resolved matmul wins."""
        from repro.obs import trace as obs_trace
        w, a, ev, ad = self._operands(p=0.03)
        n, kmax = events.window_stats(ev)
        assert (synapse.SPARSE_THRESHOLD_CONST_ADDR * self.T * self.R
                < int(n) <= synapse.SPARSE_THRESHOLD * self.T * self.R), \
            "regime check: density must sit between the two thresholds"

        def run(const_addr):
            return synapse.synaptic_current_window(
                w, a, ev, ad, 1.0, impl=KERNEL_IMPL, const_addr=const_addr,
                sparse="auto", telemetry=obs_trace.init_telemetry())

        i_gen, tl_gen = jax.jit(lambda: run(False))()
        i_ca, tl_ca = jax.jit(lambda: run(True))()
        assert int(tl_gen.sparse_windows) == 1, \
            "generic gate must still route this window sparse"
        assert int(tl_ca.dense_windows) == 1, \
            "const_addr gate must hand the window back to dense"
        assert int(tl_ca.overflow_fallbacks) == 1
        # across routes the result agrees to the const_addr fast-path
        # tolerance (the once-resolved matmul reduces in a different
        # order than the masked path — same contract as test_fused.py's
        # const_addr coverage; within one configured route the program
        # is fixed, so repeated runs stay bit-identical)
        np.testing.assert_allclose(np.asarray(i_gen), np.asarray(i_ca),
                                   rtol=1e-4, atol=1e-4)

    def test_explicit_threshold_still_wins(self):
        """A caller-provided sparse_threshold overrides the const_addr
        default (no behavior change for explicit configurations)."""
        from repro.obs import trace as obs_trace
        w, a, ev, ad = self._operands(p=0.03)
        i, tl = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, impl=KERNEL_IMPL, const_addr=True,
            sparse="auto", sparse_threshold=synapse.SPARSE_THRESHOLD,
            telemetry=obs_trace.init_telemetry())
        assert int(tl.sparse_windows) == 1


class TestDenseBatchBlock:
    """Satellite: the dense kernel's batch-block pick. The old
    ``next(d for d in (8, 4, 2, 1) if T % d == 0)`` silently degraded to
    bb=1 for odd T; now T pads up to the block and slices back."""

    R, C = 16, 16

    def _operands(self, T, key=61):
        ev, ad = _window(T, self.R, key=key, p=0.2)
        w, a = _array(self.R, self.C, key=key + 1)
        return w, a, ev, ad

    @pytest.mark.parametrize("T", [97, 101, 50])
    def test_prime_and_odd_T_through_kernel(self, T):
        """Mirrors test_blocked's T % block != 0 cases: the padded kernel
        path stays exact for window lengths the block does not divide."""
        w, a, ev, ad = self._operands(T)
        ref = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                              impl="ref", sparse="never")
        out = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                              impl="interpret",
                                              sparse="never")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("bb", [5, 16])
    def test_bb_override_knob(self, bb):
        """The bb= override reaches the kernel (incl. bb > T and bb not
        dividing T) without changing results."""
        T = 13
        w, a, ev, ad = self._operands(T, key=63)
        ref = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                              impl="ref", sparse="never")
        out = synapse.synaptic_current_window(w, a, ev, ad, 1.0,
                                              impl="interpret",
                                              sparse="never", bb=bb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestAnnCoreSparse:
    """The sparse path wired into the fused backend: whole-run equality."""

    CFG = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)

    def _cores(self, **kw):
        inst = sample_instance(self.CFG, jax.random.PRNGKey(0), ())
        dense = AnnCore(self.CFG, inst, backend="fused",
                        kernel_impl=KERNEL_IMPL, sparse_mode="never")
        sparse = AnnCore(self.CFG, inst, backend="fused",
                         kernel_impl=KERNEL_IMPL, sparse_mode="always",
                         sparse_max_events=200 * 8, sparse_k_cap=8, **kw)
        oracle = AnnCore(self.CFG, inst, backend="oracle")
        st = oracle.init_state(())
        kw_, ka = jax.random.split(jax.random.PRNGKey(9))
        st = st._replace(syn=st.syn._replace(
            weights=jax.random.randint(
                kw_, (self.CFG.n_rows, self.CFG.n_cols), 20, 64, jnp.int8),
            addresses=jax.random.randint(
                ka, (self.CFG.n_rows, self.CFG.n_cols), 0, 4, jnp.int8)))
        return oracle, dense, sparse, st

    def test_fused_sparse_bit_identical_to_dense(self):
        """sparse_mode="always" vs "never" on the same fused core: the
        whole run (spikes AND final state) is bit-identical."""
        oracle, dense, sparse, st = self._cores()
        ks = jax.random.split(jax.random.PRNGKey(71))
        ev = (jax.random.uniform(ks[0], (200, self.CFG.n_rows)) < 0.1
              ).astype(jnp.float32)
        ad = jax.random.randint(ks[1], (200, self.CFG.n_rows), 0, 4,
                                jnp.int8)
        s1, o1 = jax.jit(dense.run)(st, ev, ad)
        s2, o2 = jax.jit(sparse.run)(st, ev, ad)
        assert float(o1["spikes"].sum()) > 0
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        for x, y in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        _, o3 = jax.jit(oracle.run)(st, ev, ad)
        np.testing.assert_allclose(np.asarray(o3["spikes"]),
                                   np.asarray(o2["spikes"]),
                                   rtol=1e-4, atol=1e-4)

    def test_sparse_threads_through_run_training(self):
        """The sparse knobs reach the core through make_experiment /
        run_training, and the §5 experiment result is invariant."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, _, meta = run_training(n_trials=5, seed=7, ecfg=ecfg,
                                   sparse_mode="never")
        assert meta["core"].sparse_mode == "never"
        o2, _, meta2 = run_training(n_trials=5, seed=7, ecfg=ecfg,
                                    sparse_mode="auto",
                                    sparse_threshold=0.05)
        assert meta2["core"].sparse_mode == "auto"
        assert meta2["core"].sparse_threshold == 0.05
        np.testing.assert_array_equal(o1["w_signed_final"],
                                      o2["w_signed_final"])
        np.testing.assert_array_equal(o1["reward"], o2["reward"])
