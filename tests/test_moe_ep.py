"""The shard_map expert-parallel MoE must match the GSPMD path numerically
(8 fake devices, mesh 2x4). Subprocess because device count is set at jax
init."""
import os
import subprocess
import sys


def test_moe_ep_matches_gspmd_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.config import MoEConfig, MeshConfig, get_arch
from repro.models import moe as M
from repro.parallel.sharding import ShardingCtx, init_params, tree_pspecs

arch = dataclasses.replace(
    get_arch("moonshot-v1-16b-a3b").reduced(), d_model=32,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                  n_shared_experts=1, capacity_factor=8.0))
from repro.launch.mesh import make_smoke_mesh
mesh = make_smoke_mesh((2, 4), ("data", "model"))

# MeshConfig is fixed-shape; build a ctx whose mesh is the small test mesh
ctx = ShardingCtx(mesh=mesh)
p = init_params(M.moe_decls(arch), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))

with mesh:
    y_gspmd, aux_g = jax.jit(
        lambda xx, pp: M.moe_ffn(xx, pp, arch, ctx))(x, p)
    y_ep, aux_e = jax.jit(
        lambda xx, pp: M.moe_ffn_ep(xx, pp, arch, ctx))(x, p)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_gspmd),
                           rtol=3e-3, atol=3e-3)
# aux: EP averages per-data-rank balance terms (mean of products), GSPMD
# computes the global product of means — equal only for balanced routing
assert abs(float(aux_e) - float(aux_g)) < 0.3, (float(aux_e), float(aux_g))

# gradients flow through the shard_map path
def loss(pp):
    y, aux = M.moe_ffn_ep(x, pp, arch, ctx)
    return jnp.sum(y ** 2) + aux
with mesh:
    g = jax.jit(jax.grad(loss))(p)
gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("MOE_EP_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "MOE_EP_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
