"""End-to-end system tests: multiple subsystems composed, as a user would."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShapeConfig, get_arch


def test_calibrate_then_learn_end_to_end():
    """verif (MC calibration) -> core (machine model) -> rules (R-STDP):
    the §3.2 + §5 pipeline in one pass."""
    from repro.configs.bss2 import BSS2
    from repro.core.hybrid import run_training
    from repro.verif.calibration import calibrate_stp
    from repro.verif.mismatch import sample_instance

    cfg = dataclasses.replace(BSS2.reduced(), n_rows=32, n_cols=16)
    inst = sample_instance(cfg, jax.random.PRNGKey(7))
    codes, metrics = calibrate_stp(cfg, inst["stp_offset"])
    assert float(metrics["std_after"]) < float(metrics["std_before"])

    out, state, meta = run_training(n_trials=200, seed=0)
    mr = out["mean_reward"]
    assert float(np.mean(np.median(mr[-60:], axis=1))) > 0.7


def test_train_checkpoint_serve_roundtrip():
    """train (AdamW, ckpt) -> checkpoint restore -> serve (generate)."""
    from repro.serve.engine import ServeEngine
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.checkpoint import restore_checkpoint

    arch = get_arch("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("smoke", 32, 4, "train")
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(steps=12, ckpt_every=6, ckpt_dir=d,
                             log_every=100,
                             opt=AdamWConfig(lr=1e-3, warmup_steps=2))
        tr = Trainer(arch, shape, tcfg)
        out = tr.train()
        assert out["history"][-1]["loss"] < out["history"][0]["loss"]

        step, state = restore_checkpoint(d)
        assert step == 12
        params = jax.tree.map(jnp.asarray, state["params"])
        eng = ServeEngine(arch, max_len=64)
        gen = eng.generate(params, jnp.ones((2, 8), jnp.int32), n_new=5)
        assert gen.shape == (2, 5)
        assert (gen >= 0).all() and (gen < arch.vocab_padded).all()


def test_hybrid_plasticity_on_lm_end_to_end():
    """C1' three-factor trainer on an SSM arch (paper technique beyond the
    neuromorphic substrate), fused on device."""
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.parallel.sharding import init_params
    from repro.plasticity.three_factor import HybridReadoutTrainer

    arch = get_arch("mamba2-130m").reduced()
    tr = HybridReadoutTrainer(arch)
    params = init_params(tr.bundle.decls, jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(arch, ShapeConfig("s", 32, 4, "train"), seed=0)
    st = tr.init_state(jax.random.PRNGKey(1))
    rewards = []
    for _ in range(30):
        st, m = tr.step(params, st, pipe.next_batch())
        rewards.append(float(m["reward"]))
    assert np.isfinite(rewards).all()
    assert int(jnp.max(jnp.abs(st.w_q))) <= 31  # 6-bit signed envelope
