"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle. Pallas kernels run in interpret mode (CPU container; TPU is
the deployment target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.synray.kernel import synaptic_current_pallas
from repro.kernels.synray.ref import synaptic_current_ref
from repro.kernels.corr.kernel import correlation_window_pallas
from repro.kernels.corr.ref import correlation_window_ref
from repro.kernels.ppu_update.kernel import rstdp_update_pallas
from repro.kernels.ppu_update.ref import rstdp_update_ref


def _rng(*args):
    import zlib
    return jax.random.PRNGKey(zlib.crc32(repr(args).encode()) % (2 ** 31))


class TestSynray:
    @pytest.mark.parametrize("B,R,C,bb,rb,cb", [
        (8, 64, 128, 8, 64, 128),
        (16, 128, 256, 8, 64, 128),
        (4, 32, 512, 4, 32, 128),
        (2, 256, 128, 2, 64, 128),
        (8, 64, 128, 4, 32, 64),      # multiple grid steps on every axis
    ])
    def test_matches_ref(self, B, R, C, bb, rb, cb):
        k1, k2, k3, k4 = jax.random.split(_rng("synray", B, R, C), 4)
        ev = (jax.random.uniform(k1, (B, R)) < 0.2).astype(jnp.float32) \
            * jax.random.uniform(k2, (B, R), minval=0.2, maxval=1.2)
        ea = jax.random.randint(k2, (B, R), 0, 64, jnp.int8)
        w = jax.random.randint(k3, (R, C), 0, 64, jnp.int8)
        st = jax.random.randint(k4, (R, C), 0, 64, jnp.int8)
        out = synaptic_current_pallas(ev, ea, w, st, bb=bb, rb=rb, cb=cb,
                                      interpret=True)
        ref = synaptic_current_ref(ev, ea, w, st)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_all_match_reduces_to_matmul(self):
        B, R, C = 4, 32, 128
        ev = jnp.ones((B, R))
        ea = jnp.zeros((B, R), jnp.int8)
        w = jax.random.randint(_rng("mm"), (R, C), 0, 64, jnp.int8)
        st = jnp.zeros((R, C), jnp.int8)
        out = synaptic_current_pallas(ev, ea, w, st, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out),
            np.broadcast_to(np.asarray(w).astype(np.float32).sum(0), (B, C)),
            rtol=1e-6)

    def test_instance_grid_axis(self):
        """[N, ...] operands ride the leading grid dimension: each
        instance's result equals its own 2-D kernel call."""
        N, B, R, C = 3, 4, 64, 128
        ks = jax.random.split(_rng("synray-inst"), 4)
        ev = (jax.random.uniform(ks[0], (N, B, R)) < 0.2).astype(jnp.float32)
        ea = jax.random.randint(ks[1], (N, B, R), 0, 8, jnp.int8)
        w = jax.random.randint(ks[2], (N, R, C), 0, 64, jnp.int8)
        st = jax.random.randint(ks[3], (N, R, C), 0, 8, jnp.int8)
        out = synaptic_current_pallas(ev, ea, w, st, interpret=True)
        assert out.shape == (N, B, C)
        for n in range(N):
            one = synaptic_current_pallas(ev[n], ea[n], w[n], st[n],
                                          interpret=True)
            np.testing.assert_array_equal(np.asarray(out[n]),
                                          np.asarray(one))


class TestCorr:
    @pytest.mark.parametrize("T,R,C,rb,cb", [
        (32, 64, 128, 64, 128),
        (64, 128, 128, 64, 128),
        (16, 64, 256, 32, 128),
        (128, 32, 128, 32, 128),
    ])
    def test_matches_ref(self, T, R, C, rb, cb):
        k1, k2, k3, k4 = jax.random.split(_rng("corr", T, R, C), 4)
        pre = (jax.random.uniform(k1, (T, R)) < 0.1).astype(jnp.float32)
        post = (jax.random.uniform(k2, (T, C)) < 0.1).astype(jnp.float32)
        tp0 = jax.random.uniform(k3, (R,))
        tq0 = jax.random.uniform(k4, (C,))
        ac0 = jax.random.uniform(k3, (R, C)) * 2
        aa0 = jax.random.uniform(k4, (R, C)) * 2
        lam = float(np.exp(-0.2 / 5.0))
        got = correlation_window_pallas(pre, post, tp0, tq0, ac0, aa0,
                                        lam=lam, rb=rb, cb=cb, interpret=True)
        want = correlation_window_ref(pre, post, tp0, tq0, ac0, aa0, lam=lam)
        for g, w_, name in zip(got, want, ["ac", "aa", "tp", "tq"]):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                       rtol=2e-5, atol=2e-5, err_msg=name)

    def test_saturation_enforced(self):
        T, R, C = 16, 32, 128
        pre = jnp.ones((T, R))
        post = jnp.ones((T, C))
        z = jnp.zeros
        sat = 10.0
        ac, aa, _, _ = correlation_window_pallas(
            pre, post, z((R,)), z((C,)), z((R, C)), z((R, C)),
            lam=0.9, sat=sat, interpret=True)
        assert float(jnp.max(ac)) <= sat + 1e-6
        assert float(jnp.max(aa)) <= sat + 1e-6

    def test_instance_grid_axis(self):
        """The correlation kernel's leading instance grid axis: each
        instance integrates independently."""
        N, T, R, C = 2, 32, 64, 128
        ks = jax.random.split(_rng("corr-inst"), 4)
        pre = (jax.random.uniform(ks[0], (N, T, R)) < 0.1).astype(
            jnp.float32)
        post = (jax.random.uniform(ks[1], (N, T, C)) < 0.1).astype(
            jnp.float32)
        tp0 = jax.random.uniform(ks[2], (N, R))
        tq0 = jax.random.uniform(ks[3], (N, C))
        ac0 = jnp.zeros((N, R, C))
        lam = 0.95
        got = correlation_window_pallas(pre, post, tp0, tq0, ac0, ac0,
                                        lam=lam, interpret=True)
        for n in range(N):
            one = correlation_window_pallas(
                pre[n], post[n], tp0[n], tq0[n], ac0[n], ac0[n], lam=lam,
                interpret=True)
            for g, o in zip((x[n] for x in got), one):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(o))


class TestPPUUpdate:
    @pytest.mark.parametrize("R,C,rb,cb", [
        (64, 128, 64, 128),
        (256, 512, 64, 128),
        (32, 256, 32, 128),
    ])
    def test_matches_ref(self, R, C, rb, cb):
        ks = jax.random.split(_rng("ppu", R, C), 7)
        w = jax.random.randint(ks[0], (R, C), 0, 64, jnp.int8)
        ac = jax.random.uniform(ks[1], (R, C)) * 20
        aa = jax.random.uniform(ks[2], (R, C)) * 20
        off = jax.random.normal(ks[3], (C,)) * 4
        gain = 1 + 0.05 * jax.random.normal(ks[4], (C,))
        mod = jax.random.normal(ks[5], (C,))
        xi = 0.3 * jax.random.normal(ks[6], (R, C))
        got_w, got_e = rstdp_update_pallas(w, ac, aa, off, gain, mod, xi,
                                           eta=8.0, rb=rb, cb=cb,
                                           interpret=True)
        ref_w, ref_e = rstdp_update_ref(w, ac, aa, off, gain, mod, xi,
                                        eta=8.0)
        # eligibility may differ by exactly one CADC LSB at .5 rounding ties
        # (ULP-level multiply-order differences); such ties must be rare
        de = np.abs(np.asarray(got_e) - np.asarray(ref_e))
        assert de.max() <= 1.0 / 255.0 + 1e-6, de.max()
        assert (de > 1e-5).mean() < 1e-3
        # int8 saturating writes agree except at those ties
        diff = np.abs(np.asarray(got_w, np.int32) - np.asarray(ref_w, np.int32))
        assert (diff <= 1).all() and (diff > 0).mean() < 0.01

    def test_weights_saturate_6bit(self):
        R, C = 32, 128
        w = jnp.full((R, C), 60, jnp.int8)
        ac = jnp.full((R, C), 30.0)
        aa = jnp.zeros((R, C))
        got_w, _ = rstdp_update_pallas(
            w, ac, aa, jnp.zeros(C), jnp.ones(C), jnp.full((C,), 10.0),
            jnp.zeros((R, C)), eta=50.0, interpret=True)
        assert int(jnp.max(got_w)) == 63
        assert int(jnp.min(got_w)) >= 0


def test_vector_unit_uses_same_semantics():
    """The machine model's PPU read->rule->write path must agree with the
    fused kernel oracle on identical inputs (integration coherence)."""
    import dataclasses
    from repro.configs.bss2 import BSS2
    from repro.core.anncore import AnnCore
    from repro.core.ppu import VectorUnit
    from repro.verif.mismatch import ideal_instance

    cfg = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)
    inst = ideal_instance(cfg)
    core = AnnCore(cfg, inst)
    ppu = VectorUnit(cfg, inst)
    st = core.init_state()
    key = jax.random.PRNGKey(0)
    st = st._replace(
        syn=st.syn._replace(weights=jax.random.randint(key, (16, 16), 0, 64,
                                                       jnp.int8)),
        corr=st.corr._replace(a_causal=jax.random.uniform(key, (16, 16)) * 10))

    from repro.core import rules
    st2, _, obs = ppu.apply_rule(rules.stdp, st, {})
    got = np.asarray(st2.syn.weights)

    ref_w, _ = rstdp_update_ref(
        st.syn.weights, st.corr.a_causal, st.corr.a_acausal,
        inst["cadc_offset"], inst["cadc_gain"],
        jnp.ones((16,)), jnp.zeros((16, 16)), eta=0.0)
    # with eta=0 the fused kernel is a no-op quantization; the stdp rule
    # changes weights — just check both respect the 6-bit range
    assert got.min() >= 0 and got.max() <= 63
    assert np.asarray(ref_w).min() >= 0


def test_instance_sharding_demotes_odd_fleets_subprocess():
    """``instance_sharding`` must route through ``_pspec``'s divisibility
    demotion: a fleet that does not divide the data axis (or a column dim
    not divisible by ``model``) degrades to replicated on that dim instead
    of handing jit an invalid NamedSharding. 8 fake devices, mesh (4, 2)."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import ShardingCtx

ctx = ShardingCtx(mesh=make_smoke_mesh((4, 2)))

def spec(shape, cols=None):
    return ctx.instance_sharding(shape, cols=cols).spec

# divisible fleet + divisible cols: fully mapped
assert spec((8, 16, 4), cols=4) == jax.sharding.PartitionSpec(
    ("data",), None, "model"), spec((8, 16, 4), cols=4)
# odd fleet (6 % 4 != 0): instance dim demoted, cols still mapped
assert spec((6, 16, 4), cols=4)[0] is None
assert spec((6, 16, 4), cols=4)[2] == "model"
# odd cols (5 % 2 != 0): column dim demoted, fleet still mapped
assert spec((8, 16, 5), cols=5)[0] == ("data",)
assert spec((8, 16, 5), cols=5)[2] is None
# the demoted sharding must actually be placeable
x = jax.device_put(jnp.zeros((6, 16, 4)),
                   ctx.instance_sharding((6, 16, 4), cols=4))
assert x.sharding.is_equivalent_to(
    ctx.instance_sharding((6, 16, 4), cols=4), 3)
print("DEMOTE_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "DEMOTE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
