"""MC calibration (paper §3.2.2 / Fig. 4): binary-search trim of the STP
efficacy offset over virtual driver instances must collapse the offset
distribution, pre-"tapeout"."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bss2 import BSS2
from repro.verif.calibration import (binary_search_calibrate, calibrate_stp,
                                     measure_stp_offset)


def test_fig4_offset_distribution_narrows():
    # 128 virtual driver instances, as in the paper's Fig. 4
    key = jax.random.PRNGKey(42)
    offsets = BSS2.mismatch.sigma_stp_offset * jax.random.normal(key, (128,))
    codes, metrics = calibrate_stp(BSS2, offsets)
    assert float(metrics["std_after"]) < 0.4 * float(metrics["std_before"]), \
        (float(metrics["std_before"]), float(metrics["std_after"]))
    # residual offset bounded by the 4-bit trim resolution
    from repro.core.stp import CALIB_STEP
    assert float(metrics["max_abs_after"]) <= 4 * CALIB_STEP + 1e-6 or \
        float(jnp.mean(jnp.abs(metrics["after"]))) < CALIB_STEP


def test_calibration_is_deterministic():
    key = jax.random.PRNGKey(7)
    offsets = 0.25 * jax.random.normal(key, (32,))
    c1, _ = calibrate_stp(BSS2, offsets)
    c2, _ = calibrate_stp(BSS2, offsets)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_binary_search_hits_known_target():
    """Linear measure = 10 - code, decreasing; the search returns the
    largest code whose measurement stays above target: 9 (val=1); code 10
    hits exactly 0 and is rejected — residual < 1 LSB either way."""
    def measure(code):
        return 10.0 - code.astype(jnp.float32)
    code = binary_search_calibrate(measure, bits=4, shape=(3,), target=0.0,
                                   increasing=False)
    np.testing.assert_array_equal(np.asarray(code), [9, 9, 9])
    residual = np.asarray(measure(code + 1))
    assert (np.abs(residual) <= 1.0).all()


def test_measure_monotone_in_code():
    offs = jnp.zeros((1,))
    vals = [float(measure_stp_offset(BSS2, offs,
                                     jnp.full((1,), c, jnp.int32))[0])
            for c in range(16)]
    assert all(a > b for a, b in zip(vals, vals[1:])), vals
