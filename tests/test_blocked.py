"""Blocked-backend equivalence: the time-blocked neuron window must be
BIT-IDENTICAL to the per-dt oracle.

Unlike the fused suite's float tolerances, spikes here are asserted with
exact equality: the blocked restructuring (separate synaptic-current trace
scan, packed-carry block scan, rate counters summed outside the loop, the
VMEM-resident Pallas kernel) reuses the oracle's per-step op trees
(``adex.integrate_currents``/``membrane_step``) verbatim, so nothing may
drift — across block sizes, window lengths that do not divide the block,
instance prefixes, and the kernel in interpret mode.

``ANNCORE_KERNEL_IMPL`` (default "auto") forces the kernel impl for the
main equivalence class — the tier-2 CI job sets "interpret" to run the
whole suite through the actual Pallas kernels.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import adex
from repro.core.anncore import AnnCore
from repro.verif.mismatch import sample_instance

CFG = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)
KERNEL_IMPL = os.environ.get("ANNCORE_KERNEL_IMPL", "auto")
TOL = dict(rtol=1e-4, atol=1e-4)


def _events(T, prefix, key=0, p=0.15, n_addr=4):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    ev = (jax.random.uniform(k1, (T, *prefix, CFG.n_rows)) < p
          ).astype(jnp.float32)
    ad = jax.random.randint(k2, (T, *prefix, CFG.n_rows), 0, n_addr,
                            jnp.int8)
    return ev, ad


def _cores(prefix, **kw):
    inst = sample_instance(CFG, jax.random.PRNGKey(0), prefix)
    oracle = AnnCore(CFG, inst, backend="oracle")
    fused = AnnCore(CFG, inst, backend="fused", kernel_impl=KERNEL_IMPL)
    blocked = AnnCore(CFG, inst, backend="blocked",
                      kernel_impl=KERNEL_IMPL, **kw)
    st = oracle.init_state(prefix)
    kw_, ka = jax.random.split(jax.random.PRNGKey(9))
    st = st._replace(syn=st.syn._replace(
        weights=jax.random.randint(kw_, (*prefix, CFG.n_rows, CFG.n_cols),
                                   20, 64, jnp.int8),
        addresses=jax.random.randint(ka, (*prefix, CFG.n_rows, CFG.n_cols),
                                     0, 4, jnp.int8)))
    return oracle, fused, blocked, st


def _assert_state_close(s1, s2):
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **TOL)


class TestBlockedEquivalence:
    @pytest.mark.parametrize("block_size", [4, 8, 16])
    def test_spikes_bit_identical_to_oracle(self, block_size):
        oracle, _, blocked, st = _cores((), block_size=block_size)
        ev, ad = _events(200, ())
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(blocked.run)(st, ev, ad)
        assert float(o1["spikes"].sum()) > 0, "drive must elicit spikes"
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        _assert_state_close(s1, s2)

    @pytest.mark.parametrize("T,block_size,trace_block",
                             [(200, 7, 9), (101, 16, 16), (50, 64, 64)])
    def test_window_not_divisible_by_block(self, T, block_size, trace_block):
        """Tails (T % block != 0, even block > T) run through the same
        per-step functions and stay bit-exact."""
        oracle, _, blocked, st = _cores((), block_size=block_size,
                                        trace_block=trace_block,
                                        kernel_block=16)
        ev, ad = _events(T, (), key=1)
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(blocked.run)(st, ev, ad)
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        _assert_state_close(s1, s2)

    def test_record_v(self):
        oracle, _, blocked, st = _cores(())
        ev, ad = _events(150, (), key=2)
        s1, o1 = jax.jit(lambda s, e, a: oracle.run(s, e, a, True))(
            st, ev, ad)
        s2, o2 = jax.jit(lambda s, e, a: blocked.run(s, e, a, True))(
            st, ev, ad)
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        np.testing.assert_allclose(np.asarray(o1["v"]),
                                   np.asarray(o2["v"]), **TOL)
        _assert_state_close(s1, s2)

    def test_batched_instance_prefix(self):
        """A fleet of instances rides the kernels' instance grid axis (or
        the ref path's native broadcasting) — still bit-exact spikes."""
        prefix = (3,)
        oracle, fused, blocked, st = _cores(prefix)
        ev, ad = _events(150, prefix, key=3)
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(blocked.run)(st, ev, ad)
        s3, o3 = jax.jit(fused.run)(st, ev, ad)
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o3["spikes"]), **TOL)
        _assert_state_close(s1, s2)

    def test_matches_fused_backend(self):
        """blocked == fused == oracle on one stream (three-way lockstep)."""
        oracle, fused, blocked, st = _cores(())
        ev, ad = _events(120, (), key=4)
        _, o1 = jax.jit(oracle.run)(st, ev, ad)
        _, o2 = jax.jit(fused.run)(st, ev, ad)
        _, o3 = jax.jit(blocked.run)(st, ev, ad)
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o3["spikes"]))
        np.testing.assert_allclose(np.asarray(o2["spikes"]),
                                   np.asarray(o3["spikes"]), **TOL)


class TestBlockedKernelInterpret:
    """The Pallas neuron_scan kernel itself (interpret mode on CPU):
    VMEM-resident state across time blocks, instance grid axis, in-kernel
    tail masking."""

    @pytest.mark.parametrize("prefix", [(), (2,)])
    @pytest.mark.parametrize("T", [48, 50])
    def test_kernel_matches_oracle(self, prefix, T):
        oracle, _, _, st = _cores(prefix)
        blocked = AnnCore(CFG, oracle.inst, backend="blocked",
                          kernel_impl="interpret", kernel_block=16)
        ev, ad = _events(T, prefix, key=5, p=0.25)
        s1, o1 = oracle.run(st, ev, ad, record_v=True)
        s2, o2 = blocked.run(st, ev, ad, record_v=True)
        np.testing.assert_array_equal(np.asarray(o1["spikes"]),
                                      np.asarray(o2["spikes"]))
        np.testing.assert_allclose(np.asarray(o1["v"]),
                                   np.asarray(o2["v"]), atol=1e-3)
        np.testing.assert_array_equal(np.asarray(s1.rate_counters),
                                      np.asarray(s2.rate_counters))

    def test_ops_direct_ref_vs_interpret(self):
        """The neuron_window op: blocked jnp ref vs the kernel in
        interpret mode, bit-exact spikes + matching final state."""
        from repro.kernels.neuron_scan import ops as neuron_ops
        prefix = (2,)
        inst = sample_instance(CFG, jax.random.PRNGKey(0), prefix)
        params = inst["neuron_params"]
        T, C = 50, CFG.n_cols
        ks = jax.random.split(jax.random.PRNGKey(6), 2)
        ie = jax.random.uniform(ks[0], (T, *prefix, C)) * 120.0
        ii = jax.random.uniform(ks[1], (T, *prefix, C)) * 60.0
        st = adex.init_state((*prefix, C), params)
        rc = jnp.zeros((*prefix, C))
        outs = {}
        for impl in ("ref", "interpret"):
            outs[impl] = neuron_ops.neuron_window(
                st, rc, ie, ii, params, dt=CFG.dt,
                use_adex=CFG.neuron.adex, impl=impl, kernel_block=16,
                record_v=True)
        np.testing.assert_array_equal(np.asarray(outs["ref"][2][0]),
                                      np.asarray(outs["interpret"][2][0]))
        np.testing.assert_array_equal(np.asarray(outs["ref"][1]),
                                      np.asarray(outs["interpret"][1]))
        for a, b in zip(outs["ref"][0], outs["interpret"][0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)

    def test_rate_counters_exact_integer(self):
        """rc leaves the loop as a sum — must equal the per-step chain
        exactly (integer-valued f32)."""
        oracle, _, blocked, st = _cores(())
        ev, ad = _events(200, (), key=7, p=0.3)
        s1, _ = jax.jit(oracle.run)(st, ev, ad)
        s2, _ = jax.jit(blocked.run)(st, ev, ad)
        np.testing.assert_array_equal(np.asarray(s1.rate_counters),
                                      np.asarray(s2.rate_counters))
        assert float(s1.rate_counters.sum()) > 0


class TestBlockedTraining:
    def test_blocked_scan_matches_fused_scan(self):
        """run_training on the blocked backend == fused backend (same
        seeds, whole-experiment lax.scan composes with time blocks)."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, s1, _ = run_training(n_trials=8, seed=5, ecfg=ecfg,
                                 backend="fused")
        o2, s2, _ = run_training(n_trials=8, seed=5, ecfg=ecfg,
                                 backend="blocked")
        np.testing.assert_allclose(o1["w_signed_final"],
                                   o2["w_signed_final"], **TOL)
        np.testing.assert_allclose(o1["reward"], o2["reward"], **TOL)
        np.testing.assert_allclose(o1["rates"], o2["rates"], **TOL)

    def test_blocked_scan_matches_blocked_dispatch(self):
        """Scan-over-trials vs per-trial dispatch on the SAME blocked
        backend: identical RNG path, bit-identical observables."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, _, _ = run_training(n_trials=7, seed=6, ecfg=ecfg,
                                backend="blocked", scan=True)
        o2, _, _ = run_training(n_trials=7, seed=6, ecfg=ecfg,
                                backend="blocked", scan=False)
        np.testing.assert_allclose(o1["w_signed_final"],
                                   o2["w_signed_final"], **TOL)
        np.testing.assert_array_equal(o1["stim"], o2["stim"])
        np.testing.assert_allclose(o1["mean_reward"], o2["mean_reward"],
                                   **TOL)

    def test_block_size_threads_through_run_training(self):
        """The block-size knob reaches the core and odd sizes (trial_steps
        not divisible) still reproduce the fused result."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, _, meta = run_training(n_trials=5, seed=7, ecfg=ecfg,
                                   backend="blocked", block_size=7,
                                   trace_block=9, kernel_block=16)
        assert meta["core"].block_size == 7
        assert meta["core"].trace_block == 9
        assert meta["core"].kernel_block == 16
        o2, _, _ = run_training(n_trials=5, seed=7, ecfg=ecfg,
                                backend="fused")
        np.testing.assert_allclose(o1["w_signed_final"],
                                   o2["w_signed_final"], **TOL)
