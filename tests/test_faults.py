"""Fault injection + defect tolerance (``repro.faults``).

Four contracts:

  1. OFF is free: ``faults=None`` is the identity on every hook — the
     disabled program is the SAME jaxpr as before the subsystem existed,
     on every backend, and outputs are bit-identical.
  2. Injection is backend-consistent: the same ``FaultPlan`` produces
     ``assert_array_equal``-identical spikes / rates / weights on
     oracle, fused and blocked backends (dense and sparse synaptic
     paths), and the independent NumPy reference models the same defect
     realisation (playback co-simulation under faults).
  3. Graceful degradation is exact: screening recovers the planted
     sites, and emulating the faulted chip under its blacklist is
     bit-identical to emulating the clean reduced network — provided
     the blacklist covers the fault sites (the reduction dominates).
  4. Link failover is accounted: a dead link's traffic re-arrives over
     the reroute forwards exactly one window late, counted in
     ``link_reroutes`` — and the §5 closed loop still learns once
     screening + blacklisting run.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core.anncore import AnnCore
from repro.core.ppu import VectorUnit
from repro.faults import (Blacklist, FaultPlan, cadc_zero_code, chain,
                          remap_link_faults, sample_fault_plan, screen,
                          screen_chip, screen_links)
from repro.obs import trace as obs_trace
from repro.verif.mismatch import sample_instance
from repro.wafer import (InterChipRouter, WaferTopology, make_plan,
                         reroute_plan, s5_column_plan)

R, C, T = 16, 8, 48
BACKENDS = ("oracle", "fused", "blocked")


def _cfg():
    return dataclasses.replace(BSS2.reduced(), n_rows=R, n_cols=C)


def _inst(cfg, prefix=()):
    return sample_instance(cfg, jax.random.PRNGKey(0), prefix)


def _events(key=1, p=0.25, t=T):
    ev = (jax.random.uniform(jax.random.PRNGKey(key), (t, R)) < p
          ).astype(jnp.float32)
    return ev, jnp.zeros((t, R), jnp.int8)


def _covered_plan(rng):
    """A defect realisation whose every site lies on a row/column the
    commissioning probes blacklist — the precondition of the exactness
    contract (faults outside the blacklist legitimately change the
    dynamics and cannot be masked away)."""
    dead_rows = np.zeros(R, bool)
    dead_rows[[2, 7, 11]] = True
    hot = np.zeros(C, bool)
    hot[1] = True
    dead_n = np.zeros(C, bool)
    dead_n[5] = True
    badcol = hot | dead_n
    sw_mask = np.zeros((R, C), bool)
    sw_mask[dead_rows] = rng.random((3, C)) < 0.5
    sw_mask[:, badcol] |= rng.random((R, 2)) < 0.5
    sf = np.where(sw_mask, 1 << rng.integers(0, 6, (R, C)), 0)
    return FaultPlan(
        dead_rows=dead_rows, hot_neurons=hot, dead_neurons=dead_n,
        stuck_w_mask=sw_mask,
        stuck_w_val=rng.integers(0, 64, (R, C)).astype(np.int8),
        cadc_stuck_mask=badcol,
        cadc_stuck_code=rng.integers(0, 256, C).astype(np.int32),
        store_flip=sf.astype(np.int32))


class TestModel:
    def test_plan_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(stuck_w_mask=np.zeros((R, C), bool))  # no value
        with pytest.raises(ValueError):
            FaultPlan(cadc_stuck_code=np.zeros(C, np.int32))
        with pytest.raises(AssertionError):
            FaultPlan(stuck_w_mask=np.ones((R, C), bool),
                      stuck_w_val=np.full((R, C), 64))      # 7-bit value
        with pytest.raises(AssertionError):
            FaultPlan(flaky_links=np.array([1.5]))

    def test_chain_and_site_census(self):
        fp = FaultPlan(dead_rows=np.eye(1, R, 3, dtype=bool)[0])
        assert fp.total_sites == 1 and fp.n_dead_rows == 1
        assert chain(None, None) is None
        assert chain(fp) == (fp,)
        assert chain(fp, (fp, None), None) == (fp, fp)
        assert "dead_rows" in fp.summary()

    def test_sample_plan_rates(self):
        rng = np.random.default_rng(0)
        fp = sample_fault_plan(256, 256, rng, p_dead_row=0.1,
                               p_stuck_w=0.01, n_links=16, p_dead_link=0.5,
                               p_flaky_link=0.5, flaky_drop=0.25)
        assert 10 <= fp.n_dead_rows <= 45
        assert fp.stuck_w_val is not None
        # dead wins over flaky on the same link
        assert not (fp.dead_links & (fp.flaky_links > 0)).any()

    def test_remap_link_faults(self):
        old = WaferTopology(3, "ring").links()
        new = WaferTopology(3, "all2all").links()
        fp = FaultPlan(dead_links=np.array([False, True, False]),
                       flaky_links=np.array([0.5, 0.0, 0.0], np.float32))
        fp2 = remap_link_faults(fp, old, new)
        assert fp2.dead_links[new.index((1, 2))]
        assert fp2.dead_links.sum() == 1
        assert fp2.flaky_links[new.index((0, 1))] == np.float32(0.5)


class TestOffPath:
    """faults=None must be the SAME program, bit for bit."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("sparse", ("never", "always"))
    def test_same_jaxpr_and_outputs(self, backend, sparse):
        cfg = _cfg()
        inst = _inst(cfg)
        base = AnnCore(cfg, inst, backend=backend, sparse_mode=sparse)
        off = AnnCore(cfg, inst, backend=backend, sparse_mode=sparse,
                      faults=None)
        st = base.init_state()
        ev, ad = _events()
        assert str(jax.make_jaxpr(base.run)(st, ev, ad)) == \
            str(jax.make_jaxpr(off.run)(st, ev, ad))
        s_a, o_a = jax.jit(base.run)(st, ev, ad)
        s_b, o_b = jax.jit(off.run)(st, ev, ad)
        np.testing.assert_array_equal(np.asarray(o_a["spikes"]),
                                      np.asarray(o_b["spikes"]))
        np.testing.assert_array_equal(np.asarray(s_a.rate_counters),
                                      np.asarray(s_b.rate_counters))

    def test_vector_unit_off_is_identity(self):
        from repro.ppuvm import programs
        cfg = _cfg()
        inst = _inst(cfg)
        core = AnnCore(cfg, inst)
        st, _ = jax.jit(core.run)(core.init_state(), *_events())
        words = jnp.asarray(programs.rstdp_program(eta=8.0))
        base = VectorUnit(cfg, inst)
        off = VectorUnit(cfg, inst, faults=None)
        fn = lambda p: p.run_program_fixed(st, words)[0].syn.weights
        assert str(jax.make_jaxpr(lambda: fn(base))()) == \
            str(jax.make_jaxpr(lambda: fn(off))())

    def test_router_off_is_identity(self):
        plan = s5_column_plan(4, R // 2, 16)
        base = InterChipRouter(plan)
        off = InterChipRouter(plan, faults=None)
        sp = (jax.random.uniform(jax.random.PRNGKey(2), (8, 4, 4)) < 0.4
              ).astype(jnp.float32)
        assert str(jax.make_jaxpr(base.route)(sp)) == \
            str(jax.make_jaxpr(off.route)(sp))


class TestInjection:
    def test_backend_consistent(self):
        cfg = _cfg()
        inst = _inst(cfg)
        rng = np.random.default_rng(0)
        fp = sample_fault_plan(R, C, rng, p_dead_row=0.2, p_dead_neuron=0.2,
                               p_hot_neuron=0.1, p_stuck_w=0.05, p_cadc=0.2)
        ev, ad = _events()
        outs = {}
        for be in BACKENDS:
            for sparse in ("never", "always"):
                # full event capacity: "always" must not drop anything
                c = AnnCore(cfg, inst, backend=be, sparse_mode=sparse,
                            sparse_max_events=T * R, sparse_k_cap=R,
                            faults=fp)
                s, o = jax.jit(c.run)(c.init_state(), ev, ad)
                outs[(be, sparse)] = (np.asarray(o["spikes"]),
                                      np.asarray(s.rate_counters))
        ref = outs[("oracle", "never")]
        for k, (sp, rc) in outs.items():
            np.testing.assert_array_equal(ref[0], sp, err_msg=str(k))
            np.testing.assert_array_equal(ref[1], rc, err_msg=str(k))
        # semantics: hot columns always fire, dead never; counters agree
        sp, rc = ref
        assert (sp[:, np.asarray(fp.hot_neurons)] == 1.0).all()
        assert (sp[:, np.asarray(fp.dead_neurons)] == 0.0).all()
        np.testing.assert_array_equal(rc, sp.sum(0))

    def test_stuck_weights_analog_only(self):
        """Stuck cells corrupt the crossbar READ; the stored digital
        state (what the PPU reads back) is untouched."""
        cfg = _cfg()
        inst = _inst(cfg)
        mask = np.zeros((R, C), bool)
        mask[::2] = True
        fp = FaultPlan(stuck_w_mask=mask,
                       stuck_w_val=np.zeros((R, C), np.int8))
        w0 = np.random.default_rng(1).integers(30, 60, (R, C)).astype(np.int8)
        c = AnnCore(cfg, inst, faults=fp)
        st = c.init_state()
        st = st._replace(syn=st.syn._replace(weights=jnp.asarray(w0)))
        st, out = jax.jit(c.run)(st, *_events())
        np.testing.assert_array_equal(np.asarray(st.syn.weights), w0)
        # all-even-rows-stuck-at-zero kills the excitatory drive entirely
        assert np.asarray(out["spikes"]).sum() == 0

    def test_cadc_and_store_hooks(self):
        from repro.ppuvm import programs
        cfg = _cfg()
        inst = _inst(cfg)
        off = np.full(C, 7, np.int32)
        stuck = np.zeros(C, bool)
        stuck[3] = True
        code = np.full(C, 200, np.int32)
        flip = np.zeros((R, C), np.int32)
        flip[0, :] = 1
        zero = np.zeros((R, C), bool)
        zero[1, :] = True
        fp = FaultPlan(cadc_code_offset=off, cadc_stuck_mask=stuck,
                       cadc_stuck_code=code, store_flip=flip,
                       store_zero=zero)
        core = AnnCore(cfg, inst)
        st, _ = jax.jit(core.run)(core.init_state(), *_events())
        clean = VectorUnit(cfg, inst)
        faulted = VectorUnit(cfg, inst, faults=fp)
        qc0, _ = clean.read_correlation(st.corr)
        qc1, _ = faulted.read_correlation(st.corr)
        exp = np.clip(np.asarray(qc0) + 7, 0, 255)
        exp[:, 3] = 200
        np.testing.assert_array_equal(np.asarray(qc1), exp)
        words = jnp.asarray(programs.rstdp_program(eta=0.0))  # dw == 0
        w0 = np.asarray(st.syn.weights)
        st2, _ = jax.jit(lambda s: faulted.run_program_fixed(s, words))(st)
        w1 = np.asarray(st2.syn.weights)
        np.testing.assert_array_equal(w1[0], w0[0] ^ 1)
        np.testing.assert_array_equal(w1[1], np.zeros(C, np.int8))
        np.testing.assert_array_equal(w1[2:], w0[2:])

    def test_cosim_ref_models_same_faults(self):
        """Playback co-simulation under a fault overlay: the independent
        NumPy reference and the jitted machine model produce matching
        traces for the SAME defect realisation."""
        from repro.ppuvm import programs
        from repro.verif import playback as pb
        cfg = _cfg()
        rng = np.random.default_rng(2)
        fp = _covered_plan(rng)
        # unambiguous pulse stimuli (see tests/test_playback.py: chaotic
        # spiking diverges between two correct fp32 backends, so co-sim
        # drives the DUT robustly suprathreshold)
        w = np.full((R, C), 50, np.int8)
        ev = np.zeros((120, R), np.float32)
        ev[10] = 1.0
        ev[60] = 1.0
        ev[100, ::2] = 1.0
        prog = [pb.write_weights(w), pb.inject(ev), pb.read_rates(),
                pb.read_corr(), pb.read_v(),
                pb.write_ppu_program(programs.rstdp_program(eta=8.0)),
                pb.ppu_run(mod=rng.uniform(-1, 1, (2, C)).astype(np.float32)),
                pb.read_weights()]
        tf = pb.execute(prog, "fast", cfg, faults=fp)
        tr = pb.execute(prog, "ref", cfg, faults=fp)
        errs = pb.compare_traces(tf, tr, atol=0.05)
        assert errs == [], "\n".join(errs)
        # the faults visibly shaped the trace: dead rows kill their
        # correlation columns vs a clean run
        clean = pb.execute(prog, "fast", cfg)
        (_, _, q_f), = [t for t in tf if t[1] == "CORR"][:1]
        (_, _, q_c), = [t for t in clean if t[1] == "CORR"][:1]
        assert not np.array_equal(q_f, q_c)


class TestBlacklist:
    def test_screening_recovers_planted_sites(self):
        cfg = _cfg()
        inst = _inst(cfg)
        rng = np.random.default_rng(0)
        fp = _covered_plan(rng)
        bl = screen_chip(AnnCore(cfg, inst, faults=fp),
                         VectorUnit(cfg, inst, faults=fp))
        np.testing.assert_array_equal(bl.rows, np.asarray(fp.dead_rows))
        np.testing.assert_array_equal(
            bl.neurons,
            np.asarray(fp.hot_neurons) | np.asarray(fp.dead_neurons))

    def test_screening_clean_chip_is_empty(self):
        cfg = _cfg()
        inst = _inst(cfg)
        bl = screen_chip(AnnCore(cfg, inst), VectorUnit(cfg, inst))
        assert bl.total == 0

    def test_cadc_zero_code(self):
        cfg = _cfg()
        inst = _inst(cfg)
        core = AnnCore(cfg, inst)
        ppu = VectorUnit(cfg, inst)
        qc, qa = ppu.read_correlation(core.init_state().corr)
        base = cadc_zero_code(inst, cfg.cadc_bits)
        np.testing.assert_array_equal(
            np.asarray(qc), np.broadcast_to(base, (R, C)))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_reduction_exactness(self, backend):
        """Faulted chip under its blacklist == clean reduced network,
        bit for bit, through emulation + a PPU-VM store."""
        from repro.ppuvm import programs
        cfg = _cfg()
        inst = _inst(cfg)
        rng = np.random.default_rng(3)
        fp = _covered_plan(rng)
        bl = screen_chip(AnnCore(cfg, inst, faults=fp),
                         VectorUnit(cfg, inst, faults=fp))
        red = bl.as_faults(inst, cfg.cadc_bits)
        cov = bl.rows[:, None] | bl.neurons[None, :]
        assert (~np.asarray(fp.stuck_w_mask) | cov).all()
        words = jnp.asarray(programs.rstdp_program(eta=8.0))
        w0 = jnp.asarray(rng.integers(0, 64, (R, C)), jnp.int8)
        ev, ad = _events()

        def run_with(ov):
            c = AnnCore(cfg, inst, backend=backend, faults=ov)
            p = VectorUnit(cfg, inst, faults=ov)
            st = c.init_state()
            st = st._replace(syn=st.syn._replace(weights=w0))
            st, out = jax.jit(c.run)(st, ev, ad)
            st2, _ = jax.jit(
                lambda s: p.run_program_fixed(s, words))(st)
            return (np.asarray(out["spikes"]),
                    np.asarray(st.rate_counters),
                    np.asarray(st2.syn.weights))

        for x, y in zip(run_with(chain(fp, red)), run_with(chain(red))):
            np.testing.assert_array_equal(x, y)

    def test_reduction_counters(self):
        cfg = _cfg()
        inst = _inst(cfg)
        rng = np.random.default_rng(3)
        fp = _covered_plan(rng)
        bl = screen_chip(AnnCore(cfg, inst, faults=fp),
                         VectorUnit(cfg, inst, faults=fp))
        ov = chain(fp, bl.as_faults(inst, cfg.cadc_bits))
        c = AnnCore(cfg, inst, faults=ov)
        tele = obs_trace.init_telemetry()
        _, out = jax.jit(lambda s, e, a: c.run(s, e, a, telemetry=tele))(
            c.init_state(), *_events())
        s = obs_trace.summary(out["telemetry"])
        assert s["faults_injected"] == fp.total_sites
        assert s["faults_detected"] == bl.as_faults(inst).total_sites
        assert s["blacklisted_rows"] == bl.n_rows == 3


class TestLinkFailover:
    def _sp(self, K, C_loc, t=8, key=0, p=0.4):
        return (jax.random.uniform(jax.random.PRNGKey(key), (t, K, C_loc))
                < p).astype(jnp.float32)

    def test_dead_link_traffic_rearrives_and_is_counted(self):
        plan = s5_column_plan(4, R // 2, 16)
        dead = (0, 2)
        p2, n_re = reroute_plan(plan, [dead])
        assert n_re == 4 and p2.n_forwards == 4
        assert p2.n_routes == plan.n_routes - 4
        fp = FaultPlan(dead_links=np.array(
            [sd == dead for sd in plan.topology.links()]))
        r_clean = InterChipRouter(plan)
        r_fail = InterChipRouter(p2, faults=fp)
        sp1 = self._sp(4, 4)
        silent = jnp.zeros_like(sp1)
        tele = obs_trace.init_telemetry()
        g1c, _ = r_clean.route(sp1)
        g1f, tele = r_fail.route(sp1, tele, routed_in=r_fail.init_buffer(8))
        g2f, tele = r_fail.route(silent, tele, routed_in=g1f)
        g1c, g1f, g2f = map(np.asarray, (g1c, g1f, g2f))
        missing = np.maximum(g1c[:, 2] - g1f[:, 2], 0.0)
        assert missing.sum() > 0
        # the dead link's deliveries re-arrive exactly one window late
        np.testing.assert_array_equal(g2f[:, 2], missing)
        s = obs_trace.summary(tele)
        assert s["link_reroutes"] == int((missing > 0).sum())
        assert s["faults_injected"] == 1

    def test_route_requires_routed_in_on_failover_plans(self):
        p2, _ = reroute_plan(s5_column_plan(4, R // 2, 16), [(0, 2)])
        with pytest.raises(ValueError, match="routed_in"):
            InterChipRouter(p2).route(self._sp(4, 4))

    def test_ring_promotes_to_all2all(self):
        topo = WaferTopology(3, "ring")
        plan = make_plan(topo, 4, 2, [(0, 0, 1, 0, 7), (1, 1, 2, 1, 9),
                                      (2, 0, 0, 2, 11)])
        p2, n = reroute_plan(plan, [(1, 2)])
        assert n == 1 and p2.topology.kind == "all2all"
        assert p2.n_forwards == 1
        # the relay hop rides alive links only
        fl = (int(p2.fwd_src_chip[0]), int(p2.fwd_dst_chip[0]))
        assert fl != (1, 2)

    def test_reroute_raises_when_impossible(self):
        plan = make_plan(WaferTopology(2, "all2all"), 4, 2,
                         [(0, 0, 1, 0, 7)])
        with pytest.raises(ValueError, match="no failover"):
            reroute_plan(plan, [(0, 1)])

    def test_flaky_link_drops_deterministically(self):
        plan = s5_column_plan(2, R // 2, 16)
        fl = np.zeros(len(plan.topology.links()), np.float32)
        fl[0] = 0.5
        fp = FaultPlan(flaky_links=fl, seed=4)
        r = InterChipRouter(plan, faults=fp)
        sp = jnp.ones((64, 2, 8), jnp.float32)
        n1 = np.asarray(r.link_census(sp))
        n2 = np.asarray(r.link_census(sp))
        np.testing.assert_array_equal(n1, n2)
        n_clean = np.asarray(InterChipRouter(plan).link_census(sp))
        frac = n1[0] / n_clean[0]
        assert 0.3 < frac < 0.7, frac
        np.testing.assert_array_equal(n1[1:], n_clean[1:])

    def test_screen_links_finds_dead_and_flaky(self):
        plan = s5_column_plan(4, R // 2, 16)
        links = plan.topology.links()
        dl = np.array([sd == (0, 2) for sd in links])
        fl = np.where([sd == (1, 3) for sd in links],
                      np.float32(0.5), np.float32(0.0))
        r = InterChipRouter(plan, faults=FaultPlan(dead_links=dl,
                                                   flaky_links=fl))
        assert set(screen_links(r)) == {(0, 2), (1, 3)}

    def test_screen_full_pass_with_router(self):
        cfg = _cfg()
        inst = _inst(cfg)
        plan = s5_column_plan(4, R // 2, 16)
        dl = np.array([sd == (3, 1) for sd in plan.topology.links()])
        fp = FaultPlan(dead_links=dl)
        bl = screen(AnnCore(cfg, inst, faults=fp),
                    VectorUnit(cfg, inst, faults=fp),
                    router=InterChipRouter(plan, faults=fp))
        assert bl.links == ((3, 1),)
        assert bl.n_rows == 0 and bl.n_neurons == 0


class TestClosedLoop:
    """§5 R-STDP still learns under injected faults once screening and
    blacklisting run (the paper's commissioning promise)."""

    def test_recovery_under_faults(self):
        from repro.core.hybrid import run_training
        rng = np.random.default_rng(3)
        fp = sample_fault_plan(32, 16, rng, p_dead_row=0.06,
                               p_hot_neuron=0.25, p_cadc=0.12, seed=1)
        assert fp.total_sites >= 3
        n, tail = 200, 60

        def trailing(mr, cols=slice(None)):
            return float(np.mean(mr[-tail:, cols]))

        out_c, _, _ = run_training(n_trials=n, seed=1)
        out_f, _, meta = run_training(n_trials=n, seed=1, faults=fp)
        bl = screen(meta["core"], meta["ppu"])
        assert bl.total > 0
        out_b, _, _ = run_training(n_trials=n, seed=1, faults=fp,
                                   blacklist=bl)
        healthy = ~bl.neurons
        clean = trailing(out_c["mean_reward"])
        naive = trailing(out_f["mean_reward"])
        screened = trailing(out_b["mean_reward"], healthy)
        # faults visibly degrade the naive all-column reward; after
        # screening the healthy-column reward recovers to near-clean
        assert naive < clean - 0.03, (naive, clean)
        assert screened > naive + 0.03, (screened, naive)
        assert screened > clean - 0.05, (screened, clean)

    def test_wafer_blacklisted_link_reroutes_and_learns(self):
        from repro.core.hybrid import run_training
        bl = Blacklist(rows=np.zeros((4, 32), bool),
                       neurons=np.zeros((4, 4), bool),
                       links=((0, 2),))
        out, state, meta = run_training(n_trials=120, seed=1, wafer=4,
                                        telemetry=True, blacklist=bl)
        assert meta["router"].plan.n_forwards == 4
        tl = out["telemetry"]
        assert int(tl["link_reroutes"]) > 0
        # the rerouted wafer still learns: trailing reward beats the
        # opening trials
        mr = out["mean_reward"]
        assert float(np.mean(mr[-30:])) > float(np.mean(mr[:30])) + 0.05


def test_sharded_link_faults_match_local_subprocess():
    """Link faults and failover forwards are bit-identical under the
    local and shard_map transports (8 fake CPU devices)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.wafer import s5_column_plan, reroute_plan, InterChipRouter
from repro.faults import FaultPlan
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import ShardingCtx
from repro.obs import trace as obs

ctx = ShardingCtx(mesh=make_smoke_mesh((4, 2)))
plan = s5_column_plan(4, 8, 16)
links = plan.topology.links()
p2, _ = reroute_plan(plan, [(0, 2)])
fl = np.where([sd == (1, 3) for sd in links], np.float32(0.5),
              np.float32(0.0))
fp = FaultPlan(dead_links=np.array([sd == (0, 2) for sd in links]),
               flaky_links=fl, seed=4)
sp = (jax.random.uniform(jax.random.PRNGKey(0), (16, 4, 4)) < 0.4
      ).astype(jnp.float32)

def windows(router):
    tele = obs.init_telemetry()
    routed = router.init_buffer(16)
    outs = []
    for _ in range(3):
        routed, tele = jax.jit(router.route)(sp, tele, routed_in=routed)
        outs.append(np.asarray(routed))
    s = obs.summary(tele)
    return outs, s["link_reroutes"], s["routed_events"]

g_l, re_l, n_l = windows(InterChipRouter(p2, faults=fp))
r_sh = InterChipRouter(p2, ctx=ctx, faults=fp)
assert r_sh._axis == "data", r_sh._axis
g_s, re_s, n_s = windows(r_sh)
for a, b in zip(g_l, g_s):
    np.testing.assert_array_equal(a, b)
assert re_l == re_s and n_l == n_s and re_l > 0
print("FAULT_SHARDED_OK", re_l, n_l)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "FAULT_SHARDED_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
