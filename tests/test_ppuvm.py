"""PPU-VM subsystem tests (ISSUE 2 tentpole).

Three layers, mirroring the paper's verification strategy:

  1. per-opcode fracsat semantics: JAX executor == NumPy executor ==
     a python oracle, bit-exact (unit/testbench level, §3.2);
  2. ISA programs vs their ``repro.core.rules`` float oracles through
     ``VectorUnit`` (integration level) — equality within one 6-bit
     weight LSB;
  3. playback co-simulation: the SAME program words execute on the fast
     JAX backend and the independent NumPy backend with a
     ``compare_traces`` PASS (system level, §3.1) — and the VM R-STDP
     program inside the jitted training scan matches the fixed-function
     ``apply_rstdp`` path.

``PPUVM_KERNEL_IMPL`` selects the AnnCore kernel impl for the emulation
windows (CI runs the suite a second time with ``interpret`` so the VM
stays backend-agnostic w.r.t. the Pallas kernels around it).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import rules
from repro.core.anncore import AnnCore
from repro.core.ppu import VectorUnit
from repro.ppuvm import interp, isa, programs
from repro.ppuvm.asm import Asm
from repro.verif.mismatch import sample_instance

KERNEL_IMPL = os.environ.get("PPUVM_KERNEL_IMPL", "auto")

CFG = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)


def _rand_operands(seed=0, r=4, c=4):
    rng = np.random.RandomState(seed)
    return dict(
        weights=rng.randint(0, 64, (r, c)).astype(np.int32),
        qc=rng.randint(0, 256, (r, c)).astype(np.int32),
        qa=rng.randint(0, 256, (r, c)).astype(np.int32),
        rates=rng.randint(0, 30, (c,)).astype(np.float32),
        mod=isa.to_fixed(rng.uniform(-1, 1, (2, c))),
        noise=isa.to_fixed(0.3 * rng.randn(r, c)),
    )


def _run_both(words, ops):
    wj, rj = interp.run_program_jax(
        jnp.asarray(words), jnp.asarray(ops["weights"]),
        jnp.asarray(ops["qc"]), jnp.asarray(ops["qa"]),
        jnp.asarray(ops["rates"]), jnp.asarray(ops["mod"]),
        jnp.asarray(ops["noise"]))
    wn, rn = interp.run_program_np(words, ops["weights"], ops["qc"],
                                   ops["qa"], ops["rates"], ops["mod"],
                                   ops["noise"])
    np.testing.assert_array_equal(np.asarray(wj), wn)
    np.testing.assert_array_equal(np.asarray(rj), rn)
    return wn, rn


# ---------------------------------------------------------------------------
# 1. per-opcode semantics
# ---------------------------------------------------------------------------

class TestOpcodes:
    def test_splat_add_sub_saturate(self):
        a = Asm()
        r0, r1 = a.reg("a"), a.reg("b")
        a.splat(r0, 100.0)
        a.splat(r1, 60.0)
        a.add(r0, r0, r1)          # 160 > 127.996 -> saturates
        a.sub(r1, r1, r0)
        ops = _rand_operands()
        _, regs = _run_both(a.build(), ops)
        assert (regs[0] == isa.I16MAX).all()
        assert (regs[1] == isa.to_fixed(60.0) - isa.I16MAX).all()

    def test_mulf_rounding_shift(self):
        """fracsat multiply: (a*b + 2^(s-1)) >> s, saturating."""
        a = Asm()
        r0, r1, r2 = a.reg("a"), a.reg("b"), a.reg("c")
        a.splat(r0, 1.5)
        a.splat(r1, -2.25)
        a.mulf(r2, r0, r1)
        ops = _rand_operands(1)
        _, regs = _run_both(a.build(), ops)
        pa, pb = int(isa.to_fixed(1.5)), int(isa.to_fixed(-2.25))
        expect = (pa * pb + (1 << (isa.FRAC - 1))) >> isa.FRAC
        assert (regs[2] == expect).all()
        assert abs(expect / isa.ONE - 1.5 * -2.25) <= 1 / isa.ONE

    def test_shifts(self):
        a = Asm()
        r0, r1, r2 = a.reg("a"), a.reg("b"), a.reg("c")
        a.splat(r0, -3.0)
        a.shl(r1, r0, 2)
        a.shr(r2, r0, 3)
        ops = _rand_operands(2)
        _, regs = _run_both(a.build(), ops)
        assert (regs[1] == isa.to_fixed(-12.0)).all()
        assert (regs[2] == int(isa.to_fixed(-3.0)) >> 3).all()

    def test_cmp_sel_minmax(self):
        a = Asm()
        c, x, y, m = a.reg("c"), a.reg("x"), a.reg("y"), a.reg("m")
        a.ldcausal(x)
        a.ldacausal(y)
        a.cmpge(c, x, y)           # mask = qc >= qa
        a.sel(c, x, y)             # c = max(qc, qa) via blend
        a.vmax(m, x, y)
        ops = _rand_operands(3)
        _, regs = _run_both(a.build(), ops)
        np.testing.assert_array_equal(regs[0], regs[3])
        np.testing.assert_array_equal(
            regs[3], np.maximum(ops["qc"], ops["qa"]))
        a2 = Asm()
        x2, y2, m2 = a2.reg("x"), a2.reg("y"), a2.reg("m")
        a2.ldcausal(x2)
        a2.ldacausal(y2)
        a2.vmin(m2, x2, y2)
        _, regs2 = _run_both(a2.build(), ops)
        np.testing.assert_array_equal(
            regs2[2], np.minimum(ops["qc"], ops["qa"]))

    def test_memory_ops(self):
        """LDW/STW: integer weight load, saturating round-to-6-bit store;
        CADC loads are exact fractional codes; LDRATE saturates."""
        a = Asm()
        w, k = a.reg("w"), a.reg("k")
        a.ldw(w)
        a.splat(k, 0.75)
        a.add(w, w, k)             # w + 0.75 rounds up -> w + 1 (sat 63)
        a.stw(w)
        ops = _rand_operands(4)
        wm, regs = _run_both(a.build(), ops)
        np.testing.assert_array_equal(wm, np.minimum(ops["weights"] + 1, 63))

        a = Asm()
        r0 = a.reg("r")
        a.ldrate(r0)
        ops2 = dict(ops, rates=np.full((4,), 1000.0, np.float32))
        _, regs = _run_both(a.build(), ops2)
        assert (regs[0] == isa.I16MAX).all()   # 1000 >> Q8.8 range

    def test_ldmod_slots_and_noise(self):
        a = Asm()
        m0, m1, n = a.reg("m0"), a.reg("m1"), a.reg("n")
        a.ldmod(m0, 0)
        a.ldmod(m1, 1)
        a.ldnoise(n)
        ops = _rand_operands(5)
        _, regs = _run_both(a.build(), ops)
        np.testing.assert_array_equal(
            regs[0], np.broadcast_to(ops["mod"][0][None, :], (4, 4)))
        np.testing.assert_array_equal(
            regs[1], np.broadcast_to(ops["mod"][1][None, :], (4, 4)))
        np.testing.assert_array_equal(regs[2], ops["noise"])

    def test_executor_fuzz_bit_exact(self):
        """Random valid instruction streams: the two executors must stay
        bit-identical (the program-level transparent-interchange
        property)."""
        rng = np.random.RandomState(11)
        alu_ops = [isa.ADD, isa.SUB, isa.MULF, isa.SHL, isa.SHR, isa.CMPGE,
                   isa.SEL, isa.MAXS, isa.MINS, isa.MOV]
        for trial in range(10):
            a = Asm()
            regs = [a.reg(f"r{i}") for i in range(8)]
            for r in regs[:4]:
                a.splat(r, float(rng.uniform(-100, 100)))
            a.ldw(regs[4])
            a.ldcausal(regs[5])
            a.ldacausal(regs[6])
            a.ldnoise(regs[7])
            for _ in range(30):
                op = alu_ops[rng.randint(len(alu_ops))]
                rd, ra, rb = rng.randint(0, 8, 3)
                sh = int(rng.randint(0, 16))
                a.words.append(isa.encode(op, rd, ra, isa.alu_imm(rb, sh)))
            a.stw(regs[int(rng.randint(0, 8))])
            _run_both(a.build(), _rand_operands(trial, 8, 8))

    def test_disassembler_roundtrip_smoke(self):
        text = isa.disassemble(programs.rstdp_program())
        assert "ldcausal" in text and "stw" in text and "vmulf" in text

    def test_unknown_opcode_is_nop_in_both_executors(self):
        """Executors must stay bit-identical for ANY word stream: unknown
        ops run as NOPs in both; playback upload rejects them early."""
        a = Asm()
        r0 = a.reg("r")
        a.splat(r0, 5.0)
        a.words.append(isa.encode(25, 1, 0, 0))   # not a real opcode
        a.stw(r0)
        ops = _rand_operands(7)
        wm, regs = _run_both(a.build(), ops)
        assert (wm == 5).all()
        assert (regs[1] == 0).all()               # unknown op wrote nothing
        from repro.verif import playback as pb
        with pytest.raises(ValueError, match="unknown opcode"):
            pb.write_ppu_program(a.build())


# ---------------------------------------------------------------------------
# 2. ISA programs vs rules.py float oracles
# ---------------------------------------------------------------------------

def _machine_state(seed=0, prefix=()):
    inst = sample_instance(CFG, jax.random.PRNGKey(seed), prefix)
    core = AnnCore(CFG, inst, kernel_impl=KERNEL_IMPL)
    ppu = VectorUnit(CFG, inst)
    st = core.init_state(prefix)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 100))
    w0 = jax.random.randint(k1, (*prefix, CFG.n_rows, CFG.n_cols), 5, 60,
                            jnp.int32).astype(jnp.int8)
    st = st._replace(
        syn=st.syn._replace(weights=w0),
        corr=st.corr._replace(
            a_causal=jax.random.uniform(
                k2, (*prefix, CFG.n_rows, CFG.n_cols), maxval=8.0),
            a_acausal=jax.random.uniform(
                jax.random.fold_in(k2, 1),
                (*prefix, CFG.n_rows, CFG.n_cols), maxval=8.0)),
        rate_counters=jnp.asarray(
            np.random.RandomState(seed).randint(
                0, 20, (*prefix, CFG.n_cols)).astype(np.float32)))
    return core, ppu, st


class TestProgramsVsOracles:
    def test_rstdp_program_matches_rule(self):
        core, ppu, st = _machine_state(0)
        reward = (jax.random.uniform(jax.random.PRNGKey(1),
                                     (CFG.n_cols,)) < 0.5).astype(jnp.float32)
        rs = dict(mean_reward=0.3 * jnp.ones(CFG.n_cols),
                  key=jax.random.PRNGKey(2))
        st_ref, rs_ref, _ = ppu.apply_rule(rules.rstdp, st, dict(rs),
                                           reward=reward, eta=0.5,
                                           gamma=0.3, noise=0.3)
        prog = jnp.asarray(programs.rstdp_program(eta=0.5))
        st_vm, rs_vm, _ = ppu.apply_rstdp_program(st, dict(rs), reward=reward,
                                                  program=prog, gamma=0.3,
                                                  noise=0.3)
        d = np.abs(np.asarray(st_vm.syn.weights, np.int32)
                   - np.asarray(st_ref.syn.weights, np.int32))
        assert d.max() <= 1, f"max diff {d.max()} LSB"
        assert (d == 0).mean() > 0.95
        np.testing.assert_allclose(np.asarray(rs_vm["mean_reward"]),
                                   np.asarray(rs_ref["mean_reward"]),
                                   atol=1e-6)

    def test_stdp_program_matches_rule(self):
        core, ppu, st = _machine_state(1)
        st_ref, _, _ = ppu.apply_rule(rules.stdp, st, {}, eta_plus=0.8,
                                      eta_minus=0.9)
        prog = jnp.asarray(programs.stdp_program(eta_plus=0.8, eta_minus=0.9))
        st_vm, _ = ppu.run_program(st, prog)
        d = np.abs(np.asarray(st_vm.syn.weights, np.int32)
                   - np.asarray(st_ref.syn.weights, np.int32))
        assert d.max() <= 1, f"max diff {d.max()} LSB"
        assert (d == 0).mean() > 0.95

    def test_homeostasis_program_matches_rule(self):
        core, ppu, st = _machine_state(2)
        st_ref, _, _ = ppu.apply_rule(rules.homeostasis, st, {},
                                      target_rate=10.0, eta=0.2)
        prog = jnp.asarray(
            programs.homeostasis_program(target_rate=10.0, eta=0.2))
        st_vm, _ = ppu.run_program(st, prog)
        d = np.abs(np.asarray(st_vm.syn.weights, np.int32)
                   - np.asarray(st_ref.syn.weights, np.int32))
        assert d.max() <= 1, f"max diff {d.max()} LSB"
        assert (d == 0).mean() > 0.95

    def test_observables_reset_after_program(self):
        _, ppu, st = _machine_state(3)
        st_vm, _ = ppu.run_program(st, jnp.asarray(programs.stdp_program()))
        assert float(jnp.sum(st_vm.rate_counters)) == 0.0
        assert float(jnp.sum(jnp.abs(st_vm.corr.a_causal))) == 0.0


# ---------------------------------------------------------------------------
# 3. system level: scan integration + playback co-simulation
# ---------------------------------------------------------------------------

class TestScanIntegration:
    def test_vm_rstdp_in_jitted_scan_matches_apply_rstdp(self):
        """The ISSUE's acceptance check: the ISA-compiled R-STDP program,
        run by ``VectorUnit.run_program`` INSIDE a jitted lax.scan over
        trials (emulation window + PPU update per step), matches the
        fixed-function ``apply_rstdp`` ref path within one 6-bit LSB at
        every trial."""
        inst = sample_instance(CFG, jax.random.PRNGKey(5))
        core = AnnCore(CFG, inst, kernel_impl=KERNEL_IMPL)
        ppu = VectorUnit(CFG, inst)
        prog = jnp.asarray(programs.rstdp_program(eta=0.5))
        n_trials, T, R = 5, 64, CFG.n_rows
        ev = (jax.random.uniform(jax.random.PRNGKey(1), (n_trials, T, R))
              < 0.05).astype(jnp.float32)
        ad = jnp.zeros((n_trials, T, R), jnp.int8)
        reward = (jax.random.uniform(jax.random.PRNGKey(2),
                                     (n_trials, CFG.n_cols))
                  < 0.5).astype(jnp.float32)

        def init():
            st = core.init_state()
            return st._replace(syn=st.syn._replace(
                weights=jnp.full((R, CFG.n_cols), 30, jnp.int8)))

        def make(use_vm):
            def body(carry, xs):
                st, rs = carry
                e, a, r = xs
                st, _ = core.run(st, e, a)
                if use_vm:
                    st, rs, _ = ppu.apply_rstdp_program(
                        st, rs, reward=r, program=prog, gamma=0.3, noise=0.3)
                else:
                    st, rs, _ = ppu.apply_rstdp(st, rs, reward=r, eta=0.5,
                                                gamma=0.3, noise=0.3,
                                                impl="ref")
                return (st, rs), st.syn.weights

            def run():
                rs = dict(mean_reward=jnp.zeros(CFG.n_cols),
                          key=jax.random.PRNGKey(9))
                (st, rs), ws = jax.lax.scan(body, (init(), rs),
                                            (ev, ad, reward))
                return ws
            return jax.jit(run)

        ws_ref = np.asarray(make(False)(), np.int32)
        ws_vm = np.asarray(make(True)(), np.int32)
        d = np.abs(ws_vm - ws_ref)
        assert d.max() <= 1, f"max diff {d.max()} LSB over {len(ws_ref)} trials"

    def test_hybrid_vm_rule_trains(self):
        """The §5 experiment with the rule as a VM program: same trial
        structure, learning actually progresses."""
        from repro.core.hybrid import RSTDPConfig, run_training
        out, state, meta = run_training(
            n_trials=60, seed=0, rule_impl="vm",
            ecfg=RSTDPConfig(trial_steps=128))
        mr = np.median(out["mean_reward"], axis=1)
        assert np.isfinite(out["w_signed_final"]).all()
        assert mr[-15:].mean() > mr[:15].mean(), \
            (mr[:15].mean(), mr[-15:].mean())

    def test_hybrid_vm_dw_matches_python_rule_first_trial(self):
        """One trial from identical state: the VM dw readout path and the
        python ``_signed_rule`` agree on the signed weights to fixed-point
        tolerance (the closed-loop trajectories may then diverge — that is
        inherent to quantized feedback, not an implementation gap)."""
        from repro.core.hybrid import RSTDPConfig, make_experiment
        ecfg = RSTDPConfig(trial_steps=128)
        outs = {}
        for impl in ("python", "vm"):
            init, trial, meta = make_experiment(
                ecfg=ecfg, instance_key=jax.random.PRNGKey(3),
                rule_impl=impl, kernel_impl=KERNEL_IMPL)
            st = init(jax.random.PRNGKey(4))
            st2, m = jax.jit(trial)(st, jnp.int32(1))
            outs[impl] = np.asarray(st2.w_signed)
        d = np.abs(outs["vm"] - outs["python"])
        assert d.max() < 0.15, f"max |dw gap| {d.max()}"


class TestPlaybackCosim:
    def _program(self, words, seed=0):
        from repro.verif import playback as pb
        rng = np.random.RandomState(seed)
        r, c = 8, 8
        w = np.full((r, c), 50, np.int8)
        addr = np.zeros((r, c), np.int8)
        ev = np.zeros((100, r), np.float32)
        ev[10] = 1.0
        ev[55] = 1.0
        ev[80, ::2] = 1.0
        mod = rng.uniform(-1, 1, (2, c)).astype(np.float32)
        noise = (0.3 * rng.randn(r, c)).astype(np.float32)
        return [
            pb.write_weights(w),
            pb.write_addresses(addr),
            pb.write_ppu_program(words),
            pb.inject(ev),
            pb.ppu_run(mod=mod, noise=noise),
            pb.read_weights(),
            pb.run(40),
            pb.ppu_run(mod=mod),
            pb.read_weights(),
            pb.read_rates(),
        ]

    @pytest.mark.parametrize("builder", [
        lambda: programs.rstdp_program(eta=0.5),
        lambda: programs.stdp_program(eta_plus=0.8, eta_minus=0.9),
        lambda: programs.homeostasis_program(target_rate=4.0),
    ], ids=["rstdp", "stdp", "homeostasis"])
    def test_ppu_program_cosim_pass(self, builder):
        """WRITE_PPU_PROGRAM/PPU_RUN: the same word stream must produce
        the same trace (incl. the PPU_W weight records) on the fast JAX
        backend and the independent NumPy backend."""
        import dataclasses as dc
        from repro.verif import playback as pb
        cfg = dc.replace(BSS2.reduced(), n_rows=8, n_cols=8)
        prog = self._program(builder())
        tr_fast = pb.execute(prog, "fast", cfg)
        tr_ref = pb.execute(prog, "ref", cfg)
        errs = pb.compare_traces(tr_fast, tr_ref, atol=0.05)
        assert not errs, "\n".join(errs)
        kinds = [k for _, k, _ in tr_fast]
        assert kinds.count("PPU_W") == 2

    def test_cosim_detects_program_mutation(self):
        """A single flipped constant in the uploaded program must be
        caught by the trace diff — co-simulation for programs."""
        import dataclasses as dc
        from repro.verif import playback as pb
        cfg = dc.replace(BSS2.reduced(), n_rows=8, n_cols=8)
        good = programs.rstdp_program(eta=0.5)
        bad = good.copy()
        bad[3] = isa.encode(isa.SPLAT, 2, 0, isa.splat_imm(3.0))  # eta const
        tr_good = pb.execute(self._program(good), "ref", cfg)
        tr_bad = pb.execute(self._program(bad), "fast", cfg)
        errs = pb.compare_traces(tr_good, tr_bad, atol=0.05)
        assert errs, "trace diff must detect the mutated program"
