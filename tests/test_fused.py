"""Fused-backend equivalence: the hot path must reproduce the oracle.

The fused AnnCore backend hoists correlation out of the dt scan, batches
the whole window's synaptic currents through one event x weight matmul and
pre-splits the Dale rows — all pure restructurings of the same arithmetic,
so results must match the per-step oracle to float-reduction-order
tolerance (empirically bit-exact on CPU at these sizes, asserted to 1e-4
here to stay robust on other backends).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import rules
from repro.core.anncore import AnnCore
from repro.core.ppu import VectorUnit
from repro.verif.mismatch import sample_instance

CFG = dataclasses.replace(BSS2.reduced(), n_rows=16, n_cols=16)
TOL = dict(rtol=1e-4, atol=1e-4)


def _events(T, prefix, key=0, p=0.1, n_addr=4):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    ev = (jax.random.uniform(k1, (T, *prefix, CFG.n_rows)) < p
          ).astype(jnp.float32)
    ad = jax.random.randint(k2, (T, *prefix, CFG.n_rows), 0, n_addr,
                            jnp.int8)
    return ev, ad


def _cores(prefix, **kw):
    inst = sample_instance(CFG, jax.random.PRNGKey(0), prefix)
    oracle = AnnCore(CFG, inst, backend="oracle")
    fused = AnnCore(CFG, inst, backend="fused", **kw)
    st = oracle.init_state(prefix)
    kw_, ka = jax.random.split(jax.random.PRNGKey(9))
    st = st._replace(syn=st.syn._replace(
        weights=jax.random.randint(kw_, (*prefix, CFG.n_rows, CFG.n_cols),
                                   20, 64, jnp.int8),
        addresses=jax.random.randint(ka, (*prefix, CFG.n_rows, CFG.n_cols),
                                     0, 4, jnp.int8)))
    return oracle, fused, st


def _assert_state_close(s1, s2):
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **TOL)


class TestFusedRunEquivalence:
    @pytest.mark.parametrize("record_v", [False, True])
    def test_matches_oracle(self, record_v):
        oracle, fused, st = _cores(())
        ev, ad = _events(200, ())
        s1, o1 = jax.jit(lambda s, e, a: oracle.run(s, e, a, record_v))(
            st, ev, ad)
        s2, o2 = jax.jit(lambda s, e, a: fused.run(s, e, a, record_v))(
            st, ev, ad)
        assert float(o1["spikes"].sum()) > 0, "drive must elicit spikes"
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), **TOL)
        if record_v:
            np.testing.assert_allclose(np.asarray(o1["v"]),
                                       np.asarray(o2["v"]), **TOL)
        _assert_state_close(s1, s2)

    def test_matches_oracle_batched_instances(self):
        prefix = (3,)
        oracle, fused, st = _cores(prefix)
        ev, ad = _events(150, prefix, key=1)
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(fused.run)(st, ev, ad)
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), **TOL)
        _assert_state_close(s1, s2)

    def test_const_addr_fast_path(self):
        """Per-row-constant event addresses: the fused path may resolve the
        match mask once per window."""
        oracle, fused, st = _cores((), const_addr=True)
        ev, _ = _events(150, (), key=2)
        ad = jnp.broadcast_to(
            jax.random.randint(jax.random.PRNGKey(3), (CFG.n_rows,), 0, 4,
                               jnp.int8), ev.shape)
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(fused.run)(st, ev, ad)
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), **TOL)
        _assert_state_close(s1, s2)

    def test_fallback_multi_address_matches_oracle(self):
        """Multi-address event streams (addresses changing per step) MUST
        run off the fast path: a fused core without the const_addr promise
        takes the general per-step mask and still matches the oracle."""
        oracle, fused, st = _cores(())           # const_addr defaults False
        assert fused.const_addr is False
        ev, ad = _events(150, (), key=5, n_addr=4)
        # make the address schedule aggressively time-varying: every step
        # cycles which rows can match at all
        ad = (ad + jnp.arange(150, dtype=jnp.int8)[:, None] % 4) % 4
        s1, o1 = jax.jit(oracle.run)(st, ev, ad)
        s2, o2 = jax.jit(fused.run)(st, ev, ad)
        assert float(o1["spikes"].sum()) > 0
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), **TOL)
        _assert_state_close(s1, s2)

    def test_interpret_kernels_match_oracle(self):
        """Integration through the actual Pallas kernels (interpret mode):
        synray + corr wired into the fused run."""
        oracle, fused, st = _cores((), kernel_impl="interpret")
        ev, ad = _events(64, (), key=4)
        s1, o1 = oracle.run(st, ev, ad)
        s2, o2 = fused.run(st, ev, ad)
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), atol=1e-3)
        np.testing.assert_allclose(np.asarray(s1.corr.a_causal),
                                   np.asarray(s2.corr.a_causal),
                                   rtol=1e-3, atol=1e-3)


class TestConstAddrWindow:
    """Dedicated coverage for the PR 1 const_addr fast path: the
    address-match mask resolved ONCE per window (`synapse.
    synaptic_current_window(const_addr=True)`) vs the general per-step
    path vs the literal per-step oracle."""

    def _operands(self, T=48, n_addr=4, key=0):
        from repro.core import synapse
        ks = jax.random.split(jax.random.PRNGKey(key), 4)
        w = jax.random.randint(ks[0], (CFG.n_rows, CFG.n_cols), 0, 64,
                               jnp.int8)
        a = jax.random.randint(ks[1], (CFG.n_rows, CFG.n_cols), 0, n_addr,
                               jnp.int8)
        ev = (jax.random.uniform(ks[2], (T, CFG.n_rows)) < 0.3
              ).astype(jnp.float32)
        row_addr = jax.random.randint(ks[3], (CFG.n_rows,), 0, n_addr,
                                      jnp.int8)
        return synapse.SynapseArray(w, a), ev, row_addr

    def test_const_addr_matches_general_and_per_step(self):
        """Row-constant event addresses spanning several distinct values:
        fast path == general window path == per-step oracle."""
        from repro.core import synapse
        syn, ev, row_addr = self._operands()
        ad_t = jnp.broadcast_to(row_addr, ev.shape)
        i_fast = synapse.synaptic_current_window(
            syn.weights, syn.addresses, ev, ad_t, 1.0, impl="ref",
            const_addr=True)
        i_gen = synapse.synaptic_current_window(
            syn.weights, syn.addresses, ev, ad_t, 1.0, impl="ref",
            const_addr=False)
        i_step = jnp.stack([
            synapse.synaptic_current(syn.weights, syn.addresses, ev[t],
                                     ad_t[t], 1.0)
            for t in range(ev.shape[0])])
        np.testing.assert_allclose(np.asarray(i_fast), np.asarray(i_gen),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(i_fast), np.asarray(i_step),
                                   **TOL)

    def test_multi_address_stream_requires_fallback(self):
        """A time-varying (multi-address) stream: the general path matches
        the per-step oracle, while the const_addr fast path — which
        freezes the step-0 mask — provably diverges. This is the contract
        that multi-source rows must FALL BACK off the fast path."""
        from repro.core import synapse
        syn, ev, _ = self._operands()
        T = ev.shape[0]
        # step 0 carries address 5 (matches NO synapse: stored addrs < 4),
        # later steps carry matching addresses -> frozen mask kills all
        # current on the fast path, the general path forwards it
        ad_t = jnp.concatenate([
            jnp.full((1, CFG.n_rows), 5, jnp.int8),
            jnp.zeros((T - 1, CFG.n_rows), jnp.int8)])
        i_gen = synapse.synaptic_current_window(
            syn.weights, syn.addresses, ev, ad_t, 1.0, impl="ref",
            const_addr=False)
        i_step = jnp.stack([
            synapse.synaptic_current(syn.weights, syn.addresses, ev[t],
                                     ad_t[t], 1.0)
            for t in range(T)])
        np.testing.assert_allclose(np.asarray(i_gen), np.asarray(i_step),
                                   **TOL)
        i_fast = synapse.synaptic_current_window(
            syn.weights, syn.addresses, ev, ad_t, 1.0, impl="ref",
            const_addr=True)
        assert float(jnp.abs(i_fast).sum()) == 0.0, \
            "frozen step-0 mask must kill all current here"
        assert float(jnp.abs(i_gen).sum()) > 0.0, \
            "general path must forward the later matching events"

    def test_fused_core_const_addr_equals_general_core(self):
        """End-to-end: two fused cores (with/without the promise) on a
        row-constant stream produce identical dynamics."""
        inst = sample_instance(CFG, jax.random.PRNGKey(0), ())
        fast = AnnCore(CFG, inst, backend="fused", const_addr=True)
        gen = AnnCore(CFG, inst, backend="fused", const_addr=False)
        _, _, st = _cores(())
        ev, _ = _events(100, (), key=6)
        ad = jnp.broadcast_to(
            jax.random.randint(jax.random.PRNGKey(7), (CFG.n_rows,), 0, 4,
                               jnp.int8), ev.shape)
        s1, o1 = jax.jit(fast.run)(st, ev, ad)
        s2, o2 = jax.jit(gen.run)(st, ev, ad)
        np.testing.assert_allclose(np.asarray(o1["spikes"]),
                                   np.asarray(o2["spikes"]), **TOL)
        _assert_state_close(s1, s2)


class TestApplyRstdpKernelRouting:
    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    @pytest.mark.parametrize("prefix", [(), (2,)])
    def test_matches_generic_apply_rule(self, impl, prefix):
        inst = sample_instance(CFG, jax.random.PRNGKey(0), prefix)
        core = AnnCore(CFG, inst)
        ppu = VectorUnit(CFG, inst)
        st = core.init_state(prefix)
        shape = (*prefix, CFG.n_rows, CFG.n_cols)
        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        st = st._replace(
            syn=st.syn._replace(weights=jax.random.randint(
                ks[0], shape, 0, 64, jnp.int8)),
            corr=st.corr._replace(
                a_causal=jax.random.uniform(ks[1], shape) * 20,
                a_acausal=jax.random.uniform(ks[2], shape) * 20),
            rate_counters=jnp.ones((*prefix, CFG.n_cols)))
        reward = jax.random.bernoulli(ks[3], 0.5, (*prefix, CFG.n_cols)
                                      ).astype(jnp.float32)
        rs = dict(mean_reward=jnp.zeros((*prefix, CFG.n_cols)),
                  key=jax.random.PRNGKey(8))
        sg, rg, obs = ppu.apply_rule(rules.rstdp, st, dict(rs),
                                     reward=reward, eta=4.0, noise=0.2)
        sf, rf, elig = ppu.apply_rstdp(st, dict(rs), reward=reward,
                                       eta=4.0, noise=0.2, impl=impl)
        # int8 stores may differ by 1 LSB at exact .5 rounding ties only
        dw = np.abs(np.asarray(sg.syn.weights, np.int32)
                    - np.asarray(sf.syn.weights, np.int32))
        assert dw.max() <= 1 and (dw > 0).mean() < 0.01
        np.testing.assert_allclose(np.asarray(rg["mean_reward"]),
                                   np.asarray(rf["mean_reward"]), **TOL)
        assert (np.asarray(rg["key"]) == np.asarray(rf["key"])).all()
        # observables reset exactly like apply_rule
        assert float(sf.rate_counters.sum()) == 0.0
        assert float(sf.corr.a_causal.sum()) == 0.0
        ref_elig = (np.asarray(obs["causal"])
                    - np.asarray(obs["acausal"])) / 255.0
        np.testing.assert_allclose(ref_elig, np.asarray(elig), atol=1e-2)


class TestScannedTraining:
    def test_scan_matches_python_loop(self):
        """One-program lax.scan over trials == per-trial jit dispatch
        (same seeds -> same weights/rewards)."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, s1, _ = run_training(n_trials=9, seed=3, ecfg=ecfg, scan=True)
        o2, s2, _ = run_training(n_trials=9, seed=3, ecfg=ecfg, scan=False)
        np.testing.assert_allclose(o1["w_signed_final"],
                                   o2["w_signed_final"], **TOL)
        np.testing.assert_allclose(o1["mean_reward"], o2["mean_reward"],
                                   **TOL)
        np.testing.assert_allclose(o1["reward"], o2["reward"], **TOL)
        np.testing.assert_array_equal(o1["stim"], o2["stim"])
        assert o1["mean_reward"].shape == (9, ecfg.n_neurons)

    def test_scan_matches_oracle_backend(self):
        """The full experiment on the fused backend == oracle backend."""
        from repro.core.hybrid import RSTDPConfig, run_training
        ecfg = RSTDPConfig(trial_steps=96)
        o1, _, _ = run_training(n_trials=6, seed=4, ecfg=ecfg)
        o2, _, _ = run_training(n_trials=6, seed=4, ecfg=ecfg,
                                backend="oracle", scan=False)
        np.testing.assert_allclose(o1["w_signed_final"],
                                   o2["w_signed_final"], rtol=1e-3,
                                   atol=1e-3)
        np.testing.assert_allclose(o1["mean_reward"], o2["mean_reward"],
                                   **TOL)
