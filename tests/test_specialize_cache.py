"""Specialized-executor closure cache (ROADMAP item): one compiled
specialization per program image, keyed on the raw word bytes — playback
suites that upload dozens of rules (or re-upload the same one) must not
re-unroll/retrace per upload."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ppuvm import interp, isa, programs, specialize
from repro.ppuvm.asm import Asm


def _operands(seed=0, shape=(8, 16)):
    rng = np.random.RandomState(seed)
    return dict(
        weights=jnp.asarray(rng.randint(0, 64, shape), jnp.int32),
        qc=jnp.asarray(rng.randint(0, 256, shape), jnp.int32),
        qa=jnp.asarray(rng.randint(0, 256, shape), jnp.int32),
        rates=jnp.asarray(rng.randint(0, 8, shape[-1:]).astype(np.float32)))


class TestSpecializedClosureCache:
    def setup_method(self, method):
        specialize.cache_clear()

    def test_same_program_hits(self):
        words = programs.rstdp_program(eta=4.0)
        ops = _operands()
        f1 = specialize.specialized_callable(words)
        f2 = specialize.specialized_callable(np.array(words))  # fresh array
        assert f1 is f2, "identical word bytes must share one closure"
        stats = specialize.cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
        w1, r1 = f1(ops["weights"], ops["qc"], ops["qa"], ops["rates"],
                    None, None)
        # cached closure == direct specializer, bit-for-bit
        w2, r2 = specialize.run_program_specialized(
            words, ops["weights"], ops["qc"], ops["qa"], ops["rates"])
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_lru_eviction_bounds_cache(self):
        """One-off program sweeps must not grow the cache unboundedly —
        least-recently-used closures are evicted at the cap."""
        def one_off(i):                   # distinct Q8.8 immediate per i
            asm = Asm()
            asm.splat(asm.reg("r"), i / 256.0)
            return asm.build()

        for i in range(specialize._CACHE_MAX + 5):
            specialize.specialized_callable(one_off(i + 1))
        stats = specialize.cache_stats()
        assert stats["size"] == specialize._CACHE_MAX
        # the most recent entry still hits ...
        hits0 = stats["hits"]
        specialize.specialized_callable(one_off(specialize._CACHE_MAX + 5))
        assert specialize.cache_stats()["hits"] == hits0 + 1
        # ... while the oldest was evicted (re-specializes as a miss)
        misses0 = specialize.cache_stats()["misses"]
        specialize.specialized_callable(one_off(1))
        assert specialize.cache_stats()["misses"] == misses0 + 1

    def test_distinct_programs_distinct_entries(self):
        w1 = programs.rstdp_program(eta=4.0)
        w2 = programs.rstdp_program(eta=8.0)
        specialize.specialized_callable(w1)
        specialize.specialized_callable(w2)
        specialize.specialized_callable(w1)
        stats = specialize.cache_stats()
        assert stats["size"] == 2
        assert stats["misses"] == 2 and stats["hits"] == 1

    def test_run_program_routes_through_cache(self):
        words = programs.stdp_program()
        ops = _operands(1)
        out1 = interp.run_program(words, ops["weights"], ops["qc"],
                                  ops["qa"], ops["rates"],
                                  executor="specialized")
        misses = specialize.cache_stats()["misses"]
        out2 = interp.run_program(words, ops["weights"], ops["qc"],
                                  ops["qa"], ops["rates"],
                                  executor="specialized")
        stats = specialize.cache_stats()
        assert stats["misses"] == misses, "second run must not re-specialize"
        assert stats["hits"] >= 1
        np.testing.assert_array_equal(np.asarray(out1[0]),
                                      np.asarray(out2[0]))

    def test_playback_reupload_no_retrace(self):
        """A playback suite re-uploading rules: the FastBackend binds each
        program once and the specializer compiles each image once, however
        many uploads interleave."""
        from repro.configs.bss2 import BSS2
        from repro.verif import playback as pb
        cfg = BSS2.reduced()
        rules = [programs.rstdp_program(eta=4.0), programs.stdp_program()]
        prog = [pb.write_weights(np.full((cfg.n_rows, cfg.n_cols), 20,
                                         np.int8)),
                pb.write_addresses(np.zeros((cfg.n_rows, cfg.n_cols),
                                            np.int8))]
        mod = np.zeros((1, cfg.n_cols), np.float32)
        for _ in range(3):                     # re-upload suite, 3 rounds
            for words in rules:
                prog.append(pb.write_ppu_program(words))
                prog.append(pb.ppu_run(mod=mod))
            prog.append(pb.read_weights())
        be = pb.FastBackend(cfg, ppu_executor="specialized")
        trace = be.execute(prog)
        assert len(trace) > 0
        assert len(be._run_cache) == len(rules), \
            "one jitted PPU_RUN closure per distinct program image"
        stats = specialize.cache_stats()
        assert stats["misses"] <= len(rules), stats
