"""Unit tests for the BSS-2 machine model (repro.core)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2, BSS2Config
from repro.core import adex, capmem, correlation, stp, synapse
from repro.core.anncore import AnnCore
from repro.core.ppu import VectorUnit
from repro.core import rules
from repro.verif.mismatch import ideal_instance, sample_instance

CFG = dataclasses.replace(BSS2.reduced(), n_rows=8, n_cols=8)


def _nominal_params(n):
    return {k: jnp.full((n,), v) for k, v in
            [(name, getattr(BSS2.neuron, name)) for name in capmem.NEURON_PARAMS]}


class TestAdEx:
    def test_resting_potential(self):
        p = _nominal_params(4)
        st = adex.init_state((4,), p)
        for _ in range(500):
            st, s = adex.step(st, jnp.zeros(4), jnp.zeros(4), p, 0.2)
        np.testing.assert_allclose(st.v, p["e_leak"], atol=0.5)
        assert float(s.sum()) == 0

    def test_step_current_fires(self):
        p = _nominal_params(1)
        st = adex.init_state((1,), p)
        fired = 0.0
        for _ in range(500):
            st, s = adex.step(st, jnp.full((1,), 120.0), jnp.zeros(1), p, 0.2)
            fired += float(s.sum())
        assert fired >= 1, "strong step current must elicit spikes"

    def test_refractory_blocks(self):
        p = _nominal_params(1)
        st = adex.init_state((1,), p)
        spikes = []
        for _ in range(2000):
            st, s = adex.step(st, jnp.full((1,), 400.0), jnp.zeros(1), p, 0.2)
            spikes.append(float(s[0]))
        idx = np.flatnonzero(np.asarray(spikes))
        assert len(idx) >= 2
        isi = np.diff(idx) * 0.2
        assert isi.min() >= BSS2.neuron.tau_refrac - 0.3

    def test_adaptation_slows_firing(self):
        # moderate drive: the filtered synaptic current settles near 500 pA
        # (rheobase ~380 pA), so the adaptation current w (b=20 pA/spike,
        # tau_w=100 us) visibly stretches the ISIs
        p = _nominal_params(1)
        st = adex.init_state((1,), p)
        t_spikes = []
        for t in range(6000):
            st, s = adex.step(st, jnp.full((1,), 20.0), jnp.zeros(1), p, 0.2)
            if float(s[0]):
                t_spikes.append(t)
        assert len(t_spikes) >= 4
        isis = np.diff(t_spikes)
        assert np.mean(isis[-2:]) > 1.2 * isis[0], \
            "spike-frequency adaptation expected"


class TestSynapse:
    def test_address_matching(self):
        w = jnp.full((4, 4), 10, jnp.int8)
        addr = jnp.arange(16, dtype=jnp.int8).reshape(4, 4) % 4
        ev = jnp.ones((4,))
        i = synapse.synaptic_current(w, addr, ev, jnp.zeros((4,), jnp.int8), 1.0)
        # only synapses whose stored address == 0 conduct
        expect = 10.0 * (np.asarray(addr) == 0).sum(axis=0)
        np.testing.assert_allclose(np.asarray(i), expect)

    def test_weight_quantization_saturates(self):
        q = synapse.quantize_weight(jnp.asarray([-5.0, 0.4, 63.7, 99.0]))
        assert q.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(q), [0, 0, 63, 63])


class TestSTP:
    def test_depression_and_recovery(self):
        st = stp.init_state((1,))
        spikes = jnp.ones((1,))
        code = jnp.full((1,), 8, jnp.int32)
        offs = jnp.zeros((1,))
        e1 = stp.efficacy(st, spikes, u=0.5, offset=offs, calib_code=code)
        st = stp.update(st, spikes, u=0.5, tau_rec=20.0, dt=1.0)
        e2 = stp.efficacy(st, spikes, u=0.5, offset=offs, calib_code=code)
        assert float(e2[0]) < float(e1[0]), "paired-pulse depression"
        # long silence -> full recovery
        for _ in range(40):
            st = stp.update(st, jnp.zeros((1,)), u=0.5, tau_rec=20.0, dt=5.0)
        e3 = stp.efficacy(st, spikes, u=0.5, offset=offs, calib_code=code)
        np.testing.assert_allclose(float(e3[0]), float(e1[0]), rtol=1e-3)


class TestCorrelation:
    def test_causal_order_detected(self):
        st = correlation.init_state((), 2, 2)
        pre = jnp.asarray([1.0, 0.0])
        post = jnp.asarray([0.0, 0.0])
        st = correlation.update(st, pre, post, tau_pre=10., tau_post=10., dt=1.)
        # post fires 3 steps later -> causal credit at synapse (0, 0)
        for _ in range(2):
            st = correlation.update(st, jnp.zeros(2), jnp.zeros(2),
                                    tau_pre=10., tau_post=10., dt=1.)
        st = correlation.update(st, jnp.zeros(2), jnp.asarray([1.0, 0.0]),
                                tau_pre=10., tau_post=10., dt=1.)
        a = np.asarray(st.a_causal)
        assert a[0, 0] > 0.5 and a[1, 0] == 0.0
        assert np.asarray(st.a_acausal)[0, 0] < a[0, 0]

    def test_acausal_order_detected(self):
        st = correlation.init_state((), 1, 1)
        st = correlation.update(st, jnp.zeros(1), jnp.ones(1),
                                tau_pre=10., tau_post=10., dt=1.)
        st = correlation.update(st, jnp.ones(1), jnp.zeros(1),
                                tau_pre=10., tau_post=10., dt=1.)
        assert float(st.a_acausal[0, 0]) > float(st.a_causal[0, 0])


class TestAnnCore:
    def test_run_shapes_and_rates(self):
        inst = ideal_instance(CFG)
        core = AnnCore(CFG, inst)
        st = core.init_state()
        st = st._replace(syn=st.syn._replace(
            weights=jnp.full((8, 8), 40, jnp.int8)))
        T = 200
        ev = (jax.random.uniform(jax.random.PRNGKey(0), (T, 8)) < 0.05
              ).astype(jnp.float32)
        addr = jnp.zeros((T, 8), jnp.int8)
        st2, out = jax.jit(lambda s, e, a: core.run(s, e, a))(st, ev, addr)
        assert out["spikes"].shape == (T, 8)
        assert float(st2.rate_counters.sum()) == float(out["spikes"].sum())
        assert np.isfinite(np.asarray(st2.neuron.v)).all()

    def test_batched_instances(self):
        inst = sample_instance(CFG, jax.random.PRNGKey(1), prefix=(3,))
        core = AnnCore(CFG, inst)
        st = core.init_state((3,))
        ev = jnp.zeros((50, 3, 8))
        addr = jnp.zeros((50, 3, 8), jnp.int8)
        st2, out = core.run(st, ev, addr)
        assert out["spikes"].shape == (50, 3, 8)
        # mismatch: resting potentials differ between instances
        v = np.asarray(st2.neuron.v)
        assert np.std(v[:, 0]) > 0.01


class TestPPU:
    def test_rule_application_resets_observables(self):
        inst = ideal_instance(CFG)
        core = AnnCore(CFG, inst)
        ppu = VectorUnit(CFG, inst)
        st = core.init_state()
        st = st._replace(rate_counters=jnp.full((8,), 5.0),
                         corr=st.corr._replace(
                             a_causal=jnp.ones((8, 8)) * 3.0))
        st2, rs, obs = ppu.apply_rule(
            rules.homeostasis, st, {}, target_rate=3.0)
        assert float(st2.rate_counters.sum()) == 0.0
        assert float(st2.corr.a_causal.sum()) == 0.0
        assert (np.asarray(st2.syn.weights) >= 0).all()
        assert (np.asarray(st2.syn.weights) <= 63).all()

    def test_rstdp_moves_weights_toward_reward(self):
        w = jnp.full((4, 4), 20.0)
        obs = dict(causal=jnp.full((4, 4), 100, jnp.int32),
                   acausal=jnp.zeros((4, 4), jnp.int32),
                   rates=jnp.zeros((4,)))
        rs = dict(mean_reward=jnp.zeros((4,)), key=jax.random.PRNGKey(0))
        reward = jnp.asarray([1.0, 1.0, 0.0, 0.0])
        w2, rs2 = rules.rstdp(w, obs, rs, reward=reward, noise=0.0, eta=1.0)
        dw = np.asarray(w2 - w)
        assert (dw[:, :2] > 0).all(), "rewarded neurons potentiate"
        np.testing.assert_allclose(dw[:, 2:], 0.0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rs2["mean_reward"]),
                                   0.3 * np.asarray(reward))
