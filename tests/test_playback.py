"""Software-RTL co-simulation analogue (paper §3.1): the same playback
program must produce the same experiment trace on the optimized JAX backend
and the independent NumPy reference backend."""
import dataclasses

import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.verif import playback as pb

CFG = dataclasses.replace(BSS2.reduced(), n_rows=8, n_cols=8)


def _program(seed=0):
    """Deterministic robustly-suprathreshold program.

    Spiking dynamics are chaotic: two correct fp32 backends diverge in spike
    *timing* from ULP-level exp() differences (measured: 1e-5 V-drift per
    step, first spike flip after ~70 steps of marginal drive). Like real
    mixed-signal co-simulation, the check therefore drives the DUT with
    unambiguous stimuli and compares digital artifacts exactly, analog
    observables within tolerance.
    """
    rng = np.random.RandomState(seed)
    w = np.full((8, 8), 50, np.int8)
    addr = (rng.randint(0, 2, (8, 8)) * 3).astype(np.int8)
    ev = np.zeros((120, 8), np.float32)
    ev[10] = 1.0
    ev[60] = 1.0
    ev[100, ::2] = 1.0
    return [
        pb.write_weights(w),
        pb.write_addresses(addr),
        pb.read_weights(),
        pb.inject(ev),
        pb.read_rates(),
        pb.read_v(),
        pb.run(50),
        pb.read_rates(),
        pb.read_corr(),
    ]


def test_cosim_fast_matches_ref():
    prog = _program()
    tr_fast = pb.execute(prog, "fast", CFG)
    tr_ref = pb.execute(prog, "ref", CFG)
    errs = pb.compare_traces(tr_fast, tr_ref, atol=0.05)
    assert not errs, "\n".join(errs)


def test_cosim_detects_injected_bug():
    """Mutated weights on one backend must be caught by the trace diff —
    the co-simulation flow's whole point."""
    prog = _program(1)
    tr_ref = pb.execute(prog, "ref", CFG)
    bad = list(prog)
    w = prog[0].payload.copy()
    w[3, 4] += 7                      # single-synapse "RTL bug"
    bad[0] = pb.write_weights(w)
    tr_bad = pb.execute(bad, "fast", CFG)
    errs = pb.compare_traces(tr_bad, tr_ref, atol=0.05)
    assert errs, "trace diff must detect the injected defect"


def test_trace_is_timestamped_and_ordered():
    tr = pb.execute(_program(2), "fast", CFG)
    times = [t for t, _, _ in tr]
    assert times == sorted(times)
    kinds = [k for _, k, _ in tr]
    assert kinds == ["WEIGHTS", "SPIKES", "RATES", "V", "SPIKES", "RATES",
                     "CORR"]
