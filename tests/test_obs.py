"""Telemetry subsystem contract: free when off, honest when on.

Three invariants pin down ``repro.obs``:

  * **bit-exactness** — telemetry on/off produces IDENTICAL spikes,
    weights, and VM state (``assert_array_equal``, not tolerance) across
    the oracle/fused/blocked backends, the sparse routes, and the VM
    rule: the counters only *read* values the emulation already computes;
  * **counter correctness** — every counter matches a hand-counted
    NumPy oracle on the same inputs (events in, spikes out, routing
    decisions, saturation hits, |dw| histogram bins);
  * **zero retrace** — emitting (or re-emitting) the host summary/report
    never retraces the compiled training program.

Plus the first-divergence locator (``repro.verif.mismatch``), the phase
timer, the run report, and the specializer-cache eviction accounting.

``ANNCORE_KERNEL_IMPL`` (default "auto") forces the kernel impl — the
tier-2 CI observability job runs this suite under "interpret".
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bss2 import BSS2
from repro.core import synapse
from repro.core.anncore import AnnCore
from repro.core.hybrid import make_scanned_training, run_training
from repro.obs import report as obs_report
from repro.obs import timing as obs_timing
from repro.obs import trace as obs_trace
from repro.ppuvm import isa, programs, specialize
from repro.verif import playback as pb
from repro.verif.mismatch import (Divergence, first_divergence,
                                  ideal_instance, sample_instance)

KERNEL_IMPL = os.environ.get("ANNCORE_KERNEL_IMPL", "auto")


def _events(T, R, key=0, p=0.05):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    ev = (jax.random.uniform(ks[0], (T, R)) < p).astype(jnp.float32)
    ad = jnp.zeros((T, R), jnp.int8)
    return ev, ad


# ---------------------------------------------------------------------------
# Bit-exactness: telemetry must never touch the numbers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["oracle", "fused", "blocked"])
def test_training_on_off_bit_exact(backend):
    on, s_on, _ = run_training(n_trials=3, seed=0, backend=backend,
                               telemetry=True)
    off, s_off, _ = run_training(n_trials=3, seed=0, backend=backend,
                                 telemetry=False)
    np.testing.assert_array_equal(on["w_signed_final"],
                                  off["w_signed_final"])
    for k in off:
        if k != "w_signed_final":
            np.testing.assert_array_equal(np.asarray(on[k]),
                                          np.asarray(off[k]), err_msg=k)
    tele = on["telemetry"]
    assert tele["trials"] == 3
    assert tele["steps"] == 3 * 256
    assert tele["out_spikes"] > 0
    assert tele["dw_updates"] == 3
    assert "telemetry" not in off


def test_training_vm_rule_on_off_bit_exact():
    on, _, _ = run_training(n_trials=3, seed=0, rule_impl="vm",
                            telemetry=True)
    off, _, _ = run_training(n_trials=3, seed=0, rule_impl="vm",
                             telemetry=False)
    np.testing.assert_array_equal(on["w_signed_final"],
                                  off["w_signed_final"])
    assert on["telemetry"]["vm_runs"] == 3


def test_window_on_off_bit_exact_all_routes():
    T, R, C = 512, 64, 64
    ev, ad = _events(T, R, p=0.01)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    w = jax.random.randint(ks[0], (R, C), 0, 64, jnp.int8)
    a = jnp.zeros((R, C), jnp.int8)
    for mode in ("auto", "never", "always"):
        i_off = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse=mode)
        i_on, tele = synapse.synaptic_current_window(
            w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse=mode,
            telemetry=obs_trace.init_telemetry())
        np.testing.assert_array_equal(np.asarray(i_off), np.asarray(i_on),
                                      err_msg=mode)
        assert tele is not None


# ---------------------------------------------------------------------------
# Counter correctness vs hand-counted oracles
# ---------------------------------------------------------------------------

def test_run_counters_match_hand_count():
    cfg = BSS2.reduced()
    core = AnnCore(cfg, ideal_instance(cfg), kernel_impl=KERNEL_IMPL)
    state = state0 = core.init_state()
    state = state._replace(syn=state.syn._replace(
        weights=jnp.full((cfg.n_rows, cfg.n_cols), 45, jnp.int8)))
    ev, ad = _events(96, cfg.n_rows, p=0.04)
    tele0 = obs_trace.init_telemetry()
    state, out = core.run(state, ev, ad, telemetry=tele0)
    s = obs_trace.summary(out["telemetry"])
    assert s["steps"] == 96
    assert s["in_events"] == int(np.count_nonzero(np.asarray(ev)))
    assert s["out_spikes"] == int(np.asarray(out["spikes"]).sum())
    del state0


def test_gate_counters_sparse_fit_and_overflow():
    T, R, C = 1024, 256, 256
    ev, ad = _events(T, R, key=3, p=0.002)
    w = jnp.full((R, C), 20, jnp.int8)
    a = jnp.zeros((R, C), jnp.int8)
    n_ev = int(np.count_nonzero(np.asarray(ev)))
    k_max = int(np.asarray(ev).astype(bool).sum(axis=1).max())

    # fitting window -> routed sparse, census maxima recorded
    _, tele = synapse.synaptic_current_window(
        w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse="auto",
        telemetry=obs_trace.init_telemetry())
    s = obs_trace.summary(tele)
    assert s["gated_windows"] == 1 and s["sparse_windows"] == 1
    assert s["dense_windows"] == 0 and s["overflow_fallbacks"] == 0
    assert s["census_events_max"] == n_ev
    assert s["census_k_max"] == k_max

    # undersized capacity -> observable overflow fallback, dense result
    i_over, tele = synapse.synaptic_current_window(
        w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse="auto", max_events=4,
        telemetry=obs_trace.init_telemetry())
    s = obs_trace.summary(tele)
    assert s["overflow_fallbacks"] == 1 and s["dense_windows"] == 1
    assert s["sparse_windows"] == 0
    i_dense = synapse.synaptic_current_window(
        w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse="never")
    np.testing.assert_array_equal(np.asarray(i_over), np.asarray(i_dense))


def test_gate_counters_static_routes():
    # below the work floor: compiles to the pure dense program, counted
    # as a static dense route (gated_windows stays 0)
    ev, ad = _events(32, 16, p=0.1)
    w = jnp.ones((16, 16), jnp.int8)
    a = jnp.zeros((16, 16), jnp.int8)
    _, tele = synapse.synaptic_current_window(
        w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse="auto",
        telemetry=obs_trace.init_telemetry())
    s = obs_trace.summary(tele)
    assert s["dense_windows"] == 1 and s["gated_windows"] == 0

    _, tele = synapse.synaptic_current_window(
        w, a, ev, ad, 1.0, impl=KERNEL_IMPL, sparse="always",
        telemetry=obs_trace.init_telemetry())
    assert obs_trace.summary(tele)["sparse_windows"] == 1


def test_count_vm_saturation_hand_count():
    regs = jnp.stack([
        jnp.full((4, 4), isa.I16MAX, jnp.int32),
        jnp.full((4, 4), isa.I16MIN, jnp.int32),
        jnp.zeros((4, 4), jnp.int32),
    ])
    tele = obs_trace.count_vm(obs_trace.init_telemetry(), regs)
    s = obs_trace.summary(tele)
    assert s["vm_runs"] == 1
    assert s["vm_sat_hits"] == 32          # two full [4,4] planes
    assert obs_trace.count_vm(None, regs) is None


def test_dw_histogram_hand_count():
    w_old = jnp.zeros((8,), jnp.float32)
    w_new = jnp.asarray([0.0, 1/512, 0.1, 0.3, 1.5, 5.0, 31.0, 40.0],
                        jnp.float32)
    tele = obs_trace.count_dw(obs_trace.init_telemetry(), w_old, w_new)
    s = obs_trace.summary(tele)
    dw = np.abs(np.asarray(w_new))
    expect = np.zeros(obs_trace.DW_BINS, np.int64)
    for b in np.searchsorted(obs_trace.DW_EDGES, dw):
        expect[b] += 1
    assert s["dw_hist"] == expect.tolist()
    assert s["dw_updates"] == 1
    assert s["dw_abs_max"] == pytest.approx(40.0)


def test_update_helpers_identity_on_none():
    assert obs_trace.count_run(None, jnp.zeros((4, 4)),
                               jnp.zeros((4, 4))) is None
    assert obs_trace.count_route(None, sparse=True) is None
    assert obs_trace.count_trial(None, jnp.zeros(4)) is None
    assert obs_trace.count_dw(None, jnp.zeros(4), jnp.ones(4)) is None
    assert obs_trace.summary(None) is None


def test_init_telemetry_distinct_buffers():
    # the training scan donates its carry: duplicate buffers in the
    # telemetry pytree would make donation reject the dispatch
    tele = obs_trace.init_telemetry()
    ptrs = [x.unsafe_buffer_pointer() for x in tele]
    assert len(set(ptrs)) == len(ptrs)


# ---------------------------------------------------------------------------
# Zero retrace: report emission is a pure host-side read
# ---------------------------------------------------------------------------

def test_summary_emission_zero_retrace():
    from repro.core.hybrid import make_experiment
    init, _, meta = make_experiment(instance_key=jax.random.PRNGKey(0),
                                    telemetry=True)
    scanned = make_scanned_training(meta["scanned_training"])
    stims = jnp.asarray([1, 2, 0, 1], jnp.int32)
    state, _ = scanned(init(jax.random.PRNGKey(1)), stims)
    assert scanned._cache_size() == 1
    obs_trace.summary(state.tele)                     # emit a report...
    obs_report.build_report("t", telemetry=obs_trace.summary(state.tele))
    state, _ = scanned(init(jax.random.PRNGKey(2)), stims)  # ...run again
    assert scanned._cache_size() == 1                 # no retrace
    obs_trace.summary(state.tele)


# ---------------------------------------------------------------------------
# Phase timing
# ---------------------------------------------------------------------------

def test_phase_timer_spans():
    t = obs_timing.PhaseTimer()
    with t.span("a") as mark:
        mark(jnp.ones(4) * 2)
    t.time_fn("b", lambda x: x + 1, jnp.ones(3), iters=2)
    s = t.summary()
    assert s["a"]["count"] == 1 and s["b"]["count"] == 2
    assert s["b"]["best_us"] <= s["b"]["mean_us"] + 1e-9


def test_profile_phases_keys():
    cfg = BSS2.reduced()
    core = AnnCore(cfg, ideal_instance(cfg), kernel_impl=KERNEL_IMPL)
    ev, ad = _events(32, cfg.n_rows, p=0.05)
    s = obs_timing.profile_phases(core, core.init_state(), ev,
                                  np.asarray(ad), iters=1)
    assert set(s) >= {"synray", "neuron", "corr", "total"}
    assert all(v["best_us"] > 0 for v in s.values())


def test_profiler_trace_noop():
    with obs_timing.profiler_trace(None):
        pass


# ---------------------------------------------------------------------------
# Run report
# ---------------------------------------------------------------------------

def test_report_roundtrip(tmp_path):
    out, _, _ = run_training(n_trials=3, seed=0, telemetry=True)
    rep = obs_report.build_report(
        "unit", telemetry=out["telemetry"],
        timings={"total": dict(count=1, total_us=5.0, mean_us=5.0,
                               best_us=5.0)},
        cache=obs_timing.cache_snapshot(),
        config=dict(n_trials=3))
    assert rep["telemetry"]["out_spikes"] > 0
    assert rep["git_sha"]
    md = obs_report.to_markdown(rep)
    assert "out_spikes" in md and "Phase timings" in md
    paths = obs_report.write_report(rep, str(tmp_path / "r.json"))
    import json
    j = json.load(open(paths["json"]))
    assert j["telemetry"]["trials"] == 3
    assert os.path.exists(paths["md"])


def test_report_warnings_derived():
    tele = dict(overflow_fallbacks=2, census_events_max=999,
                vm_sat_hits=7)
    rep = obs_report.build_report("w", telemetry=tele,
                                  cache=dict(hits=0, misses=100,
                                             evictions=36, size=64,
                                             max_size=64))
    assert len(rep["warnings"]) == 3
    joined = " ".join(rep["warnings"])
    assert "overflow" in joined and "saturation" in joined \
        and "eviction storm" in joined


# ---------------------------------------------------------------------------
# First-divergence locator
# ---------------------------------------------------------------------------

def _mk_trace():
    return [(64, "SPIKES", np.zeros((64, 8))),
            (64, "RATES", np.arange(8.0)),
            (64, "WEIGHTS", np.ones((4, 8)))]


def test_first_divergence_none_on_match():
    assert first_divergence(_mk_trace(), _mk_trace()) is None


def test_first_divergence_localizes():
    a, b = _mk_trace(), _mk_trace()
    b[0][2][13, 5] = 1.0
    d = first_divergence(a, b)
    assert isinstance(d, Divergence)
    assert d.record == 0 and d.kind == "SPIKES"
    assert d.phase == "neuron-scan"
    assert d.where == (13, 5)
    assert d.step == 64 - 64 + 13           # absolute timestep
    assert d.n_mismatch == 1 and d.max_abs == pytest.approx(1.0)
    assert "index (13, 5)" in d.describe()


def test_first_divergence_structural():
    a, b = _mk_trace(), _mk_trace()
    b[2] = (64, "WEIGHTS", np.ones((4, 9)))
    d = first_divergence(a, b)
    assert d.structural and d.record == 2 and "shape" in d.detail

    d = first_divergence(_mk_trace(), _mk_trace()[:2])
    assert d.structural and "length" in d.detail

    b = _mk_trace()
    b[1] = (64, "CORR", b[1][2])
    d = first_divergence(_mk_trace(), b)
    assert d.structural and d.record == 1


def test_compare_traces_enriched_and_playback_telemetry():
    cfg = BSS2.reduced()
    rng = np.random.default_rng(0)
    T = 48
    ev = (rng.random((T, cfg.n_rows)) < 0.05).astype(np.float32)
    w = rng.integers(0, 40, (cfg.n_rows, cfg.n_cols)).astype(np.int8)
    prog = [pb.write_weights(w), pb.inject(ev), pb.run(16),
            pb.read_rates(), pb.write_ppu_program(programs.stdp_program()),
            pb.ppu_run(), pb.read_weights()]
    fb = pb.FastBackend(cfg, telemetry=True)
    trace = fb.execute(prog)
    s = fb.telemetry_summary()
    assert s["steps"] == T + 16
    assert s["in_events"] == int(ev.sum())
    assert s["vm_runs"] == 1 and s["trials"] == 1

    fb_off = pb.FastBackend(cfg)
    trace_off = fb_off.execute(prog)
    assert pb.compare_traces(trace, trace_off) == []

    bad = [(t, k, np.array(v, copy=True)) for t, k, v in trace_off]
    bad[-1][2].flat[3] += 5
    errs = pb.compare_traces(trace, bad)
    assert errs and "phase ppu" in errs[0] and "index" in errs[0]


# ---------------------------------------------------------------------------
# Specializer-cache accounting
# ---------------------------------------------------------------------------

def test_cache_evictions_counted_and_storm_detected():
    specialize.cache_clear()
    cap = specialize._CACHE_MAX
    with obs_timing.CacheDelta(warn=False) as cd:
        for i in range(cap + 8):
            # distinct 1-instruction programs; jit closures are lazy, so
            # nothing compiles — only the cache bookkeeping runs
            specialize.specialized_callable(
                np.asarray([isa.encode(isa.SPLAT, 0, 0, i)],
                           np.int64))
    assert cd.delta["misses"] == cap + 8
    assert cd.delta["evictions"] == 8
    assert cd.delta["size"] == cap
    assert obs_timing.eviction_storm(cd.delta)

    specialize.cache_clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with obs_timing.CacheDelta():
            for i in range(cap + 1):
                specialize.specialized_callable(
                    np.asarray([isa.encode(isa.SPLAT, 0, 0, i)],
                               np.int64))
    assert any("eviction storm" in str(w.message) for w in rec)
    specialize.cache_clear()


def test_cache_hits_no_storm():
    specialize.cache_clear()
    words = np.asarray(programs.stdp_program(), np.int64)
    with obs_timing.CacheDelta() as cd:
        for _ in range(5):
            specialize.specialized_callable(words)
    assert cd.delta == dict(hits=4, misses=1, evictions=0, size=1,
                            max_size=specialize._CACHE_MAX)
    assert not obs_timing.eviction_storm(cd.delta)
    specialize.cache_clear()


def test_instance_prefix_counters():
    # counters are fleet-wide totals: a [2]-instance prefix doubles the
    # per-instance spike count in one run
    cfg = BSS2.reduced()
    inst = sample_instance(cfg, jax.random.PRNGKey(0), prefix=(2,))
    core = AnnCore(cfg, inst, kernel_impl=KERNEL_IMPL)
    state = core.init_state(prefix=(2,))
    state = state._replace(syn=state.syn._replace(
        weights=jnp.broadcast_to(
            jnp.full((cfg.n_rows, cfg.n_cols), 45, jnp.int8),
            (2, cfg.n_rows, cfg.n_cols))))
    ev, ad = _events(64, cfg.n_rows, p=0.05)
    ev2 = jnp.broadcast_to(ev[:, None, :], (64, 2, cfg.n_rows))
    ad2 = jnp.broadcast_to(ad[:, None, :], (64, 2, cfg.n_rows))
    state, out = core.run(state, ev2, ad2,
                          telemetry=obs_trace.init_telemetry())
    s = obs_trace.summary(out["telemetry"])
    assert s["in_events"] == 2 * int(np.count_nonzero(np.asarray(ev)))
    assert s["out_spikes"] == int(np.asarray(out["spikes"]).sum())
