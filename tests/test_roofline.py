"""Roofline/analysis unit tests: HLO parsing, cell matrix accounting."""
import numpy as np

from repro.analysis.roofline import (collective_seconds, entry_computation,
                                     hbm_bytes_estimate, model_flops_for,
                                     parse_collectives)
from repro.config import ASSIGNED_ARCHS, SHAPES, cell_applicable, get_arch

FAKE_HLO = """
%fused_computation.1 {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %e = f32[1024,1024]{1,0} exponential(%p0)
  ROOT %m = f32[1024,1024]{1,0} multiply(%e, %e)
}

ENTRY %main.1 (p0: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %ag = bf16[64,2048]{1,0} all-gather(bf16[4,2048]{1,0} %x), replica_groups={}
  %ar = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %p0), to_apply=%add
  %rs = f32[64,1024]{1,0} reduce-scatter(f32[1024,1024]{1,0} %ar), dimensions={0}
  %cp = u8[128]{0} collective-permute(u8[128]{0} %y), source_target_pairs={}
  %fus = f32[1024,1024]{1,0} fusion(%p0), kind=kLoop, calls=%fused_computation.1
  %bc = f32[1024,1024]{1,0} bitcast(%fus)
  ROOT %out = f32[1024,1024]{1,0} add(%bc, %p0)
}
"""


class TestCollectiveParsing:
    def test_kinds_and_bytes(self):
        c = parse_collectives(FAKE_HLO)
        assert c["all-gather"]["count"] == 1
        assert c["all-gather"]["bytes"] == 64 * 2048 * 2
        assert c["all-reduce"]["bytes"] == 1024 * 1024 * 4
        # reduce-scatter counts the (larger) operand side
        assert c["reduce-scatter"]["bytes"] == 1024 * 1024 * 4
        assert c["collective-permute"]["bytes"] == 128

    def test_ring_model(self):
        c = parse_collectives(FAKE_HLO)
        s = collective_seconds(c, link_bw=50e9, links=4)
        # all-reduce weighted 2x in the effective model
        assert s["bytes_effective"] > s["bytes_simple"]
        assert s["sec_simple"] == s["bytes_simple"] / 200e9


class TestEntryBytes:
    def test_fusion_internals_excluded(self):
        est = hbm_bytes_estimate(FAKE_HLO)
        # entry ops: all-gather + all-reduce + reduce-scatter + permute +
        # fusion + add results; the exponential/multiply INSIDE the fusion
        # and the bitcast/parameters contribute nothing
        ent = entry_computation(FAKE_HLO)
        assert "exponential" not in est["by_kind"]
        assert "fusion" in est["by_kind"]
        assert est["by_kind"]["fusion"] == 1024 * 1024 * 4
        assert "bitcast" not in est["by_kind"]


class TestCellMatrix:
    def test_40_cells_31_runnable(self):
        total = runnable = 0
        for a in ASSIGNED_ARCHS:
            arch = get_arch(a)
            for s in SHAPES.values():
                total += 1
                ok, reason = cell_applicable(arch, s)
                runnable += ok
                if not ok:
                    assert reason
        assert total == 40
        assert runnable == 31
        # exactly: hubert skips 2 decode shapes; 8 full-attn archs skip
        # long_500k; mamba2+hymba run it
        assert cell_applicable(get_arch("mamba2-130m"), SHAPES["long_500k"])[0]
        assert cell_applicable(get_arch("hymba-1.5b"), SHAPES["long_500k"])[0]
        assert not cell_applicable(get_arch("hubert-xlarge"),
                                   SHAPES["decode_32k"])[0]

    def test_model_flops_scales(self):
        arch = get_arch("phi4-mini-3.8b")
        tr = model_flops_for(arch, SHAPES["train_4k"])
        pf = model_flops_for(arch, SHAPES["prefill_32k"])
        dc = model_flops_for(arch, SHAPES["decode_32k"])
        assert tr == 6 * arch.active_param_count() * 256 * 4096
        assert pf == 2 * arch.active_param_count() * 32 * 32768
        assert dc == 2 * arch.active_param_count() * 128

    def test_moe_active_vs_total(self):
        m = get_arch("moonshot-v1-16b-a3b")
        assert m.active_param_count() < 0.25 * m.param_count()
        assert m.active_param_count() > 0
