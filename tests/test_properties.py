"""Property-based tests (hypothesis) on system invariants."""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stp, synapse, correlation
from repro.parallel import compress as gc
from repro.models.layers import apply_rope
from repro.checkpoint.ckpt import _flatten, _unflatten

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

floats32 = st.floats(-1e6, 1e6, allow_nan=False, width=32)


class TestWeightQuantization:
    @given(hnp.arrays(np.float32, hnp.array_shapes(max_dims=2, max_side=8),
                      elements=floats32))
    def test_bounded_and_idempotent(self, w):
        q = synapse.quantize_weight(jnp.asarray(w))
        qn = np.asarray(q)
        assert qn.min() >= 0 and qn.max() <= synapse.WMAX
        q2 = synapse.quantize_weight(q.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(q2), qn)

    @given(st.floats(0, 63, allow_nan=False),
           st.floats(0, 63, allow_nan=False))
    def test_monotone(self, a, b):
        qa = int(synapse.quantize_weight(jnp.float32(a)))
        qb = int(synapse.quantize_weight(jnp.float32(b)))
        if a <= b:
            assert qa <= qb


class TestSTPInvariants:
    @given(hnp.arrays(np.float32, (12, 4),
                      elements=st.sampled_from([0.0, 1.0])),
           st.floats(0.05, 0.9), st.floats(1.0, 100.0))
    def test_resources_stay_in_unit_interval(self, spikes, u, tau):
        state = stp.init_state((4,))
        for t in range(spikes.shape[0]):
            state = stp.update(state, jnp.asarray(spikes[t]), u=u,
                               tau_rec=tau, dt=1.0)
            r = np.asarray(state.r)
            assert (r >= 0).all() and (r <= 1).all()

    @given(st.floats(0.05, 0.9))
    def test_efficacy_depresses_on_consecutive_spikes(self, u):
        state = stp.init_state((1,))
        ones = jnp.ones((1,))
        code = jnp.full((1,), 8, jnp.int32)
        offs = jnp.zeros((1,))
        last = None
        for _ in range(5):
            e = float(stp.efficacy(state, ones, u=u, offset=offs,
                                   calib_code=code)[0])
            if last is not None:
                assert e <= last + 1e-6
            last = e
            state = stp.update(state, ones, u=u, tau_rec=50.0, dt=0.5)


class TestCorrelationInvariants:
    @given(hnp.arrays(np.float32, (10, 3),
                      elements=st.sampled_from([0.0, 1.0])),
           hnp.arrays(np.float32, (10, 5),
                      elements=st.sampled_from([0.0, 1.0])))
    def test_accumulators_nonneg_bounded_monotone(self, pre, post):
        s = correlation.init_state((), 3, 5)
        prev_c = np.zeros((3, 5))
        for t in range(10):
            s = correlation.update(s, jnp.asarray(pre[t]),
                                   jnp.asarray(post[t]),
                                   tau_pre=5., tau_post=5., dt=1., sat=100.)
            c = np.asarray(s.a_causal)
            assert (c >= prev_c - 1e-6).all(), "causal accum is monotone"
            assert c.max() <= 100.0 + 1e-6
            prev_c = c


class TestCompression:
    @given(hnp.arrays(np.float32, st.integers(1, 256).map(lambda n: (n,)),
                      elements=st.floats(-1e3, 1e3, allow_nan=False,
                                         width=32)))
    def test_roundtrip_error_bounded_by_half_step(self, g):
        q, s = gc.compress(jnp.asarray(g), bits=8)
        back = np.asarray(gc.decompress(q, s))
        step = float(s)
        assert np.abs(back - g).max() <= step * 0.5 + 1e-6


class TestRoPE:
    @given(st.integers(0, 10000), st.integers(1, 8))
    def test_rotation_preserves_norm(self, pos, h):
        x = jax.random.normal(jax.random.PRNGKey(h), (1, 4, h, 16))
        pos_arr = jnp.full((4,), pos)
        y = apply_rope(x, pos_arr, theta=10000.0)
        nx = np.linalg.norm(np.asarray(x), axis=-1)
        ny = np.linalg.norm(np.asarray(y), axis=-1)
        np.testing.assert_allclose(nx, ny, rtol=1e-4)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
        def dot_at(m, n):
            qm = apply_rope(q, jnp.asarray([m]), 10000.0)
            kn = apply_rope(k, jnp.asarray([n]), 10000.0)
            return float(jnp.sum(qm * kn))
        assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
        assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-3


_tree_strategy = st.recursive(
    st.dictionaries(st.text(st.characters(min_codepoint=97, max_codepoint=122),
                            min_size=1, max_size=4),
                    st.just(np.arange(3)), min_size=1, max_size=3),
    lambda children: st.dictionaries(
        st.text(st.characters(min_codepoint=97, max_codepoint=122),
                min_size=1, max_size=4), children, min_size=1, max_size=3),
    max_leaves=8)


class TestCheckpointTree:
    @given(_tree_strategy)
    def test_flatten_unflatten_roundtrip(self, tree):
        flat = _flatten(tree)
        back = _unflatten(flat)
        def eq(a, b):
            if isinstance(a, dict):
                assert set(a) == set(b)
                for k in a:
                    eq(a[k], b[k])
            else:
                np.testing.assert_array_equal(a, b)
        eq(tree, back)


class TestCalibrationProperty:
    @given(st.floats(-0.5, 0.5), st.floats(0.01, 0.2))
    def test_binary_search_residual_bounded(self, target_off, slope):
        from repro.verif.calibration import binary_search_calibrate
        def measure(code):
            return target_off - slope * code.astype(jnp.float32)
        code = binary_search_calibrate(measure, bits=4, shape=(),
                                       target=0.0, increasing=False)
        resid = float(measure(code))
        # residual is within one step above target (or code railed at 0/15)
        c = int(code)
        if 0 < c < 15:
            assert -1e-6 <= resid <= slope + 1e-6
