"""Reproduction test for the paper's §5 R-STDP experiment (Fig. 11).

Claim: "The mean expected reward converges to approximately one for all
neurons during training ... despite pattern overlap" (40% overlap).
"""
import numpy as np
import pytest

from repro.core.hybrid import RSTDPConfig, run_training


def _trailing(mr, sel, n=150):
    """Mean over the last n trials of the population-median <R> (the xi
    random walk keeps exploring, so instantaneous <R> dips transiently —
    the paper's Fig. 11 likewise shows 15/85% error bands)."""
    med = np.median(mr[-n:, sel], axis=1)
    return float(np.mean(med))


def test_fig11_reward_converges_to_one():
    out, state, meta = run_training(n_trials=450, seed=0)
    even = np.asarray(meta["even"]) > 0
    mr = out["mean_reward"]
    te = _trailing(mr, even)
    to = _trailing(mr, ~even)
    assert te > 0.85, f"even population trailing <R> = {te}"
    assert to > 0.85, f"odd population trailing <R> = {to}"
    # discrimination: weights from pattern-A channels are excitatory toward
    # the A-population and depressed toward the B-population
    w = out["w_signed_final"]
    ma = np.asarray(meta["mask_a"]) > 0
    assert w[ma][:, even].mean() > 5.0
    assert w[ma][:, even].mean() > w[ma][:, ~even].mean() + 10.0


def test_reward_improves_from_start():
    """Cheap smoke: trailing reward clearly above the silent-attractor
    baseline (2/3) after 250 trials."""
    out, state, meta = run_training(n_trials=250, seed=1)
    mr = out["mean_reward"]
    assert _trailing(mr, slice(None), n=80) > 0.75


def test_overlap_zero_also_converges():
    """Zero overlap is NOT an easier/faster instance: disjoint patterns use
    2*pattern_size = 10 distinct channels (vs 8 at 40% overlap), i.e. more
    independent weights to learn. At 300 trials the median <R> was still
    rising monotonically (0.36/0.46/0.62/0.71/0.76/0.81 per 50-trial
    window, seed 2) and the trailing-80 mean landed at 0.796 — an
    under-trained test budget, not a convergence bug. With the same 450
    trials the fig11 test uses it reaches 0.865 (seed 2) / 0.915 (seed 3).
    """
    ecfg = RSTDPConfig(overlap=0.0)
    out, state, meta = run_training(n_trials=450, seed=2, ecfg=ecfg)
    assert _trailing(out["mean_reward"], slice(None), n=80) > 0.8
