"""Doc-sync contracts: the documentation layer may not drift.

Three checks, all host-only (no jit):

- every knob row in docs/architecture.md's knob matrix names real
  signatures, and the knob is a parameter of every one of them;
- every relative markdown link in README.md / docs/*.md resolves to a
  file that exists (anchors resolve to a real heading);
- every backticked repo path mentioned in the docs (tests/..., src/...,
  examples/..., benchmarks/...) exists on disk.

A failure here means a doc made a promise the code no longer keeps —
fix the doc or the signature, not the test.
"""
import inspect
import importlib
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = sorted((REPO / "docs").glob("*.md"))
ALL_MD = [REPO / "README.md", *DOCS]

assert DOCS, "docs/ must exist and contain the guides"


# ----------------------------------------------------------------- knob matrix

def _knob_rows():
    """Yield (knob, [dotted_path, ...]) from architecture.md's matrix."""
    text = (REPO / "docs" / "architecture.md").read_text()
    section = text.split("## Knob matrix", 1)[1]
    rows = []
    for line in section.splitlines():
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) < 3 or set(cells[0]) <= {"-", " "} or \
                cells[0] == "knob":
            continue
        knob = re.findall(r"`([^`]+)`", cells[0])
        paths = re.findall(r"`([^`]+)`", cells[1])
        assert len(knob) == 1, f"malformed knob cell: {cells[0]!r}"
        assert paths, f"knob {knob[0]!r} lists no signatures"
        rows.append((knob[0], paths))
    return rows


def _resolve(dotted):
    """Dotted path -> python object (module attr chain)."""
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for attr in parts[i:]:
            obj = getattr(obj, attr)
        return obj
    raise ImportError(dotted)


KNOB_ROWS = _knob_rows()


@pytest.mark.parametrize("knob,paths", KNOB_ROWS,
                         ids=[k for k, _ in KNOB_ROWS])
def test_knob_matrix_matches_signatures(knob, paths):
    for dotted in paths:
        obj = _resolve(dotted)
        if inspect.isclass(obj):
            obj = obj.__init__
        params = inspect.signature(obj).parameters
        assert knob in params, (
            f"docs/architecture.md lists `{knob}` for `{dotted}` but the "
            f"signature has no such parameter: {sorted(params)}")


def test_knob_matrix_is_nonempty():
    # a silent run proves nothing: the parser must have found the table
    assert len(KNOB_ROWS) >= 20


# ----------------------------------------------------------------------- links

def _slugify(heading):
    """GitHub-style anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[`*]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _headings(md_path):
    return {_slugify(m.group(1))
            for m in re.finditer(r"^#+\s+(.+)$", md_path.read_text(),
                                 re.MULTILINE)}


@pytest.mark.parametrize("md", ALL_MD, ids=[p.name for p in ALL_MD])
def test_relative_links_resolve(md):
    text = md.read_text()
    links = re.findall(r"\[[^\]]+\]\(([^)\s]+)\)", text)
    assert links or md.name != "README.md", "README must be an index"
    for target in links:
        if re.match(r"^[a-z]+://", target) or target.startswith("#"):
            continue
        path_part, _, anchor = target.partition("#")
        resolved = (md.parent / path_part).resolve()
        assert resolved.exists(), f"{md.name}: broken link {target!r}"
        if anchor:
            assert anchor in _headings(resolved), (
                f"{md.name}: anchor {target!r} matches no heading")


@pytest.mark.parametrize("md", ALL_MD, ids=[p.name for p in ALL_MD])
def test_backticked_repo_paths_exist(md):
    text = md.read_text()
    for token in re.findall(r"`([^`]+)`", text):
        if " " in token or "*" in token or "{" in token:
            continue
        if not re.match(r"^(tests|src|examples|benchmarks|docs)/", token):
            continue
        path = token.split("::")[0]
        assert (REPO / path).exists(), f"{md.name}: dangling path `{token}`"


# ---------------------------------------------------------- index completeness

def test_readme_links_every_doc():
    readme = (REPO / "README.md").read_text()
    for doc in DOCS:
        assert f"docs/{doc.name}" in readme, (
            f"README index must link docs/{doc.name}")
