"""Differential fuzz harness for the PPU-VM executors (ISSUE 3).

The paper's verification methodology in miniature: the same program runs
on independent implementations and the results are diffed (§3-§4). Here
the implementations are the four executors —

  numpy        straight-loop reference (repro.ppuvm.interp.run_program_np)
  scan         lax.scan + lax.switch interpreter (run_program_jax)
  specialized  trace-time specializer (repro.ppuvm.specialize)
  pallas       tile VM in kernel-interpret mode (repro.kernels.ppuvm_exec)

— and the contract is BIT-identical weights and registers for *every
valid word stream*, not just the shipped programs. The generator
produces bounded random programs in which every opcode is reachable,
with random register/row operands; operand planes mix random values with
saturation edge cases (±1.0, ±1 LSB, the 0x7FFF/0x8000 rails, 6-bit
weight extremes, CADC code extremes, rate-counter overflow).

Runs on plain numpy RNG so the corpus is deterministic and needs no
extra deps; when `hypothesis` is installed (CI tier-2) an additional
property-based pass draws programs from strategies.

All programs are NOP-padded to a fixed length so the scan and Pallas
executors hit their jit caches across the whole corpus.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ppuvm_exec import ops as exec_ops
from repro.ppuvm import interp, isa, specialize
from repro.ppuvm.asm import Asm

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

R, C = 8, 8
PAD_LEN = 40                    # fixed word count -> one jit cache entry

# saturation / wrap-candidate corpus the generator must draw from:
# ±1.0, ±1 LSB, the int16 rails (0x7FFF = 127.996, 0x8000 = -128.0) and
# values whose products/sums cross them
EDGE_SPLATS = (1.0, -1.0, 1 / isa.ONE, -1 / isa.ONE, 127.996, -128.0,
               127.0, -127.0, 64.0, -64.0, 0.0)

_jit_scan = jax.jit(interp.run_program_jax)
_jit_pallas = jax.jit(
    lambda words, w, qc, qa, rates, mod, noise: exec_ops.run_program_tiled(
        words, w, qc, qa, rates, mod, noise, interpret=True))


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

def gen_program(rng: np.random.RandomState, max_len: int = 32) -> np.ndarray:
    """One random *valid* program: bounded length, every opcode drawable,
    random register/slot/shift operands, edge-value constants mixed in."""
    a = Asm()
    n = int(rng.randint(1, max_len + 1))
    ops = rng.randint(0, isa.N_OPS, n)
    for op in ops:
        rd, ra, rb = (int(x) for x in rng.randint(0, isa.N_REGS, 3))
        sh = int(rng.randint(0, 20))          # beyond the clamp on purpose
        if op == isa.SPLAT:
            if rng.rand() < 0.5:
                val = float(EDGE_SPLATS[rng.randint(len(EDGE_SPLATS))])
            else:
                val = float(rng.uniform(-130, 130))
            a.splat(rd, val)
        elif op == isa.LDMOD:
            a.ldmod(rd, int(rng.randint(0, 4)))   # incl. out-of-range slots
        elif op == isa.STW:
            a.stw(ra)
        elif op in (isa.MOV,):
            a.mov(rd, ra)
        elif op in (isa.LDW, isa.LDCAUSAL, isa.LDACAUSAL, isa.LDRATE,
                    isa.LDNOISE):
            a._emit(op, rd)
        elif op in (isa.SHL, isa.SHR):
            a._emit(op, rd, ra, isa.alu_imm(0, sh))
        elif op == isa.NOP:
            a.nop()
        else:                                 # 3-reg ALU (+ MULF shift)
            a._emit(op, rd, ra, isa.alu_imm(rb, sh if op == isa.MULF else 0))
    words = a.build()
    isa.validate(words)                        # generator only emits valid
    assert words.shape[0] <= PAD_LEN
    return words


def gen_operands(rng: np.random.RandomState, edge: bool = False) -> dict:
    """Random operand planes; ``edge=True`` pins them to the saturation
    corpus (weight rails 0/63, CADC rails 0/255, rate overflow, ±1 mod,
    int16-rail noise)."""
    if edge:
        w_pool = np.array([0, 63, 1, 62], np.int32)
        q_pool = np.array([0, 255, 1, 254], np.int32)
        return dict(
            weights=w_pool[rng.randint(0, 4, (R, C))],
            qc=q_pool[rng.randint(0, 4, (R, C))],
            qa=q_pool[rng.randint(0, 4, (R, C))],
            rates=np.array([0.0, 1.0, 127.0, 1000.0] * (C // 4),
                           np.float32)[:C],
            mod=np.stack([np.full(C, isa.I16MAX, np.int32),
                          np.full(C, isa.I16MIN, np.int32)]),
            noise=np.where(rng.rand(R, C) < 0.5, isa.I16MAX,
                           isa.I16MIN).astype(np.int32),
        )
    return dict(
        weights=rng.randint(0, 64, (R, C)).astype(np.int32),
        qc=rng.randint(0, 256, (R, C)).astype(np.int32),
        qa=rng.randint(0, 256, (R, C)).astype(np.int32),
        rates=rng.randint(0, 300, (C,)).astype(np.float32),
        mod=isa.to_fixed(rng.uniform(-2, 2, (2, C))),
        noise=isa.to_fixed(rng.uniform(-128, 128, (R, C))),
    )


def _pad(words: np.ndarray) -> np.ndarray:
    """NOP-pad to the next multiple of PAD_LEN (NOP == all-zero word):
    programs up to PAD_LEN share ONE jit cache entry; longer custom rules
    (README's verify-your-rule flow) still work, one entry per bucket."""
    n = max(PAD_LEN, -(-int(words.shape[0]) // PAD_LEN) * PAD_LEN)
    out = np.zeros(n, np.int32)
    out[:words.shape[0]] = words
    return out


def run_all_executors(words: np.ndarray, ops: dict) -> dict:
    """Execute on all four executors; return {name: (wmem, regs)} as
    numpy arrays."""
    words = _pad(np.asarray(words, np.int32))
    j = {k: jnp.asarray(v) for k, v in ops.items()}
    args = (j["weights"], j["qc"], j["qa"], j["rates"], j["mod"], j["noise"])
    out = {
        "numpy": interp.run_program_np(
            words, ops["weights"], ops["qc"], ops["qa"], ops["rates"],
            ops["mod"], ops["noise"]),
        "scan": _jit_scan(jnp.asarray(words), *args),
        "specialized": specialize.run_program_specialized(words, *args),
        "pallas": _jit_pallas(jnp.asarray(words), *args),
    }
    return {k: (np.asarray(w), np.asarray(r)) for k, (w, r) in out.items()}


def assert_bit_identical(outs: dict, ctx: str = ""):
    w_ref, r_ref = outs["numpy"]
    for name, (w, r) in outs.items():
        np.testing.assert_array_equal(
            w, w_ref, err_msg=f"{name} weights diverge from numpy {ctx}")
        np.testing.assert_array_equal(
            r, r_ref, err_msg=f"{name} registers diverge from numpy {ctx}")


# ---------------------------------------------------------------------------
# the differential fuzz corpus (deterministic, >= 200 programs)
# ---------------------------------------------------------------------------

class TestDifferentialFuzz:
    N_PROGRAMS = 200

    def test_fuzz_corpus_bit_identical(self):
        """>= 200 random valid programs x 4 executors, bit-identical."""
        for seed in range(self.N_PROGRAMS):
            rng = np.random.RandomState(seed)
            words = gen_program(rng)
            ops = gen_operands(rng, edge=(seed % 5 == 0))
            assert_bit_identical(run_all_executors(words, ops),
                                 ctx=f"(seed {seed})")

    def test_corpus_reaches_every_opcode(self):
        """The generator must be able to emit every opcode — otherwise
        the fuzz corpus silently under-covers the ISA."""
        seen = set()
        for seed in range(self.N_PROGRAMS):
            words = gen_program(np.random.RandomState(seed))
            seen |= set(((np.asarray(words, np.int64) >> 26) & 0x3F)
                        .tolist())
        assert seen == set(range(isa.N_OPS)), \
            f"missing opcodes {set(range(isa.N_OPS)) - seen}"

    def test_edge_value_saturation_program(self):
        """Explicit wrap-candidate program: every edge constant is
        splatted, summed against itself (0x7FFF + anything must clamp,
        not wrap), multiplied at shift 0 (max product magnitude), and
        stored."""
        a = Asm()
        for i, v in enumerate((127.996, -128.0, 1.0, -1.0, 1 / isa.ONE)):
            a.splat(i % isa.N_REGS, v)
        a.add(0, 0, 0)             # I16MAX + I16MAX -> clamp
        a.sub(1, 1, 0)             # I16MIN - I16MAX -> clamp
        a.mulf(2, 0, 1, 0)         # huge product, shift 0 -> clamp
        a.mulf(3, 4, 4, 16)        # tiny product, max shift -> 0 or ±1
        a.shl(4, 0, 15)            # clamp via shift
        a.ldw(5)
        a.add(5, 5, 0)             # weight + I16MAX
        a.stw(5)                   # must store 63, not wrap
        for seed in (0, 1, 2):
            ops = gen_operands(np.random.RandomState(seed), edge=True)
            outs = run_all_executors(a.build(), ops)
            assert_bit_identical(outs, ctx="(edge program)")
            assert (outs["numpy"][0] == 63).all(), "store must saturate"

    def test_edge_operand_planes(self):
        """Shipped programs on the saturation operand corpus."""
        from repro.ppuvm import programs
        for builder in (lambda: programs.rstdp_program(eta=0.5),
                        lambda: programs.stdp_program(),
                        lambda: programs.homeostasis_program(
                            target_rate=4.0)):
            for seed in range(3):
                ops = gen_operands(np.random.RandomState(seed), edge=True)
                assert_bit_identical(
                    run_all_executors(builder(), ops),
                    ctx="(edge operands)")

    def test_pallas_multi_tile_and_batched_prefix(self):
        """The tile-VM paths the 8x8 corpus can't reach: a real multi-tile
        grid (16x16 with rb=cb=8 -> 2x2 tiles, exercising every BlockSpec
        index map) and an instance-prefix vmap fold (axis conventions:
        mod at axis 1 in, regs prefix at axis 1 out)."""
        for seed in range(8):
            rng = np.random.RandomState(1000 + seed)
            words = jnp.asarray(_pad(gen_program(rng)))
            for shape in ((16, 16), (2, 16, 16)):
                r, c = shape[-2:]
                ops = dict(
                    weights=rng.randint(0, 64, shape).astype(np.int32),
                    qc=rng.randint(0, 256, shape).astype(np.int32),
                    qa=rng.randint(0, 256, shape).astype(np.int32),
                    rates=rng.randint(0, 300, (*shape[:-2], c)
                                      ).astype(np.float32),
                    mod=isa.to_fixed(rng.uniform(-2, 2, (2, *shape[:-2],
                                                         c))),
                    noise=isa.to_fixed(rng.uniform(-128, 128, shape)),
                )
                wn, rn = interp.run_program_np(np.asarray(words), **ops)
                wp, rp = exec_ops.run_program_tiled(
                    words, *(jnp.asarray(ops[k]) for k in
                             ("weights", "qc", "qa", "rates", "mod",
                              "noise")),
                    rb=8, cb=8, interpret=True)
                np.testing.assert_array_equal(np.asarray(wp), wn)
                np.testing.assert_array_equal(np.asarray(rp), rn)

    def test_fuzz_detects_semantic_divergence(self):
        """Harness sanity: a deliberately perturbed result must FAIL the
        bit-identity assertion (the diff harness can actually see)."""
        rng = np.random.RandomState(0)
        outs = run_all_executors(gen_program(rng), gen_operands(rng))
        w, r = outs["scan"]
        outs["scan"] = (w + (w == 0), r)      # flip at least one lane
        with pytest.raises(AssertionError):
            assert_bit_identical(outs)


# ---------------------------------------------------------------------------
# hypothesis pass (CI tier-2; skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_instr=st.integers(1, 32),
           edge=st.booleans())
    def test_fuzz_hypothesis(seed, n_instr, edge):
        """Property: ANY generated valid program is bit-identical across
        all four executors (hypothesis shrinks failures to a minimal
        program)."""
        rng = np.random.RandomState(seed)
        words = gen_program(rng, max_len=n_instr)
        ops = gen_operands(rng, edge=edge)
        assert_bit_identical(run_all_executors(words, ops),
                             ctx=f"(hypothesis seed {seed})")
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fuzz_hypothesis():
        pass
