"""The scan-over-segments forward must equal the unrolled forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig, get_arch
from repro.models.transformer import build_model, input_specs, _layer_segments
from repro.parallel.sharding import ShardingCtx, init_params

SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _batch(arch, key):
    specs = input_specs(arch, SHAPE, None)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, arch.vocab,
                                          jnp.int32)
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("name", ["smollm-360m", "moonshot-v1-16b-a3b",
                                  "hymba-1.5b", "mamba2-130m",
                                  "hubert-xlarge"])
def test_scan_equals_unrolled(name):
    arch = get_arch(name).reduced()
    ctx_scan = ShardingCtx(unroll=False)
    ctx_unroll = ShardingCtx(unroll=True)
    b_scan = build_model(arch, ctx_scan)
    b_unroll = build_model(arch, ctx_unroll)
    params = init_params(b_scan.decls, jax.random.PRNGKey(0))
    batch = _batch(arch, jax.random.PRNGKey(1))

    l_scan = float(jax.jit(b_scan.loss)(params, batch))
    l_unroll = float(jax.jit(b_unroll.loss)(params, batch))
    np.testing.assert_allclose(l_scan, l_unroll, rtol=1e-4)

    g_scan = jax.jit(jax.grad(b_scan.loss))(params, batch)
    g_unroll = jax.jit(jax.grad(b_unroll.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g_scan), jax.tree.leaves(g_unroll)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_segments_cover_all_layers():
    for name in ("hymba-1.5b", "moonshot-v1-16b-a3b", "phi4-mini-3.8b"):
        arch = get_arch(name)
        segs = _layer_segments(arch)
        covered = []
        for lo, hi, kind in segs:
            covered.extend(range(lo, hi))
        assert covered == list(range(arch.n_layers)), (name, segs)
