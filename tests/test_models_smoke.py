"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ASSIGNED_ARCHS, ShapeConfig, get_arch
from repro.models.transformer import build_model, input_specs, prefix_len
from repro.parallel.sharding import ShardingCtx, init_params

SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _batch(arch, key):
    specs = input_specs(arch, SHAPE, None)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            hi = max(arch.vocab, 2)
            batch[k] = jax.random.randint(key, v.shape, 0, hi, jnp.int32)
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_and_train_step(name):
    arch = get_arch(name).reduced()
    ctx = ShardingCtx()
    bundle = build_model(arch, ctx)
    key = jax.random.PRNGKey(0)
    params = init_params(bundle.decls, key)
    batch = _batch(arch, jax.random.PRNGKey(1))

    logits, aux, _ = jax.jit(bundle.forward)(params, batch)
    b, s = 2, SHAPE.seq_len
    assert logits.shape == (b, s, arch.vocab_padded), logits.shape
    assert np.isfinite(np.asarray(logits)).all(), "NaN/Inf in logits"

    # one SGD step through the loss
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert np.isfinite(float(loss)), loss
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), "NaN in grads"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = jax.jit(bundle.loss)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", [a for a in ASSIGNED_ARCHS
                                  if not get_arch(a).is_encoder_only])
def test_decode_step(name):
    arch = get_arch(name).reduced()
    ctx = ShardingCtx()
    bundle = build_model(arch, ctx)
    params = init_params(bundle.decls, jax.random.PRNGKey(0))
    cache_decls = bundle.make_cache_decls(2, SHAPE.seq_len)
    cache = init_params(cache_decls, jax.random.PRNGKey(1))
    cache = jax.tree.map(jnp.zeros_like, cache)
    token = jnp.ones((2, 1), jnp.int32)
    step = jax.jit(bundle.decode_step)
    logits, cache = step(params, cache, token, jnp.int32(0))
    assert logits.shape == (2, 1, arch.vocab_padded)
    logits, cache = step(params, cache, token * 2, jnp.int32(1))
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", [a for a in ASSIGNED_ARCHS
                                  if not get_arch(a).is_encoder_only])
def test_prefill_matches_decode(name):
    """Prefill then one decode step == forward over the extended sequence."""
    arch = get_arch(name).reduced()
    ctx = ShardingCtx()
    bundle = build_model(arch, ctx)
    params = init_params(bundle.decls, jax.random.PRNGKey(0))

    shape = ShapeConfig("smoke", 32, 2, "prefill")
    batch = _batch(arch, jax.random.PRNGKey(1))
    batch.pop("labels", None)
    s_total = 32

    logits_p, cache = jax.jit(bundle.prefill)(params, batch)
    # pad the kv caches out to s_total + 1 for the decode step
    def pad_kv(x):
        return jnp.pad(x, ((0, 0), (0, 8), (0, 0), (0, 0)))
    cache = jax.tree.map(
        lambda x: pad_kv(x) if x.ndim == 4 and x.shape[1] == s_total else x,
        cache)
    tok = jnp.full((2, 1), 3, jnp.int32)
    logits_d, _ = jax.jit(bundle.decode_step)(params, cache, tok,
                                              jnp.int32(s_total))

    # reference: full forward over [tokens ++ tok]
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_f, _, _ = jax.jit(bundle.forward)(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_f[:, -1]),
                               rtol=2e-2, atol=2e-2)
