"""C1' hybrid-plasticity LM trainer: the three-factor rule on a quantized
readout must learn the synthetic Markov structure, fully on-device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig, get_arch, ASSIGNED_ARCHS
from repro.data.pipeline import SyntheticLMPipeline
from repro.parallel.sharding import init_params
from repro.plasticity.three_factor import HybridReadoutTrainer, \
    ThreeFactorConfig

SHAPE = ShapeConfig("smoke", 32, 4, "train")


def test_three_factor_learns_markov_readout():
    arch = get_arch("smollm-360m").reduced()
    tr = HybridReadoutTrainer(arch, pcfg=ThreeFactorConfig(eta=4.0))
    params = init_params(tr.bundle.decls, jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(arch, SHAPE, seed=0)
    st = tr.init_state(jax.random.PRNGKey(1))
    accs = []
    for i in range(100):
        st, m = tr.step(params, st, pipe.next_batch())
        accs.append(float(m["acc_greedy"]))
    # sampled-match rewards are sparse on a ~500-way task, so three-factor
    # learning is slow (the paper's own task is 16 binary neurons) — the
    # criterion is a clear multiple of chance (1/vocab ~ 0.002), not
    # supervised-level accuracy
    chance = 1.0 / arch.vocab
    assert np.mean(accs[-10:]) > 8 * chance, (chance, np.mean(accs[-10:]))
    assert np.mean(accs[-10:]) > np.mean(accs[:5]) + 0.01
    # weights stay within the signed 6-bit envelope (saturating writes)
    assert int(jnp.max(st.w_q)) <= 31 and int(jnp.min(st.w_q)) >= -31


def test_mean_reward_tracks(paper_gamma=0.05):
    arch = get_arch("qwen1.5-0.5b").reduced()
    tr = HybridReadoutTrainer(arch)
    params = init_params(tr.bundle.decls, jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(arch, SHAPE, seed=3)
    st = tr.init_state(jax.random.PRNGKey(1))
    for _ in range(5):
        st, m = tr.step(params, st, pipe.next_batch())
    assert 0.0 <= float(st.mean_r) <= 1.0


@pytest.mark.parametrize("name", ["mamba2-130m", "hymba-1.5b",
                                  "moonshot-v1-16b-a3b"])
def test_applies_across_families(name):
    """DESIGN.md §6: the scheme is architecture-agnostic."""
    arch = get_arch(name).reduced()
    tr = HybridReadoutTrainer(arch)
    params = init_params(tr.bundle.decls, jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(arch, SHAPE, seed=0)
    st = tr.init_state(jax.random.PRNGKey(1))
    st, m = tr.step(params, st, pipe.next_batch())
    assert np.isfinite(float(m["reward"]))
