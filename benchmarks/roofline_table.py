"""Render the roofline table from the dry-run results JSON (§Roofline)."""
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def _recompute(r):
    """Re-derive MODEL_FLOPS-based metrics with current config code (the
    stored values may predate fixes, e.g. MoE active-param counting)."""
    from repro.config import SHAPES, get_arch
    from repro.analysis.roofline import model_flops_for
    try:
        arch = get_arch(r["arch"])
        shape = SHAPES[r["shape"]]
        mf = model_flops_for(arch, shape)
        r = dict(r)
        r["model_flops_global"] = mf
        hlo_global = r["flops_per_dev"] * r["n_devices"]
        r["useful_flops_ratio"] = mf / max(hlo_global, 1.0)
        r["mfu"] = mf / (r["n_devices"] * 197e12 * max(
            r["t_compute"], r["t_memory"], r["t_collective"]))
    except Exception:
        pass
    return r


def fmt_row(r):
    if r["status"] == "SKIP":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP — "
                f"{r['reason']} | | | | | |")
    if r["status"] != "OK":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | | | |")
    if r["mesh"] == "2x16x16" and r.get("step_kind") in ("train", "prefill"):
        # multi-pod rows compile via the production scan path: they prove
        # the pod axis shards + fits (temp/collectives meaningful), but a
        # while-body is costed once, so FLOP/byte terms are not roofline-
        # valid — the roofline table is single-pod by design.
        return ("| {arch} | {shape} | {mesh} | sharding-proof (scan): "
                "temp {t:.1f} GiB, coll {c:.1f} GB/dev, compile OK "
                "| | | | | |").format(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            t=r["temp_bytes"] / 2**30,
            c=r["coll_sec"]["bytes_simple"] / 1e9)
    r = _recompute(r)
    tc, tm, tcoll = r["t_compute"], r["t_memory"], r["t_collective"]
    probe = " (probed)" if r.get("depth_probe") else ""
    return ("| {arch} | {shape} | {mesh}{probe} | {tc:.2e} | {tm:.2e} "
            "| {tcoll:.2e} | {bn} | {ratio:.2f} | {mfu:.1%} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"], probe=probe,
        tc=tc, tm=tm, tcoll=tcoll, bn=r["bottleneck"],
        ratio=r["useful_flops_ratio"], mfu=r["mfu"])


def run(path=RESULTS):
    if not Path(path).exists():
        print(f"(no dry-run results at {path} — run repro.launch.dryrun)")
        return dict(name="roofline", cells=0)
    recs = json.loads(Path(path).read_text())
    print("| arch | shape | mesh | t_compute(s) | t_memory(s) | t_coll(s) "
          "| bottleneck | 6ND/HLO | MFU@roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    order = sorted(recs.values(), key=lambda r: (r["mesh"], r["arch"],
                                                 r["shape"]))
    for r in order:
        print(fmt_row(r))
    n_ok = sum(1 for r in recs.values() if r["status"] == "OK")
    print(f"\n{n_ok} OK / {len(recs)} cells")
    return dict(name="roofline", cells=n_ok)


if __name__ == "__main__":
    run()
