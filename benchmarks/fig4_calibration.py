"""Paper Fig. 4: STP efficacy-offset distribution before/after MC
calibration, 128 virtual driver instances."""
import jax
import numpy as np


def run():
    from repro.configs.bss2 import BSS2
    from repro.verif.calibration import calibrate_stp

    key = jax.random.PRNGKey(42)
    offsets = BSS2.mismatch.sigma_stp_offset * jax.random.normal(key, (128,))
    codes, m = calibrate_stp(BSS2, offsets)
    before = np.asarray(m["before"])
    after = np.asarray(m["after"])

    def hist(x, lo=-0.8, hi=0.8, bins=16):
        h, edges = np.histogram(x, bins=bins, range=(lo, hi))
        return " ".join(f"{c:3d}" for c in h)

    print("# Fig. 4 reproduction — offset distribution (128 instances)")
    print(f"before: std={before.std():.4f}  [{hist(before)}]")
    print(f"after : std={after.std():.4f}  [{hist(after)}]")
    ratio = before.std() / max(after.std(), 1e-9)
    print(f"narrowing factor: {ratio:.1f}x "
          f"(paper: post-calibration spread within trim resolution)")
    return dict(name="fig4_calibration",
                std_before=float(before.std()),
                std_after=float(after.std()),
                narrowing=float(ratio))


if __name__ == "__main__":
    run()
