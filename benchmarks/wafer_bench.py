"""Wafer weak-scaling + inter-chip bus throughput.

Weak scaling: K chips of fixed per-chip size on the ring topology (one
out-link per chip, so per-chip routing work is constant) against the
K=1 baseline — the wafer premise is that chips emulate concurrently, so
per-window time should grow far slower than K. (On the single-CPU
container the emulation itself serializes to ~Kx; the rung exists to
catch the router adding superlinear cost on top.)

Bus throughput: routed events per wall-clock second through the router
ALONE (``route()`` on a busy spike grid, full-fan-out all2all routes,
compact transport) against the paper's ~0.4M events/s software
event-bus budget (the fig8 anchor) — the same quantity the silicon
verification budgets for the inter-chip link.
"""
import time

import numpy as np


REPEATS = 6
CHIPS = (1, 2, 4, 8)
R, C, T, W = 32, 16, 128, 4
ROUTES_PER_LINK = 4


def _plan_and_arrays(K, rng, kind="ring"):
    from repro.wafer import WaferTopology, make_plan

    routes = []
    for s in range(K):
        dsts = [(s + 1) % K] if kind == "ring" else list(range(K))
        for d in dsts:
            for _ in range(ROUTES_PER_LINK):
                routes.append((s, int(rng.integers(C)), d,
                               int(rng.integers(R)), 7))
    plan = make_plan(WaferTopology(K, kind), R, C, routes)
    w = rng.integers(20, 60, (K, R, C)).astype(np.int8)
    a = np.zeros((K, R, C), np.int8)
    relay = plan.relay_rows()
    for k in range(K):
        a[k][relay[k]] = 7
    return plan, w, a


def _bench(fn, *args):
    """best-of wall time of a blocked call (compile outside)."""
    import jax
    best = float("inf")
    out = None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run():
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.bss2 import BSS2
    from repro.core.anncore import AnnCore
    from repro.obs import trace as obs_trace
    from repro.verif.mismatch import sample_instance
    from repro.wafer import InterChipRouter, run_windows

    cfg = dataclasses.replace(BSS2.reduced(), n_rows=R, n_cols=C)
    rng = np.random.default_rng(0)
    ev = (rng.random((W, T, 1, R)) < 0.15).astype(np.float32)
    ad = np.zeros((W, T, 1, R), np.int8)

    scaling = []
    for K in CHIPS:
        plan, w, a = _plan_and_arrays(K, rng)
        inst = sample_instance(cfg, jax.random.PRNGKey(3), (K,))
        core = AnnCore(cfg, inst, backend="fused")
        router = InterChipRouter(plan, link_mode="auto")
        st = core.init_state((K,))
        st = st._replace(syn=st.syn._replace(weights=jnp.asarray(w),
                                             addresses=jnp.asarray(a)))
        evK = jnp.asarray(np.broadcast_to(ev, (W, T, K, R)))
        adK = jnp.asarray(np.broadcast_to(ad, (W, T, K, R)))
        tele = obs_trace.init_telemetry()

        fn = jax.jit(lambda s, e, d: run_windows(core, router, s, e, d,
                                                 telemetry=tele))
        jax.block_until_ready(fn(st, evK, adK))   # compile
        best, (_, out) = _bench(fn, st, evK, adK)
        routed = int(np.asarray(out["telemetry"].routed_events))
        us_per_win = best / W * 1e6
        ev_per_s = routed / best if best > 0 else 0.0
        scaling.append(dict(n_chips=K, us_per_window=round(us_per_win, 1),
                            routed_events=routed,
                            routed_events_per_s=round(ev_per_s, 1),
                            spikes=int(np.asarray(out["spikes"]).sum())))
        print(f"K={K}: {us_per_win:8.1f} us/window, {routed:6d} routed, "
              f"{ev_per_s / 1e6:7.3f} M events/s", flush=True)

    base = scaling[0]["us_per_window"]
    for row in scaling:
        row["weak_scaling_vs_k1"] = round(row["us_per_window"] / base, 2)

    # router-only bus throughput: full fan-out routes, busy traffic, the
    # compact (event-record) transport — no emulation in the timed region
    K = 4
    routes = [(s, c, d, (c * K + s + d) % R, 7)
              for s in range(K) for d in range(K) for c in range(C)]
    from repro.wafer import WaferTopology, make_plan
    plan = make_plan(WaferTopology(K, "all2all"), R, C, routes)
    router = InterChipRouter(plan, link_mode="compact",
                             link_budget=T * R, link_step_budget=R)
    spikes = jnp.asarray(
        (rng.random((T, K, C)) < 0.5).astype(np.float32))
    tele = obs_trace.init_telemetry()
    route_fn = jax.jit(lambda s: router.route(s, tele))
    jax.block_until_ready(route_fn(spikes))       # compile
    best, (_, tl) = _bench(route_fn, spikes)
    routed = int(np.asarray(tl.routed_events))
    bus = routed / best if best > 0 else 0.0
    bus_budget = 0.4e6   # paper: ~0.4M events/s software event-bus path
    print(f"router-only: {routed} routed events in {best * 1e6:.0f} us -> "
          f"{bus / 1e6:.3f} M events/s "
          f"({bus / bus_budget:.1f}x the 0.4M events/s bus budget)")
    return dict(weak_scaling=scaling,
                router_routed_events=routed,
                router_events_per_s=round(bus, 1),
                paper_bus_budget_events_per_s=bus_budget,
                budget_ratio=round(bus / bus_budget, 2))
