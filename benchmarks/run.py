"""Benchmark harness — one entry per paper figure/claim + framework perf.

  fig4_calibration     paper Fig. 4  (MC calibration narrows STP offsets)
  fig8_event_interface paper Fig. 8  (event-bus integrity, adapted)
  fig11_rstdp          paper Fig. 11 (R-STDP reward -> ~1 @ 40% overlap)
  step_time            paper §5     (290us claim: on-device vs host loop)
  kernels              Pallas hot-spot microbenchmarks
  roofline             §Roofline table from the dry-run artifacts
"""
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig4_calibration, fig8_event_interface,
                            fig11_rstdp, step_time, kernels_bench,
                            roofline_table)
    suites = [
        ("fig4_calibration", fig4_calibration.run),
        ("fig8_event_interface", fig8_event_interface.run),
        ("fig11_rstdp", fig11_rstdp.run),
        ("step_time", step_time.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_table.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    results = []
    failed = 0
    for name, fn in suites:
        if only and only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            r = fn() or {}
            r.setdefault("name", name)
            r["seconds"] = round(time.perf_counter() - t0, 2)
            results.append(r)
        except Exception:
            failed += 1
            traceback.print_exc()
    print("\n# name,us_per_call,derived")
    for r in results:
        us = r.get("fused_us") or r.get("seconds", 0) * 1e6
        derived = {k: v for k, v in r.items()
                   if k not in ("name", "seconds")}
        print(f"{r['name']},{us:.1f},{derived}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
