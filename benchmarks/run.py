"""Benchmark harness — one entry per paper figure/claim + framework perf.

  fig4_calibration     paper Fig. 4  (MC calibration narrows STP offsets)
  fig8_event_interface paper Fig. 8  (event-bus integrity, adapted)
  fig11_rstdp          paper Fig. 11 (R-STDP reward -> ~1 @ 40% overlap)
  step_time            paper §5     (290us claim: scan vs dispatch vs host)
  kernels              Pallas hot-spot microbenchmarks
  ppuvm                PPU-VM executor ladder (scan / specialized /
                       pallas) vs the fixed-function rule; the ladder is
                       emitted under ``executor_ladder`` in --json output
  roofline             §Roofline table from the dry-run artifacts

Usage:
  PYTHONPATH=src python -m benchmarks.run [suite] [--json BENCH_x.json]

``--json`` persists the machine-readable results (the bench trajectory
across PRs); without it results are print-only.
"""
import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def _host_header():
    """Attribution header for BENCH_* trajectory files: which commit, which
    accelerator, and which AnnCore backend produced the numbers (ROADMAP
    "bench trajectory discipline" — the files travel across machines)."""
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        sha = None
    import jax
    backend = jax.default_backend()
    return dict(git_sha=sha, jax_backend=backend,
                anncore_backend="blocked" if backend == "tpu" else "fused")


def _jsonable(x):
    """Best-effort conversion of numpy/jax scalars and containers."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if hasattr(x, "item") and getattr(x, "ndim", 1) == 0:
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    if isinstance(x, (int, float, str, bool)) or x is None:
        return x
    return repr(x)


def main() -> None:
    from benchmarks import (fig4_calibration, fig8_event_interface,
                            fig11_rstdp, step_time, kernels_bench,
                            ppuvm_bench, roofline_table)
    suites = [
        ("fig4_calibration", fig4_calibration.run),
        ("fig8_event_interface", fig8_event_interface.run),
        ("fig11_rstdp", fig11_rstdp.run),
        ("step_time", step_time.run),
        ("kernels", kernels_bench.run),
        ("ppuvm", ppuvm_bench.run),
        ("roofline", roofline_table.run),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single suite by name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist machine-readable results to PATH")
    args = ap.parse_args()
    results = []
    failed = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            r = fn() or {}
            r.setdefault("name", name)
            r["seconds"] = round(time.perf_counter() - t0, 2)
            results.append(r)
        except Exception:
            failed += 1
            traceback.print_exc()
    print("\n# name,us_per_call,derived")
    for r in results:
        us = r.get("fused_us") or r.get("seconds", 0) * 1e6
        derived = {k: v for k, v in r.items()
                   if k not in ("name", "seconds")}
        print(f"{r['name']},{us:.1f},{derived}")
    if args.json:
        payload = dict(timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                       argv=sys.argv[1:], **_host_header(), failed=failed,
                       results=_jsonable(results))
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
