"""Benchmark harness — one entry per paper figure/claim + framework perf.

  fig4_calibration     paper Fig. 4  (MC calibration narrows STP offsets)
  fig8_event_interface paper Fig. 8  (event-bus integrity, adapted)
  fig11_rstdp          paper Fig. 11 (R-STDP reward -> ~1 @ 40% overlap)
  step_time            paper §5     (290us claim: scan vs dispatch vs host)
  kernels              Pallas hot-spot microbenchmarks
  ppuvm                PPU-VM executor ladder (scan / specialized /
                       pallas) vs the fixed-function rule; the ladder is
                       emitted under ``executor_ladder`` in --json output
                       (plus the specializer-cache hit/miss/eviction
                       delta over the bench)
  telemetry            observability overhead ladder: scanned training
                       with the jit-safe counter pytree off vs on
                       (paired-median), counter summary, phase split,
                       and a run report under results/
  wafer                multi-chip weak scaling + routed events/s vs the
                       ~0.4M events/s bus budget
  faults               defect-tolerance sweep: §5 reward vs injected
                       fault rate, naive vs screened+blacklisted, plus
                       the dead-link failover accounting
  mapper               network-mapper compile time vs size, ring relay
                       overhead vs fan-in, mapped-vs-monolithic
                       step-time ratio
  roofline             §Roofline table from the dry-run artifacts

Usage:
  PYTHONPATH=src python -m benchmarks.run [suite] [--json BENCH_x.json]

``--json`` persists the machine-readable results (the bench trajectory
across PRs); without it results are print-only.
"""
import argparse
import json
import sys
import time
import traceback

# provenance + serialization shared with the run-report subsystem: BENCH_*
# trajectory files and results/REPORT_* carry the same header fields
from repro.obs.report import host_header as _host_header
from repro.obs.report import jsonable as _jsonable


def main() -> None:
    from benchmarks import (fig4_calibration, fig8_event_interface,
                            fig11_rstdp, step_time, faults_bench,
                            kernels_bench, mapper_bench, ppuvm_bench,
                            roofline_table, telemetry_bench, wafer_bench)
    suites = [
        ("fig4_calibration", fig4_calibration.run),
        ("fig8_event_interface", fig8_event_interface.run),
        ("fig11_rstdp", fig11_rstdp.run),
        ("step_time", step_time.run),
        ("kernels", kernels_bench.run),
        ("ppuvm", ppuvm_bench.run),
        ("telemetry", telemetry_bench.run),
        ("wafer", wafer_bench.run),
        ("faults", faults_bench.run),
        ("mapper", mapper_bench.run),
        ("roofline", roofline_table.run),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single suite by name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist machine-readable results to PATH")
    args = ap.parse_args()
    results = []
    failed = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            r = fn() or {}
            r.setdefault("name", name)
            r["seconds"] = round(time.perf_counter() - t0, 2)
            results.append(r)
        except Exception:
            failed += 1
            traceback.print_exc()
    print("\n# name,us_per_call,derived")
    for r in results:
        us = r.get("fused_us") or r.get("seconds", 0) * 1e6
        derived = {k: v for k, v in r.items()
                   if k not in ("name", "seconds")}
        print(f"{r['name']},{us:.1f},{derived}")
    if args.json:
        payload = dict(timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
                       argv=sys.argv[1:], **_host_header(), failed=failed,
                       results=_jsonable(results))
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
