"""Kernel microbenchmarks: oracle path wall-time on CPU (structural check)
+ analytic VMEM/roofline expectations for the TPU target."""
import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=30):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    from repro.kernels.synray.ref import synaptic_current_ref
    from repro.kernels.corr.ref import correlation_window_ref
    from repro.kernels.ppu_update.ref import rstdp_update_ref

    R, C, B, T = 256, 512, 16, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    ev = (jax.random.uniform(ks[0], (B, R)) < 0.1).astype(jnp.float32)
    ea = jax.random.randint(ks[1], (B, R), 0, 64, jnp.int8)
    w = jax.random.randint(ks[2], (R, C), 0, 64, jnp.int8)
    st = jax.random.randint(ks[3], (R, C), 0, 64, jnp.int8)
    rows = []

    t = _time(jax.jit(synaptic_current_ref), ev, ea, w, st)
    flops = 2 * B * R * C
    rows.append(("synray", t * 1e6, f"{flops/t/1e9:.1f} GFLOP/s oracle"))

    pre = (jax.random.uniform(ks[4], (T, R)) < 0.1).astype(jnp.float32)
    post = (jax.random.uniform(ks[5], (T, C)) < 0.1).astype(jnp.float32)
    z = jnp.zeros
    # `corr` is the PRODUCTION CPU path (`repro.core.correlation.window`
    # ref impl: vector trace scans + one window einsum). The per-step
    # oracle below is ~40x slower — that is its real sequential cost
    # (T x two [R, C] accumulator updates = ~134 MFLOP of outer products
    # at [256, 512]), NOT retracing: both are module-jitted once. Earlier
    # BENCH files reported the oracle's time under the `corr` label.
    from repro.core import correlation
    tau = -1.0 / float(jnp.log(0.96))
    st = correlation.CorrelationState(z((R,)), z((C,)), z((R, C)),
                                      z((R, C)))
    f = jax.jit(lambda s, p, q: correlation.window(
        s, p, q, tau_pre=tau, tau_post=tau, dt=1.0, impl="ref"))
    t = _time(f, st, pre, post)
    # fused kernel HBM traffic: (R*C accumulators once) vs (T x R*C naive)
    rows.append(("corr", t * 1e6,
                 f"production window path; fusion saves {T}x accumulator "
                 f"HBM traffic on TPU"))

    f = jax.jit(lambda *a: correlation_window_ref(*a, lam=0.96))
    t = _time(f, pre, post, z((R,)), z((C,)), z((R, C)), z((R, C)))
    rows.append(("corr_oracle", t * 1e6,
                 f"per-step oracle: {T} sequential [R, C] updates — the "
                 f"cost the window path removes"))

    ac = jax.random.uniform(ks[6], (R, C)) * 20
    aa = jax.random.uniform(ks[7], (R, C)) * 20
    f = jax.jit(lambda *a: rstdp_update_ref(*a, eta=8.0))
    t = _time(f, w, ac, aa, jnp.zeros(C), jnp.ones(C), jnp.ones(C),
              jnp.zeros((R, C)))
    rows.append(("ppu_update", t * 1e6, "row-parallel, 128-lane blocks"))

    print("# kernel microbenchmarks (oracle path, CPU container)")
    for name, us, note in rows:
        print(f"{name:12s} {us:9.1f} us/call   {note}")
    return dict(name="kernels", rows=[(n, u) for n, u, _ in rows])


if __name__ == "__main__":
    run()
