"""Kernel microbenchmarks: oracle path wall-time on CPU (structural check)
+ analytic VMEM/roofline expectations for the TPU target."""
import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=30):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _sparse_density_sweep():
    """Dense vs event-sparse synaptic window across firing-rate densities
    (full-size window: the production shape of one blocked-backend trial).
    Returns the sweep plus the measured dense/sparse crossover density —
    the number ``synapse.SPARSE_THRESHOLD`` is calibrated against."""
    import numpy as np
    from repro.core import events, synapse

    T, R, C = 128, 256, 512
    w = jax.random.randint(jax.random.PRNGKey(1), (R, C), 0, 64, jnp.int8)
    a = jax.random.randint(jax.random.PRNGKey(2), (R, C), 0, 4, jnp.int8)

    dense_fn = jax.jit(lambda *o: synapse.synaptic_current_window(
        *o, sparse="never"))
    auto_fn = jax.jit(lambda *o: synapse.synaptic_current_window(
        *o, sparse="auto"))

    sweep = []
    for p in (0.001, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0):
        ks = jax.random.split(jax.random.PRNGKey(int(p * 10000)), 3)
        fired = jax.random.uniform(ks[0], (T, R)) < p
        ev = jnp.where(fired, jax.random.uniform(
            ks[1], (T, R), minval=0.1, maxval=1.5), 0.0)
        ad = jax.random.randint(ks[2], (T, R), 0, 4, jnp.int8)
        n, kmax = (int(x) for x in events.window_stats(ev))
        # capacities sized for THIS density (the honest sparse cost: a
        # deployment tuning its threshold sizes the stream accordingly)
        E = max(32, ((n + 7) // 8) * 8)
        K = max(8, ((kmax + 3) // 4) * 4)
        sparse_fn = jax.jit(lambda *o, E=E, K=K: synapse.
                            synaptic_current_window(
                                *o, sparse="always", max_events=E,
                                k_cap=K))
        t_dense = _time(dense_fn, w, a, ev, ad, 1.0)
        t_sparse = _time(sparse_fn, w, a, ev, ad, 1.0)
        t_auto = _time(auto_fn, w, a, ev, ad, 1.0)
        sweep.append(dict(density=p, n_events=n, dense_us=t_dense * 1e6,
                          sparse_us=t_sparse * 1e6, auto_us=t_auto * 1e6,
                          speedup=t_dense / t_sparse))

    # crossover: lowest swept density where dense is at least as fast
    crossover = next((s["density"] for s in sweep if s["speedup"] <= 1.0),
                     1.0)
    low, high = sweep[0], sweep[-1]
    auto_ok = (low["auto_us"] < low["dense_us"]
               and high["auto_us"] < 1.5 * high["dense_us"])
    at_1pct = next(s for s in sweep if s["density"] == 0.01)
    print("# synray_sparse density sweep "
          f"[T={T}, R={R}, C={C}] (us/window)")
    for s in sweep:
        print(f"  p={s['density']:<6g} dense {s['dense_us']:8.1f}  "
              f"sparse {s['sparse_us']:8.1f}  auto {s['auto_us']:8.1f}  "
              f"speedup {s['speedup']:5.2f}x")
    print(f"  crossover ~{crossover:g}, speedup@1% "
          f"{at_1pct['speedup']:.2f}x, auto tracks best: {auto_ok}")
    return dict(sweep=sweep, crossover_density=crossover,
                speedup_at_1pct=at_1pct["speedup"],
                auto_tracks_best=bool(auto_ok),
                threshold_default=synapse.SPARSE_THRESHOLD)


def run():
    from repro.kernels.synray.ref import synaptic_current_ref
    from repro.kernels.corr.ref import correlation_window_ref
    from repro.kernels.ppu_update.ref import rstdp_update_ref

    R, C, B, T = 256, 512, 16, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    ev = (jax.random.uniform(ks[0], (B, R)) < 0.1).astype(jnp.float32)
    ea = jax.random.randint(ks[1], (B, R), 0, 64, jnp.int8)
    w = jax.random.randint(ks[2], (R, C), 0, 64, jnp.int8)
    st = jax.random.randint(ks[3], (R, C), 0, 64, jnp.int8)
    rows = []

    t = _time(jax.jit(synaptic_current_ref), ev, ea, w, st)
    flops = 2 * B * R * C
    rows.append(("synray", t * 1e6, f"{flops/t/1e9:.1f} GFLOP/s oracle"))

    pre = (jax.random.uniform(ks[4], (T, R)) < 0.1).astype(jnp.float32)
    post = (jax.random.uniform(ks[5], (T, C)) < 0.1).astype(jnp.float32)
    z = jnp.zeros
    # `corr` is the PRODUCTION CPU path (`repro.core.correlation.window`
    # ref impl: vector trace scans + one window einsum). The per-step
    # oracle below is ~40x slower — that is its real sequential cost
    # (T x two [R, C] accumulator updates = ~134 MFLOP of outer products
    # at [256, 512]), NOT retracing: both are module-jitted once. Earlier
    # BENCH files reported the oracle's time under the `corr` label.
    from repro.core import correlation
    tau = -1.0 / float(jnp.log(0.96))
    st = correlation.CorrelationState(z((R,)), z((C,)), z((R, C)),
                                      z((R, C)))
    f = jax.jit(lambda s, p, q: correlation.window(
        s, p, q, tau_pre=tau, tau_post=tau, dt=1.0, impl="ref"))
    t = _time(f, st, pre, post)
    # fused kernel HBM traffic: (R*C accumulators once) vs (T x R*C naive)
    rows.append(("corr", t * 1e6,
                 f"production window path; fusion saves {T}x accumulator "
                 f"HBM traffic on TPU"))

    f = jax.jit(lambda *a: correlation_window_ref(*a, lam=0.96))
    t = _time(f, pre, post, z((R,)), z((C,)), z((R, C)), z((R, C)))
    rows.append(("corr_oracle", t * 1e6,
                 f"per-step oracle: {T} sequential [R, C] updates — the "
                 f"cost the window path removes"))

    ac = jax.random.uniform(ks[6], (R, C)) * 20
    aa = jax.random.uniform(ks[7], (R, C)) * 20
    f = jax.jit(lambda *a: rstdp_update_ref(*a, eta=8.0))
    t = _time(f, w, ac, aa, jnp.zeros(C), jnp.ones(C), jnp.ones(C),
              jnp.zeros((R, C)))
    rows.append(("ppu_update", t * 1e6, "row-parallel, 128-lane blocks"))

    print("# kernel microbenchmarks (oracle path, CPU container)")
    for name, us, note in rows:
        print(f"{name:12s} {us:9.1f} us/call   {note}")
    sparse = _sparse_density_sweep()
    return dict(name="kernels", rows=[(n, u) for n, u, _ in rows],
                synray_sparse=sparse)


if __name__ == "__main__":
    run()
