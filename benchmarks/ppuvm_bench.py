"""PPU-VM interpreter overhead vs the fixed-function R-STDP path.

Two levels:

  * rule-only: `VectorUnit.run_program` (ISA R-STDP, interpreted
    instruction-by-instruction) vs `ppu_update.rstdp_update_ref` (one
    fused jnp expression) on full-size [256, 512] synapse arrays — the
    raw cost of programmability;
  * in-scan: the §5 experiment's scanned training with
    ``rule_impl="vm"`` vs ``"python"`` — what the overhead amounts to
    once the emulation window dominates the trial.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=20):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    import dataclasses

    from repro.configs.bss2 import BSS2
    from repro.core.anncore import AnnCore
    from repro.core.ppu import VectorUnit
    from repro.ppuvm import programs
    from repro.verif.mismatch import sample_instance

    # -- rule-only: full-size array, program interpreter vs fused update --
    cfg = BSS2  # 256 x 512
    inst = sample_instance(cfg, jax.random.PRNGKey(0))
    ppu = VectorUnit(cfg, inst)
    core = AnnCore(cfg, inst)
    st = core.init_state()
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    st = st._replace(
        syn=st.syn._replace(weights=jax.random.randint(
            ks[0], (cfg.n_rows, cfg.n_cols), 0, 64, jnp.int8)),
        corr=st.corr._replace(
            a_causal=jax.random.uniform(ks[1], (cfg.n_rows, cfg.n_cols),
                                        maxval=8.0),
            a_acausal=jax.random.uniform(ks[2], (cfg.n_rows, cfg.n_cols),
                                         maxval=8.0)))
    reward = (jax.random.uniform(ks[3], (cfg.n_cols,)) < 0.5
              ).astype(jnp.float32)
    rs = dict(mean_reward=jnp.zeros(cfg.n_cols), key=jax.random.PRNGKey(2))
    prog = jnp.asarray(programs.rstdp_program(eta=0.5))

    f_fixed = jax.jit(lambda s, r: ppu.apply_rstdp(
        s, dict(rs), reward=r, eta=0.5, impl="ref"))
    f_vm = jax.jit(lambda s, r: ppu.apply_rstdp_program(
        s, dict(rs), reward=r, program=prog))
    t_fixed = _time(f_fixed, st, reward)
    t_vm = _time(f_vm, st, reward)

    # -- in-scan: whole §5 experiment, python rule vs VM program rule -----
    from repro.core.hybrid import RSTDPConfig, make_experiment, \
        make_scanned_training

    n_trials = 50
    ecfg = RSTDPConfig()
    t_scan = {}
    for impl in ("python", "vm"):
        init, trial, meta = make_experiment(
            ecfg=ecfg, instance_key=jax.random.PRNGKey(0), rule_impl=impl)
        scanned = make_scanned_training(meta["scanned_training"])
        stims = jnp.asarray(np.resize([1, 2, 0], n_trials), jnp.int32)

        def once(scanned=scanned, init=init, stims=stims):
            state, hist = scanned(init(jax.random.PRNGKey(1)), stims)
            return hist["mean_reward"]

        t_scan[impl] = _time(once, iters=5) / n_trials

    res = dict(
        name="ppuvm",
        rule_fixed_us=t_fixed * 1e6, rule_vm_us=t_vm * 1e6,
        rule_overhead_x=t_vm / t_fixed,
        trial_python_us=t_scan["python"] * 1e6,
        trial_vm_us=t_scan["vm"] * 1e6,
        trial_overhead_x=t_scan["vm"] / t_scan["python"],
        n_instructions=int(prog.shape[0]),
    )
    print(f"rule-only [256x512]: fixed {res['rule_fixed_us']:.0f}us  "
          f"VM {res['rule_vm_us']:.0f}us  "
          f"overhead {res['rule_overhead_x']:.2f}x "
          f"({res['n_instructions']} instructions)")
    print(f"in-scan trial [{ecfg.n_inputs}->{ecfg.n_neurons}]: "
          f"python {res['trial_python_us']:.0f}us  "
          f"VM {res['trial_vm_us']:.0f}us  "
          f"overhead {res['trial_overhead_x']:.2f}x")
    return res


if __name__ == "__main__":
    run()
