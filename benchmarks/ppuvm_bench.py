"""PPU-VM executor ladder vs the fixed-function R-STDP path.

Three levels:

  * rule-only executor ladder: `VectorUnit.apply_rstdp_program` under
    every executor (scan interpreter, trace-time specializer, Pallas tile
    VM) vs `ppu_update.rstdp_update_ref` (one fused jnp expression) on
    full-size [256, 512] synapse arrays — the raw cost of
    programmability per executor. The ISSUE-3 acceptance bar is
    specialized <= 1.5x the fixed-function path (from 5.3x for the scan
    interpreter in PR 2).
  * in-scan: the §5 experiment's scanned training with
    ``rule_impl="vm"`` per executor vs ``"python"`` — what the overhead
    amounts to once the emulation window dominates the trial.

The Pallas executor is timed in its native mode on TPU and in
kernel-interpret mode elsewhere; interpret mode measures semantics, not
speed, so it is reported but excluded from the acceptance comparison.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=20):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    """Executor ladder + in-scan comparison, with the specializer-cache
    stats delta for the whole bench surfaced in the result (satellite of
    the PR 7 observability work: a cache that silently thrashes shows up
    as a specializer that silently got 64x slower)."""
    from repro.obs.timing import CacheDelta, eviction_storm

    with CacheDelta(warn=False) as cd:
        res = _run_inner()
    res["specialize_cache"] = dict(cd.delta)
    storm = eviction_storm(cd.delta)
    res["cache_eviction_storm"] = storm
    print(f"specializer cache over this bench: {cd.delta['hits']} hits / "
          f"{cd.delta['misses']} misses / {cd.delta['evictions']} "
          f"evictions (size {cd.delta['size']}/{cd.delta['max_size']})")
    if storm:
        print("WARNING: eviction storm — the program working set exceeds "
              "the LRU capacity; every upload re-specializes")
    return res


def _run_inner():
    from repro.configs.bss2 import BSS2
    from repro.core.anncore import AnnCore
    from repro.core.ppu import VectorUnit
    from repro.ppuvm import programs
    from repro.verif.mismatch import sample_instance

    # -- rule-only: full-size array, executor ladder vs fused update ------
    cfg = BSS2  # 256 x 512
    inst = sample_instance(cfg, jax.random.PRNGKey(0))
    ppu = VectorUnit(cfg, inst)
    core = AnnCore(cfg, inst)
    st = core.init_state()
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    st = st._replace(
        syn=st.syn._replace(weights=jax.random.randint(
            ks[0], (cfg.n_rows, cfg.n_cols), 0, 64, jnp.int8)),
        corr=st.corr._replace(
            a_causal=jax.random.uniform(ks[1], (cfg.n_rows, cfg.n_cols),
                                        maxval=8.0),
            a_acausal=jax.random.uniform(ks[2], (cfg.n_rows, cfg.n_cols),
                                         maxval=8.0)))
    reward = (jax.random.uniform(ks[3], (cfg.n_cols,)) < 0.5
              ).astype(jnp.float32)
    rs = dict(mean_reward=jnp.zeros(cfg.n_cols), key=jax.random.PRNGKey(2))
    prog = jnp.asarray(programs.rstdp_program(eta=0.5))

    on_tpu = jax.default_backend() == "tpu"
    pallas_ex = "pallas" if on_tpu else "pallas_interpret"

    f_fixed = jax.jit(lambda s, r: ppu.apply_rstdp(
        s, dict(rs), reward=r, eta=0.5, impl="ref"))
    t_fixed = _time(f_fixed, st, reward)

    ladder = {}
    for ex in ("scan", "specialized", pallas_ex):
        f = jax.jit(lambda s, r, _ex=ex: ppu.apply_rstdp_program(
            s, dict(rs), reward=r, program=prog, executor=_ex))
        iters = 3 if ex == "pallas_interpret" else 20
        ladder[ex] = _time(f, st, reward, iters=iters)

    # -- in-scan: whole §5 experiment, python rule vs VM executors --------
    from repro.core.hybrid import RSTDPConfig, make_experiment, \
        make_scanned_training

    n_trials = 50
    ecfg = RSTDPConfig()
    t_scan = {}
    scan_variants = [("python", "python", "auto"),
                     ("vm", "vm", "specialized"),
                     ("vm_scan", "vm", "scan")]
    for label, impl, vex in scan_variants:
        init, trial, meta = make_experiment(
            ecfg=ecfg, instance_key=jax.random.PRNGKey(0), rule_impl=impl,
            vm_executor=vex)
        scanned = make_scanned_training(meta["scanned_training"])
        stims = jnp.asarray(np.resize([1, 2, 0], n_trials), jnp.int32)

        def once(scanned=scanned, init=init, stims=stims):
            state, hist = scanned(init(jax.random.PRNGKey(1)), stims)
            return hist["mean_reward"]

        t_scan[label] = _time(once, iters=5) / n_trials

    executor_ladder = dict(
        fixed_us=t_fixed * 1e6,
        **{f"{ex}_us": t * 1e6 for ex, t in ladder.items()},
        **{f"{ex}_overhead_x": t / t_fixed for ex, t in ladder.items()},
    )
    res = dict(
        name="ppuvm",
        executor_ladder=executor_ladder,
        rule_fixed_us=t_fixed * 1e6,
        rule_vm_us=ladder["scan"] * 1e6,
        rule_overhead_x=ladder["scan"] / t_fixed,
        rule_specialized_overhead_x=ladder["specialized"] / t_fixed,
        trial_python_us=t_scan["python"] * 1e6,
        trial_vm_us=t_scan["vm"] * 1e6,
        trial_vm_scan_us=t_scan["vm_scan"] * 1e6,
        trial_overhead_x=t_scan["vm"] / t_scan["python"],
        n_instructions=int(prog.shape[0]),
        pallas_mode=pallas_ex,
    )
    print(f"rule-only [256x512] vs fixed {res['rule_fixed_us']:.0f}us "
          f"({res['n_instructions']} instructions):")
    for ex, t in ladder.items():
        note = "  (interpret: semantics-only)" if ex == "pallas_interpret" \
            else ""
        print(f"  {ex:<17} {t * 1e6:9.0f}us  {t / t_fixed:6.2f}x{note}")
    print(f"in-scan trial [{ecfg.n_inputs}->{ecfg.n_neurons}]: "
          f"python {res['trial_python_us']:.0f}us  "
          f"VM/specialized {res['trial_vm_us']:.0f}us "
          f"({res['trial_overhead_x']:.2f}x)  "
          f"VM/scan {res['trial_vm_scan_us']:.0f}us "
          f"({t_scan['vm_scan'] / t_scan['python']:.2f}x)")
    return res


if __name__ == "__main__":
    run()
