"""Paper Fig. 8 (adapted): event-interface integrity.

The silicon verification constrains the source-synchronous event bus to a
<=150 ps skew window so events latch identically on every lane. The
software analogue of that contract: the event-injection path must deliver
*bit-identical* spike routing across backends and across batch lanes, and
its throughput is a first-class number. We measure (a) cross-backend event
routing equality on randomized address patterns (the 'skew window' check),
and (b) events/second through the fused event path.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def run():
    from repro.configs.bss2 import BSS2
    from repro.core.synapse import synaptic_current
    from repro.kernels.synray.ref import synaptic_current_ref
    from repro.kernels.synray.kernel import synaptic_current_pallas

    R, C, B = 256, 512, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    ev = (jax.random.uniform(ks[0], (B, R)) < 0.1).astype(jnp.float32)
    ea = jax.random.randint(ks[1], (B, R), 0, 64, jnp.int8)
    w = jax.random.randint(ks[2], (R, C), 0, 64, jnp.int8)
    st = jax.random.randint(ks[3], (R, C), 0, 64, jnp.int8)

    ref = np.asarray(synaptic_current_ref(ev, ea, w, st))
    pal = np.asarray(synaptic_current_pallas(ev, ea, w, st, interpret=True))
    max_dev = float(np.max(np.abs(ref - pal)))
    print("# Fig. 8 adaptation — event-interface integrity")
    print(f"cross-backend routing deviation (skew-window analogue): "
          f"{max_dev:.2e} (must be 0 within fp32)")

    f = jax.jit(lambda *a: synaptic_current_ref(*a))
    f(ev, ea, w, st).block_until_ready()
    t0 = time.perf_counter()
    iters = 50
    for _ in range(iters):
        out = f(ev, ea, w, st)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    n_events = float(jnp.sum(ev)) * 1  # events per call
    print(f"event path: {n_events/dt/1e6:.2f} M events/s "
          f"({dt*1e6:.0f} us per {int(n_events)}-event step, "
          f"{R}x{C} array, batch {B})")
    return dict(name="fig8_event_interface", max_dev=max_dev,
                events_per_s=n_events / dt)


if __name__ == "__main__":
    run()
