"""Paper Fig. 8 (adapted): event-interface integrity.

The silicon verification constrains the source-synchronous event bus to a
<=150 ps skew window so events latch identically on every lane. The
software analogue of that contract: the event-injection path must deliver
*bit-identical* spike routing across backends and across batch lanes, and
its throughput is a first-class number. We measure (a) cross-backend event
routing equality on randomized address patterns (the 'skew window' check),
and (b) events/second through the fused event path.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def run():
    from repro.configs.bss2 import BSS2
    from repro.core.synapse import synaptic_current
    from repro.kernels.synray.ref import synaptic_current_ref
    from repro.kernels.synray.kernel import synaptic_current_pallas

    R, C, B = 256, 512, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    ev = (jax.random.uniform(ks[0], (B, R)) < 0.1).astype(jnp.float32)
    ea = jax.random.randint(ks[1], (B, R), 0, 64, jnp.int8)
    w = jax.random.randint(ks[2], (R, C), 0, 64, jnp.int8)
    st = jax.random.randint(ks[3], (R, C), 0, 64, jnp.int8)

    ref = np.asarray(synaptic_current_ref(ev, ea, w, st))
    pal = np.asarray(synaptic_current_pallas(ev, ea, w, st, interpret=True))
    max_dev = float(np.max(np.abs(ref - pal)))
    print("# Fig. 8 adaptation — event-interface integrity")
    print(f"cross-backend routing deviation (skew-window analogue): "
          f"{max_dev:.2e} (must be 0 within fp32)")

    f = jax.jit(lambda *a: synaptic_current_ref(*a))
    f(ev, ea, w, st).block_until_ready()
    t0 = time.perf_counter()
    iters = 50
    for _ in range(iters):
        out = f(ev, ea, w, st)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    n_events = float(jnp.sum(ev)) * 1  # events per call
    print(f"event path: {n_events/dt/1e6:.2f} M events/s "
          f"({dt*1e6:.0f} us per {int(n_events)}-event step, "
          f"{R}x{C} array, batch {B})")

    # firing-rate sweep: events/s through the whole-window path, dense vs
    # event-sparse — the paper budgets the event bus at ~0.4M events/s, so
    # per-event cost of the emulation backends belongs in the same
    # artifact. Work per window is O(T*R*C) dense but O(n_events * C)
    # sparse: dense events/s COLLAPSES at low rates (same matmul, fewer
    # events to bill it to) while sparse stays roughly flat.
    from repro.core import events as ev_mod
    from repro.core import synapse
    T = 128
    dense_fn = jax.jit(lambda *o: synapse.synaptic_current_window(
        *o, sparse="never"))
    rate_sweep = []
    for rate in (0.001, 0.01, 0.05, 0.1, 0.5):
        ks = jax.random.split(jax.random.PRNGKey(int(rate * 1e4)), 3)
        fired = jax.random.uniform(ks[0], (T, R)) < rate
        evt = jnp.where(fired, jax.random.uniform(
            ks[1], (T, R), minval=0.1, maxval=1.5), 0.0)
        adt = jax.random.randint(ks[2], (T, R), 0, 64, jnp.int8)
        n, kmax = (int(x) for x in ev_mod.window_stats(evt))
        E = max(32, ((n + 7) // 8) * 8)
        K = max(8, ((kmax + 3) // 4) * 4)
        sparse_fn = jax.jit(lambda *o, E=E, K=K: synapse.
                            synaptic_current_window(
                                *o, sparse="always", max_events=E,
                                k_cap=K))

        def _t(fn):
            fn(w, st, evt, adt, 1.0).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(10):
                out = fn(w, st, evt, adt, 1.0)
            out.block_until_ready()
            return (time.perf_counter() - t0) / 10

        td, ts = _t(dense_fn), _t(sparse_fn)
        rate_sweep.append(dict(
            rate=rate, n_events=n, dense_us=td * 1e6, sparse_us=ts * 1e6,
            dense_events_per_s=n / td, sparse_events_per_s=n / ts))
    print(f"# firing-rate sweep [T={T}, {R}x{C} window]: events/s by path")
    for s in rate_sweep:
        print(f"  rate={s['rate']:<6g} n={s['n_events']:<6d} "
              f"dense {s['dense_events_per_s']/1e6:8.3f} M ev/s   "
              f"sparse {s['sparse_events_per_s']/1e6:8.3f} M ev/s")
    return dict(name="fig8_event_interface", max_dev=max_dev,
                events_per_s=n_events / dt, rate_sweep=rate_sweep)


if __name__ == "__main__":
    run()
