"""Network-mapper benchmarks: compile time, relay overhead, step ratio.

Three rungs (recorded as the ``mapper`` suite, BENCH_pr10_mapper.json):

* mapping time vs network size — the mapper is a host-side compiler
  (partition + row allocation + routing + validation); it must stay
  interactive even for beyond-native-fabric networks;
* relay-row overhead vs recurrent fan-in on the ring topology — every
  edge whose chip distance is 2 costs one forward rule and at most one
  transit row (reuse makes it sublinear in edges);
* mapped-vs-monolithic step-time ratio — the price of running the SAME
  network split over K chips + router instead of one big virtual chip
  (the bits are identical either way: tests/test_mapper.py).
"""
import time

import numpy as np

REPEATS = 5
SIZES = ((100, 100), (200, 400), (300, 700))
K = 4
FAN_INS = (1, 2, 4, 6)
W, T = 2, 64


def _bench(fn, *args):
    import jax
    best = float("inf")
    out = None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run():
    import jax.numpy as jnp

    from repro import mapper

    rng = np.random.default_rng(0)

    # --- mapping time vs size (native 256x512 chips, all2all) -----------
    # locality-structured fan-out (each input drives a contiguous
    # neighborhood): unconstrained random graphs at 300x700 exceed the
    # native 256-row budget per chip — locality is what makes
    # beyond-fabric networks mappable, same as examples/map_network.py
    mapping_time = []
    for n_in, n_neurons in SIZES:
        w_in = np.zeros((n_in, n_neurons), np.int32)
        stride = max(1, n_neurons // n_in)
        for i in range(n_in):
            for d in range(4):
                w_in[i, (i * stride + d) % n_neurons] = 30 - 5 * d
        spec = mapper.NetworkSpec(n_in=n_in, n_neurons=n_neurons,
                                  w_in=w_in)
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            m = mapper.map_network(spec, n_chips=K)
            best = min(best, time.perf_counter() - t0)
        rows = int((m.row_source >= 0).sum())
        mapping_time.append(dict(n_in=n_in, n_neurons=n_neurons,
                                 ms=round(best * 1e3, 1), rows_used=rows))
        print(f"map {n_in}x{n_neurons} -> {K} chips: {best * 1e3:7.1f} ms, "
              f"{rows} rows", flush=True)

    # --- relay overhead vs recurrent fan-in (ring) -----------------------
    # on the K=4 ring only chip distance 1 is a direct link; distance 2
    # costs a relay. Allow exactly those distances so every extra unit of
    # fan-in adds a realizable mix of direct and relayed edges.
    n_in, n_neurons = 32, 64
    block = n_neurons // K
    chip_of = np.arange(n_neurons) // block
    dist = (chip_of[None, :] - chip_of[:, None]) % K
    rec_mask = (dist == 1) | (dist == 2)
    relay = []
    for f in FAN_INS:
        spec = mapper.random_spec(rng, n_in, n_neurons, fan_out=2,
                                  rec_fan_out=f, dale=True,
                                  rec_mask=rec_mask)
        m = mapper.map_network(spec, n_chips=K, chip_rows=256,
                               chip_cols=block, topology="ring")
        n_rec = int((spec.w_rec != 0).sum())
        relay.append(dict(rec_fan_out=f, rec_edges=n_rec,
                          relayed_edges=m.n_relayed_edges,
                          transit_rows=m.n_transit_rows))
        print(f"ring fan-in {f}: {n_rec:3d} rec edges, "
              f"{m.n_relayed_edges:3d} relayed, "
              f"{m.n_transit_rows:3d} transit rows", flush=True)

    # --- mapped vs monolithic step time ----------------------------------
    spec = mapper.random_spec(rng, 64, 128, fan_out=8, rec_fan_out=2,
                              dale=True)
    ev = jnp.asarray((rng.random((W, T, 64)) < 0.05).astype(np.float32))
    step = {}
    for label, n_chips, cols in (("monolithic", 1, 128), ("mapped", K, 32)):
        rows = max(mapper.min_chip_rows(spec, n_chips, chip_cols=cols), 8)
        m = mapper.map_network(spec, n_chips=n_chips, chip_rows=rows,
                               chip_cols=cols)
        rt = mapper.build_runtime(m)
        rt.run(ev)                                   # compile
        best, (_, out) = _bench(rt.run, ev)
        step[label] = dict(us_per_window=round(best / W * 1e6, 1),
                           spikes=int(np.asarray(out["spikes"]).sum()))
        print(f"{label}: {step[label]['us_per_window']:8.1f} us/window",
              flush=True)
    ratio = step["mapped"]["us_per_window"] / step["monolithic"][
        "us_per_window"]
    print(f"mapped/monolithic step-time ratio: {ratio:.2f}x "
          f"({K} chips + router vs one virtual chip)")
    return dict(mapping_time=mapping_time, relay_overhead=relay,
                step_time=step, mapped_over_monolithic=round(ratio, 2))
