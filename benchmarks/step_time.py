"""Paper §5 timing claim: removing host I/O from the training loop is the
architectural win — 290 us/training step on silicon once read-out happens
only at the end.

We measure the same ladder on the machine model, slowest to fastest:

  host      host-in-the-loop: observables cross the host boundary every
            trial (device_get/device_put) — the path the paper eliminates
  oracle    per-trial jit dispatch of the seed's per-step emulation (the
            correlation sensors and the address-match mask recomputed at
            every dt inside the scan) — the pre-fusion hot path
  dispatch  per-trial jit dispatch of the FUSED trial (hoisted correlation
            window, whole-trial synray matmul, neuron-only dt scan)
  scan      the whole experiment as ONE jitted lax.scan over trials —
            no host dispatch at all, §5's "everything on device"

Absolute times are CPU-container artifacts; the RATIOS are the
architecture.
"""
import time

import jax
import numpy as np


REPEATS = 4   # best-of repeats: CPU container timings are noisy


def _bench_loop(trial_jit, state0, stims, n_trials):
    state, _ = trial_jit(state0, stims[0])         # warmup/compile
    jax.block_until_ready(state)
    best = float("inf")
    for _ in range(REPEATS):
        state = state0
        t0 = time.perf_counter()
        for i in range(n_trials):
            state, m = trial_jit(state, stims[i])
        jax.block_until_ready(state)
        best = min(best, (time.perf_counter() - t0) / n_trials)
    return best


def run(n_trials: int = 60):
    import jax.numpy as jnp
    from repro.core.hybrid import (host_loop_trial, make_experiment,
                                   make_scanned_training)

    init, trial, meta = make_experiment()                    # fused backend
    init_o, trial_o, _ = make_experiment(backend="oracle")   # seed hot path
    state0 = init(jax.random.PRNGKey(0))
    stims_np = np.resize([1, 2, 0], n_trials).astype(np.int32)
    stims = [jnp.int32(int(s)) for s in stims_np]
    stims_arr = jnp.asarray(stims_np)

    # --- scan: whole experiment, one jitted program ---------------------
    scanned = make_scanned_training(meta["scanned_training"])
    s, _ = scanned(init(jax.random.PRNGKey(0)), stims_arr)  # warmup/compile
    jax.block_until_ready(s)
    scan_t = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        s, hist = scanned(init(jax.random.PRNGKey(0)), stims_arr)
        jax.block_until_ready((s, hist))
        scan_t = min(scan_t, (time.perf_counter() - t0) / n_trials)

    # --- per-trial dispatch, fused and oracle backends ------------------
    dispatch_t = _bench_loop(jax.jit(trial), state0, stims, n_trials)
    oracle_t = _bench_loop(jax.jit(trial_o), init_o(jax.random.PRNGKey(0)),
                           stims, n_trials)

    # --- host-in-the-loop ----------------------------------------------
    state2 = init(jax.random.PRNGKey(0))
    state2, _ = jax.jit(trial)(state2, stims[0])
    t0 = time.perf_counter()
    for i in range(n_trials):
        state2, m = host_loop_trial(trial, state2, stims[i])
    host_t = (time.perf_counter() - t0) / n_trials

    emu_us = 256 * 0.2  # emulated hardware time per trial (model time)
    print("# §5 timing — one-program scan vs dispatch vs host loop")
    print(f"scan     (one jitted program) : {scan_t*1e6:9.0f} us/trial")
    print(f"dispatch (fused trial)        : {dispatch_t*1e6:9.0f} us/trial")
    print(f"dispatch (oracle trial, seed) : {oracle_t*1e6:9.0f} us/trial")
    print(f"host-in-the-loop              : {host_t*1e6:9.0f} us/trial")
    print(f"scan vs seed dispatch : {oracle_t/scan_t:5.1f}x "
          f"(acceptance floor: 3x)")
    print(f"scan vs fused dispatch: {dispatch_t/scan_t:5.1f}x "
          f"(pure host-dispatch overhead)")
    print(f"host I/O removal      : {host_t/scan_t:5.1f}x "
          f"(paper: runtime 'heavily dominated' by host transfers; "
          f"290 us/step once eliminated)")
    print(f"(emulated model time per trial: {emu_us:.0f} us)")
    return dict(name="step_time",
                scan_us=scan_t * 1e6,
                # fused_us keeps the seed's meaning (one jitted trial,
                # dispatched per trial) so the bench trajectory stays
                # like-for-like across PRs; scan_us is the new program
                fused_us=dispatch_t * 1e6,
                dispatch_us=dispatch_t * 1e6,
                oracle_dispatch_us=oracle_t * 1e6,
                host_us=host_t * 1e6,
                speedup_scan_vs_seed_dispatch=oracle_t / scan_t,
                speedup_scan_vs_fused_dispatch=dispatch_t / scan_t,
                speedup_vs_host=host_t / scan_t)


if __name__ == "__main__":
    run()
