"""Paper §5 timing claim: removing host I/O from the training loop is the
architectural win — 290 us/training step on silicon once read-out happens
only at the end.

We measure the same ratio on the machine model: the fused on-device trial
(one jitted program: emulate -> digitize -> R-STDP -> write weights) vs the
host-in-the-loop variant that pulls observables to the host every trial.
Absolute times are CPU-container artifacts; the RATIO is the architecture.
"""
import time

import jax
import numpy as np


def run(n_trials: int = 60):
    from repro.core.hybrid import make_experiment, host_loop_trial
    import jax.numpy as jnp

    init, trial, meta = make_experiment()
    state = init(jax.random.PRNGKey(0))
    jtrial = jax.jit(trial)
    stims = np.resize([1, 2, 0], n_trials).astype(np.int32)

    # warmup/compile
    state, _ = jtrial(state, jnp.int32(1))
    jax.block_until_ready(state)

    t0 = time.perf_counter()
    for i in range(n_trials):
        state, m = jtrial(state, jnp.int32(int(stims[i])))
    jax.block_until_ready(state)
    fused = (time.perf_counter() - t0) / n_trials

    state2 = init(jax.random.PRNGKey(0))
    state2, _ = jtrial(state2, jnp.int32(1))
    t0 = time.perf_counter()
    for i in range(n_trials):
        state2, m = host_loop_trial(trial, state2, jnp.int32(int(stims[i])))
    host = (time.perf_counter() - t0) / n_trials

    emu_us = 256 * 0.2  # emulated hardware time per trial (model time)
    print("# §5 timing — fused on-device step vs host-in-the-loop")
    print(f"fused on-device trial : {fused*1e6:9.0f} us/step")
    print(f"host-in-the-loop trial: {host*1e6:9.0f} us/step")
    print(f"speedup from removing host I/O: {host/fused:.1f}x "
          f"(paper: runtime 'heavily dominated' by host transfers; "
          f"290 us/step once eliminated)")
    print(f"(emulated model time per trial: {emu_us:.0f} us)")
    return dict(name="step_time", fused_us=fused * 1e6, host_us=host * 1e6,
                speedup=host / fused)


if __name__ == "__main__":
    run()
