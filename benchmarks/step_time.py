"""Paper §5 timing claim: removing host I/O from the training loop is the
architectural win — 290 us/training step on silicon once read-out happens
only at the end.

We measure the same ladder on the machine model, slowest to fastest:

  host      host-in-the-loop: observables cross the host boundary every
            trial (device_get/device_put) — the path the paper eliminates
  oracle    per-trial jit dispatch of the seed's per-step emulation (the
            correlation sensors and the address-match mask recomputed at
            every dt inside the scan) — the pre-fusion hot path
  dispatch  per-trial jit dispatch of the FUSED trial (hoisted correlation
            window, whole-trial synray matmul, neuron-only dt scan)
  scan      the whole experiment as ONE jitted lax.scan over trials —
            no host dispatch at all, §5's "everything on device"
  blocked   the scan with AnnCore(backend="blocked"): the remaining
            per-dt neuron loop replaced by the time-blocked window
            (repro.kernels.neuron_scan) — on TPU the Pallas kernel keeps
            the state VMEM-resident for the whole trial; on CPU the
            packed-carry block scan amortizes the XLA while-loop cost

Absolute times are CPU-container artifacts; the RATIOS are the
architecture.
"""
import time

import jax
import numpy as np


REPEATS = 8   # best-of repeats: CPU container timings are noisy


def _bench_loop(trial_jit, state0, stims, n_trials):
    state, _ = trial_jit(state0, stims[0])         # warmup/compile
    jax.block_until_ready(state)
    best = float("inf")
    for _ in range(REPEATS):
        state = state0
        t0 = time.perf_counter()
        for i in range(n_trials):
            state, m = trial_jit(state, stims[i])
        jax.block_until_ready(state)
        best = min(best, (time.perf_counter() - t0) / n_trials)
    return best


def run(n_trials: int = 60):
    import jax.numpy as jnp
    from repro.core.hybrid import (host_loop_trial, make_experiment,
                                   make_scanned_training)

    init, trial, meta = make_experiment(backend="fused")
    init_o, trial_o, _ = make_experiment(backend="oracle")   # seed hot path
    init_b, _, meta_b = make_experiment(backend="blocked")
    state0 = init(jax.random.PRNGKey(0))
    stims_np = np.resize([1, 2, 0], n_trials).astype(np.int32)
    stims = [jnp.int32(int(s)) for s in stims_np]
    stims_arr = jnp.asarray(stims_np)

    # --- scan rungs: whole experiment, one jitted program. The fused and
    # blocked programs are measured INTERLEAVED (alternating reps) so the
    # blocked-vs-scan ratio sees identical machine weather — sequential
    # best-of lets one rung catch a quiet slice of a shared container and
    # skews the ratio either way.
    runs = [(make_scanned_training(meta["scanned_training"]), init),
            (make_scanned_training(meta_b["scanned_training"]), init_b)]
    for scanned, init_fn in runs:                       # warmup/compile
        s, _ = scanned(init_fn(jax.random.PRNGKey(0)), stims_arr)
        jax.block_until_ready(s)
    samples = [[], []]
    for _ in range(REPEATS):
        for i, (scanned, init_fn) in enumerate(runs):
            t0 = time.perf_counter()
            s, hist = scanned(init_fn(jax.random.PRNGKey(0)), stims_arr)
            jax.block_until_ready((s, hist))
            samples[i].append((time.perf_counter() - t0) / n_trials)
    scan_t, blocked_t = min(samples[0]), min(samples[1])
    # best-of favors whichever rung catches the quietest slice of a shared
    # container (the fused scan's runtime varies ~25%, the blocked one
    # ~10%, so best-of systematically understates the gap). The PAIRED
    # ratio — each rep's two programs run back-to-back in the same machine
    # window — cancels that drift; its median is the robust speedup.
    paired = sorted(f / b for f, b in zip(*samples))
    blocked_speedup_paired = paired[len(paired) // 2]

    # --- per-trial dispatch, fused and oracle backends ------------------
    dispatch_t = _bench_loop(jax.jit(trial), state0, stims, n_trials)
    oracle_t = _bench_loop(jax.jit(trial_o), init_o(jax.random.PRNGKey(0)),
                           stims, n_trials)

    # --- host-in-the-loop ----------------------------------------------
    state2 = init(jax.random.PRNGKey(0))
    state2, _ = jax.jit(trial)(state2, stims[0])
    t0 = time.perf_counter()
    for i in range(n_trials):
        state2, m = host_loop_trial(trial, state2, stims[i])
    host_t = (time.perf_counter() - t0) / n_trials

    emu_us = 256 * 0.2  # emulated hardware time per trial (model time)
    print("# §5 timing — one-program scan vs dispatch vs host loop")
    print(f"blocked  (time-blocked scan)  : {blocked_t*1e6:9.0f} us/trial")
    print(f"scan     (one jitted program) : {scan_t*1e6:9.0f} us/trial")
    print(f"dispatch (fused trial)        : {dispatch_t*1e6:9.0f} us/trial")
    print(f"dispatch (oracle trial, seed) : {oracle_t*1e6:9.0f} us/trial")
    print(f"host-in-the-loop              : {host_t*1e6:9.0f} us/trial")
    print(f"blocked vs scan       : {blocked_speedup_paired:5.2f}x "
          f"paired-median ({scan_t/blocked_t:.2f}x best-of; target 1.5x — "
          f"the isolated neuron phase is a steady 1.55x; see README for "
          f"the shared-container noise band)")
    print(f"scan vs seed dispatch : {oracle_t/scan_t:5.1f}x "
          f"(acceptance floor: 3x)")
    print(f"scan vs fused dispatch: {dispatch_t/scan_t:5.1f}x "
          f"(pure host-dispatch overhead)")
    print(f"host I/O removal      : {host_t/scan_t:5.1f}x "
          f"(paper: runtime 'heavily dominated' by host transfers; "
          f"290 us/step once eliminated)")
    print(f"(emulated model time per trial: {emu_us:.0f} us)")
    return dict(name="step_time",
                blocked_us=blocked_t * 1e6,
                scan_us=scan_t * 1e6,
                # fused_us keeps the seed's meaning (one jitted trial,
                # dispatched per trial) so the bench trajectory stays
                # like-for-like across PRs; scan_us is the new program
                fused_us=dispatch_t * 1e6,
                dispatch_us=dispatch_t * 1e6,
                oracle_dispatch_us=oracle_t * 1e6,
                host_us=host_t * 1e6,
                speedup_blocked_vs_scan=scan_t / blocked_t,
                speedup_blocked_vs_scan_paired=blocked_speedup_paired,
                speedup_scan_vs_seed_dispatch=oracle_t / scan_t,
                speedup_scan_vs_fused_dispatch=dispatch_t / scan_t,
                speedup_vs_host=host_t / scan_t)


if __name__ == "__main__":
    run()
