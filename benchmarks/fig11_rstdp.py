"""Paper Fig. 11: R-STDP pattern discrimination — mean expected reward
converges to ~1 for both populations despite 40% pattern overlap."""
import numpy as np


def run(n_trials: int = 450):
    from repro.core.hybrid import run_training

    out, state, meta = run_training(n_trials=n_trials, seed=0)
    even = np.asarray(meta["even"]) > 0
    mr = out["mean_reward"]

    def med(t, sel):
        return float(np.median(mr[t, sel]))

    print("# Fig. 11 reproduction — median <R> per population (40% overlap)")
    for frac in (0.1, 0.25, 0.5, 0.75, 1.0):
        t = int(n_trials * frac) - 1
        print(f"trial {t:4d}: A-pop(even)={med(t, even):.3f} "
              f"B-pop(odd)={med(t, ~even):.3f}")
    n = 100
    trail_e = float(np.mean(np.median(mr[-n:, :][:, even], axis=1)))
    trail_o = float(np.mean(np.median(mr[-n:, :][:, ~even], axis=1)))
    print(f"trailing-{n} mean of medians: even={trail_e:.3f} odd={trail_o:.3f}")
    print("paper claim: 'converges to approximately one for all neurons'")
    return dict(name="fig11_rstdp", trailing_even=trail_e,
                trailing_odd=trail_o)


if __name__ == "__main__":
    run()
