"""Fault-rate sweep: defect tolerance of the §5 closed loop.

The commissioning claim behind ``repro.faults``: wafers ship with dead
drivers, hot neurons and corrupted readouts, and the screening +
blacklist flow keeps the experiment usable. The sweep injects defect
realisations at increasing per-site rates and compares

  naive      trailing mean reward over ALL columns, faults unscreened
  screened   trailing mean reward over the HEALTHY (non-blacklisted)
             columns after the probe-based screening pass

against the clean baseline, plus the telemetry fault counters for the
screened run (``faults_injected`` / ``faults_detected`` /
``blacklisted_rows`` — degradation is never silent).

A second rung kills one inter-chip link of a 4-chip wafer partition and
reports the host-side re-route: forward rules installed, forwarded
events per window (``link_reroutes``), and that routed traffic survives.
"""
import time

import numpy as np

N_TRIALS = 150
TAIL = 45
RATES = (0.0, 0.06, 0.12, 0.25)


def _trailing(out, cols=slice(None)):
    return round(float(np.mean(out["mean_reward"][-TAIL:, cols])), 4)


def run():
    import jax

    from repro.core.hybrid import run_training
    from repro.faults import FaultPlan, sample_fault_plan, screen
    from repro.obs import trace as obs_trace
    from repro.wafer import InterChipRouter, reroute_plan, s5_column_plan

    out_clean, _, _ = run_training(n_trials=N_TRIALS, seed=1)
    clean = _trailing(out_clean)
    print(f"clean baseline: {clean:.4f} trailing mean reward", flush=True)

    sweep = []
    for rate in RATES:
        rng = np.random.default_rng(7)
        fp = (sample_fault_plan(32, 16, rng, p_dead_row=rate / 2,
                                p_hot_neuron=rate, p_cadc=rate, seed=1)
              if rate > 0 else None)
        row = dict(rate=rate, sites=0 if fp is None else fp.total_sites,
                   clean=clean)
        out_f, _, meta = run_training(n_trials=N_TRIALS, seed=1, faults=fp)
        row["naive"] = _trailing(out_f)
        t0 = time.perf_counter()
        bl = screen(meta["core"], meta["ppu"])
        row["screen_ms"] = round((time.perf_counter() - t0) * 1e3, 1)
        row["blacklisted_rows"] = bl.n_rows
        row["blacklisted_neurons"] = bl.n_neurons
        out_b, _, _ = run_training(n_trials=N_TRIALS, seed=1, faults=fp,
                                   blacklist=bl, telemetry=True)
        healthy = ~bl.neurons
        row["screened"] = (_trailing(out_b, healthy) if healthy.any()
                           else float("nan"))
        tl = out_b["telemetry"]
        row["faults_injected"] = int(tl["faults_injected"])
        row["faults_detected"] = int(tl["faults_detected"])
        sweep.append(row)
        print(f"rate={rate:5.2f}: {row['sites']:3d} sites, "
              f"naive {row['naive']:.4f}, screened {row['screened']:.4f} "
              f"(blacklist {bl.n_rows} rows / {bl.n_neurons} neurons, "
              f"screen {row['screen_ms']:.0f} ms)", flush=True)

    # link failover: kill one link of a 4-chip s5 partition
    import jax.numpy as jnp
    plan = s5_column_plan(4, 16, 16)
    links = plan.topology.links()
    dead = (0, 2)
    p2, n_re = reroute_plan(plan, [dead])
    fp = FaultPlan(dead_links=np.array([sd == dead for sd in links]))
    router = InterChipRouter(p2, faults=fp)
    sp = jnp.asarray((np.random.default_rng(0).random((64, 4, 4)) < 0.5)
                     .astype(np.float32))
    tele = obs_trace.init_telemetry()
    routed = router.init_buffer(64)
    fn = jax.jit(router.route)
    for _ in range(3):
        routed, tele = fn(sp, tele, routed_in=routed)
    s = obs_trace.summary(tele)
    failover = dict(dead_link=list(dead), rerouted_routes=n_re,
                    forward_rules=int(p2.n_forwards),
                    link_reroutes=int(s["link_reroutes"]),
                    routed_events=int(s["routed_events"]))
    print(f"failover: link {dead} dead -> {n_re} routes re-homed over "
          f"{p2.n_forwards} forward rules, {s['link_reroutes']} events "
          f"forwarded / {s['routed_events']} routed", flush=True)
    assert s["link_reroutes"] > 0 and s["routed_events"] > 0
    return dict(sweep=sweep, failover=failover)
