"""Telemetry overhead ladder: scanned training with the counter pytree
OFF vs ON.

OFF must be free — the disabled program is the same jaxpr as before the
telemetry subsystem existed (``None`` compiles out of the scan carry), so
its step time belongs inside the noise band of the PR 6 ``step_time``
scan rung. ON pays for the counter arithmetic riding the carry; that cost
is the price of observability and gets its own ladder entry.

Both programs are measured INTERLEAVED (alternating reps) and the
overhead is the PAIRED-ratio median, the same shared-container noise
discipline as ``step_time`` — sequential best-of lets one rung catch a
quiet slice of the machine and fakes (or hides) an overhead.

Also emitted: the ON run's counter summary, a phase-timing split of one
emulation window (``repro.obs.timing.profile_phases``), the
specializer-cache delta over the bench, and a full run report
(``results/REPORT_telemetry_bench.{json,md}``).
"""
import time

import jax
import numpy as np


REPEATS = 8   # best-of/paired repeats: CPU container timings are noisy
N_TRIALS = 60


def run():
    import jax.numpy as jnp
    from repro.core.hybrid import make_experiment, make_scanned_training
    from repro.obs import report as obs_report
    from repro.obs import trace as obs_trace
    from repro.obs.timing import CacheDelta, profile_phases

    with CacheDelta(warn=False) as cd:
        init_off, _, meta_off = make_experiment(
            instance_key=jax.random.PRNGKey(0))
        init_on, _, meta_on = make_experiment(
            instance_key=jax.random.PRNGKey(0), telemetry=True)
        stims = jnp.asarray(np.resize([1, 2, 0], N_TRIALS), jnp.int32)

        runs = [(make_scanned_training(meta_off["scanned_training"]),
                 init_off),
                (make_scanned_training(meta_on["scanned_training"]),
                 init_on)]
        final = [None, None]
        for i, (scanned, init_fn) in enumerate(runs):  # warmup/compile
            s, _ = scanned(init_fn(jax.random.PRNGKey(1)), stims)
            jax.block_until_ready(s)
            final[i] = s
        samples = [[], []]
        for _ in range(REPEATS):
            for i, (scanned, init_fn) in enumerate(runs):
                t0 = time.perf_counter()
                s, hist = scanned(init_fn(jax.random.PRNGKey(1)), stims)
                jax.block_until_ready((s, hist))
                samples[i].append((time.perf_counter() - t0) / N_TRIALS)
                final[i] = s
        off_t, on_t = min(samples[0]), min(samples[1])
        paired = sorted(b / a for a, b in zip(*samples))
        overhead_paired = paired[len(paired) // 2]

        # the ON run's counters — the report payload
        tele = obs_trace.summary(final[1].tele)

        # bit-exactness spot check rides the bench for free: same seeds,
        # one program with counters, one without
        w_match = bool(np.array_equal(np.asarray(final[0].w_signed),
                                      np.asarray(final[1].w_signed)))

        # phase attribution of one emulation window on the fused backend
        core = meta_off["core"]
        state0 = init_off(jax.random.PRNGKey(1))
        ecfg = meta_off["ecfg"]
        rng = np.random.default_rng(0)
        T = ecfg.trial_steps if hasattr(ecfg, "trial_steps") else 256
        ev = (rng.random((T, core.cfg.n_rows)) < 0.02).astype(np.float32)
        ad = np.zeros((T, core.cfg.n_rows), np.int8)
        phases = profile_phases(core, state0.core, ev, ad, iters=3)

    res = dict(
        name="telemetry",
        telemetry_off_us=off_t * 1e6,
        telemetry_on_us=on_t * 1e6,
        overhead_x_paired=overhead_paired,
        overhead_x_bestof=on_t / off_t,
        bit_exact_on_off=w_match,
        counters=tele,
        phase_us={k: v["best_us"] for k, v in phases.items()},
        specialize_cache=dict(cd.delta),
    )

    print("# telemetry overhead — scanned §5 training, counters off vs on")
    print(f"off (PR 6 program)  : {off_t * 1e6:9.0f} us/trial")
    print(f"on  (counter carry) : {on_t * 1e6:9.0f} us/trial")
    print(f"overhead            : {overhead_paired:6.3f}x paired-median "
          f"({on_t / off_t:.3f}x best-of)")
    print(f"on/off bit-exact    : {w_match}")
    print(f"counters: steps={tele['steps']} in={tele['in_events']} "
          f"out={tele['out_spikes']} trials={tele['trials']} "
          f"dense={tele['dense_windows']} sparse={tele['sparse_windows']} "
          f"fallbacks={tele['overflow_fallbacks']}")
    print("phase split (best us): "
          + "  ".join(f"{k}={v['best_us']:.0f}" for k, v in phases.items()))

    rep = obs_report.build_report(
        "telemetry_bench", telemetry=tele, timings=phases,
        cache=dict(cd.delta),
        config=dict(n_trials=N_TRIALS, repeats=REPEATS, backend="fused"),
        extra=dict(telemetry_off_us=res["telemetry_off_us"],
                   telemetry_on_us=res["telemetry_on_us"],
                   overhead_x_paired=overhead_paired))
    import os
    out_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "results")
    paths = obs_report.write_report(
        rep, os.path.join(out_dir, "REPORT_telemetry_bench.json"))
    print(f"report: {paths['json']}")
    return res


if __name__ == "__main__":
    run()
